"""Continuous-batching serving example: mixed-length prompts through the
slot-level scheduler (prefill + greedy decode through the VEXP stack).

  PYTHONPATH=src python examples/serve_batched.py [--arch gpt2-small]
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models import api
from repro.launch.serve import Server, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"[serve] arch={args.arch} (reduced config), "
          f"{args.requests} requests, prompts up to {args.prompt_len}, "
          f"+{args.max_new} tokens, exp_impl={cfg.exp_impl}")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, max_batch=4, max_seq=128)

    rng = np.random.default_rng(0)
    # ragged prompt lengths: the slot scheduler right-pads each admission
    # batch and tracks per-slot cache positions, so unequal lengths decode
    # exactly as if each request were served alone.
    lens = rng.integers(4, args.prompt_len + 1, args.requests)
    reqs = [Request(i, rng.integers(0, cfg.vocab, (int(lens[i]),),
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = server.run(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"[serve] {ntok} tokens in {dt:.2f}s ({ntok / dt:.1f} tok/s, "
          f"incl. compile)")
    for r in done:
        print(f"  req {r.rid}: len={len(r.prompt)} "
              f"prompt[:5]={r.prompt[:5].tolist()} -> out={r.out}")


if __name__ == "__main__":
    main()
