"""Quickstart: the VEXP exponential and softmax in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.vexp import vexp_f32, vexp_bf16_fixedpoint
from repro.core.softmax import softmax
from repro.core.attention import attention


def main():
    print("=== VEXP: Schraudolph + P(x) exponential (paper §III-D) ===")
    x = jnp.linspace(-10, 5, 7)
    print("x        :", np.asarray(x).round(2))
    print("vexp(x)  :", np.asarray(vexp_f32(x)).round(5))
    print("exp(x)   :", np.asarray(jnp.exp(x)).round(5))

    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.uniform(-20, 5, 100000), jnp.float32)
    rel = jnp.abs(vexp_f32(xs) - jnp.exp(xs)) / jnp.exp(xs)
    print(f"\nrelative error vs exp: mean {float(rel.mean())*100:.3f}%  "
          f"max {float(rel.max())*100:.3f}%   (paper: 0.14% / 0.78%)")

    hw = vexp_bf16_fixedpoint(xs.astype(jnp.bfloat16))
    print("bit-exact HW model sample:", np.asarray(hw[:3], np.float32))

    print("\n=== VEXP softmax (MAX / EXP / reciprocal-multiply NORM) ===")
    s = jax.random.normal(jax.random.PRNGKey(1), (4, 8)) * 3
    sm = softmax(s, exp_impl="vexp")
    print("rows sum to:", np.asarray(sm.sum(-1)).round(4))
    delta = jnp.abs(sm - jax.nn.softmax(s, -1)).max()
    print(f"max delta vs exact softmax: {float(delta):.2e}")

    print("\n=== FlashAttention-2 with VEXP partial softmax ===")
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 128, 2, 64))
    out_flash = attention(q, k, v, impl="flash", exp_impl="vexp")
    out_exact = attention(q, k, v, impl="xla", exp_impl="exact")
    print("output shape:", out_flash.shape, "(GQA 2:1, causal)")
    print(f"max delta flash-vexp vs exact: "
          f"{float(jnp.abs(out_flash - out_exact).max()):.2e}")


if __name__ == "__main__":
    main()
