"""Accuracy study: what does replacing exp with VEXP do to a model?

Mirrors the paper's Table II methodology at the forward-parity level
(no pretrained weights offline): exact-exp vs vexp on the same weights.

  PYTHONPATH=src python examples/accuracy_study.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.exp_accuracy import exp_relative_error, softmax_mse
from benchmarks.model_accuracy import parity_study


def main():
    print("=== exp approximation accuracy (paper §V-A) ===")
    for impl, e in exp_relative_error().items():
        print(f"  {impl:14s} mean {e['mean_rel']*100:.3f}%  "
              f"max {e['max_rel']*100:.3f}%   (paper: 0.14% / 0.78%)")
    print("\n=== softmax MSE (paper Table IV: 1.62e-9) ===")
    for impl, mse in softmax_mse().items():
        print(f"  {impl:14s} {mse:.3e}")
    print("\n=== model forward parity (paper Table II analogue) ===")
    for impl, m in parity_study().items():
        print(f"  {impl}: argmax agreement {m['argmax_agree_pct']:.2f}% "
              f"(random-init worst case), loss delta {m['loss_delta']:.5f} "
              f"on {m['loss_ref']:.3f}, mean KL {m['mean_kl']:.2e}")
    print("\nConclusion: parity within noise — matches the paper's "
          "'no retraining, <0.1% accuracy change'.")


if __name__ == "__main__":
    main()
