"""Long-context decode demo: the sequence-parallel flash-decode path.

Shows the paper's partial-softmax merge doing real distributed work: a KV
cache sharded along the *sequence* axis produces per-shard (m, l, acc)
partial softmax statistics that merge through an all-reduce — numerically
identical to replicated decode. Runs on 8 fake host devices.

  python examples/long_context_decode.py     (sets its own XLA_FLAGS)
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import api
from repro.distributed import sharding as shd


def _mesh_2x4():
    # AxisType landed after 0.4.x; older jax meshes are implicitly "auto".
    kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 2}
          if hasattr(jax.sharding, "AxisType") else {})
    return jax.make_mesh((2, 4), ("data", "model"), **kw)


def main():
    cfg = get_config("gpt2-small").reduced()
    b, s, smax = 1, 48, 64
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    _, cache = api.prefill(params, cfg, {"tokens": toks})
    ck = jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.hd),
                   jnp.bfloat16).at[:, :, :s].set(cache["k"])
    cv = jnp.zeros_like(ck).at[:, :, :s].set(cache["v"])
    cache = {"k": ck, "v": cv}
    tok = toks[:, -1:]
    f = lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos)
    ref, _ = jax.jit(f)(params, tok, cache, jnp.int32(s - 1))

    mesh = _mesh_2x4()
    with mesh:
        cs = {"k": P(None, None, "model", None, None),
              "v": P(None, None, "model", None, None)}
        cc = jax.device_put(cache, shd.named(mesh, cs))
        pp = jax.device_put(params,
                            shd.named(mesh, shd.param_specs(cfg, mesh)))
        out, _ = jax.jit(f)(pp, tok, cc, jnp.int32(s - 1))
    delta = float(jnp.abs(ref - out).max())
    print(f"[long-context] KV cache sharded over 'model' (seq axis), "
          f"batch=1 at 8 devices")
    print(f"[long-context] max |replicated - seq-parallel| logits delta: "
          f"{delta:.2e}")
    assert delta < 1e-2
    print("[long-context] sequence-parallel flash-decode == replicated  OK")


def fused_sharded_op_demo():
    """The same partial-softmax merge, explicitly: the Pallas kernel's
    partial-(m, l, acc) mode + psum merge under shard_map (what the
    GSPMD reduction above expresses implicitly), via the
    ``decode_attention_sharded`` dispatch entry."""
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_sharded)
    from repro.runtime import ExecPolicy

    pol = ExecPolicy(kernel_backend="pallas", block_s=512)
    b, h, hkv, d, smax = 1, 8, 4, 64, 4096
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, smax, hkv, d), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (b, smax, hkv, d), jnp.bfloat16)
    clen = jnp.array([3007], jnp.int32)
    single = decode_attention(q, kc, vc, clen, layout="bshd", policy=pol)
    mesh = _mesh_2x4()
    spec = NamedSharding(mesh, P(None, "model", None, None))
    with mesh:
        out = decode_attention_sharded(
            q, jax.device_put(kc, spec), jax.device_put(vc, spec), clen,
            mesh=mesh, layout="bshd", policy=pol)
    delta = float(jnp.abs(out - single).max())
    print(f"[long-context] fused shard_map decode (8-way seq-sharded "
          f"cache, S={smax}): max delta vs single-device {delta:.2e}")
    assert delta < 2e-3
    print("[long-context] partial-(m, l, acc) + psum merge == one-shot  OK")


if __name__ == "__main__":
    main()
    fused_sharded_op_demo()
