"""Long-context decode demo: the sequence-parallel flash-decode path.

Shows the paper's partial-softmax merge doing real distributed work: a KV
cache sharded along the *sequence* axis produces per-shard (m, l, acc)
partial softmax statistics that merge through an all-reduce — numerically
identical to replicated decode. Runs on 8 fake host devices.

  python examples/long_context_decode.py     (sets its own XLA_FLAGS)
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import api
from repro.distributed import sharding as shd


def main():
    cfg = get_config("gpt2-small").reduced()
    b, s, smax = 1, 48, 64
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    _, cache = api.prefill(params, cfg, {"tokens": toks})
    ck = jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.hd),
                   jnp.bfloat16).at[:, :, :s].set(cache["k"])
    cv = jnp.zeros_like(ck).at[:, :, :s].set(cache["v"])
    cache = {"k": ck, "v": cv}
    tok = toks[:, -1:]
    f = lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos)
    ref, _ = jax.jit(f)(params, tok, cache, jnp.int32(s - 1))

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with mesh:
        cs = {"k": P(None, None, "model", None, None),
              "v": P(None, None, "model", None, None)}
        cc = jax.device_put(cache, shd.named(mesh, cs))
        pp = jax.device_put(params,
                            shd.named(mesh, shd.param_specs(cfg, mesh)))
        out, _ = jax.jit(f)(pp, tok, cc, jnp.int32(s - 1))
    delta = float(jnp.abs(ref - out).max())
    print(f"[long-context] KV cache sharded over 'model' (seq axis), "
          f"batch=1 at 8 devices")
    print(f"[long-context] max |replicated - seq-parallel| logits delta: "
          f"{delta:.2e}")
    assert delta < 1e-2
    print("[long-context] sequence-parallel flash-decode == replicated  OK")


if __name__ == "__main__":
    main()
