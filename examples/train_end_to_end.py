"""End-to-end training driver: a GPT-2-family model on the structured
synthetic corpus, with checkpointing and resume.

Default is a ~20M-parameter model x 200 steps so it completes on this CPU
container in minutes; ``--full`` selects a ~110M GPT-2-small (the paper's
model) for a real multi-hour CPU run / minutes on accelerators.

  PYTHONPATH=src python examples/train_end_to_end.py [--steps 200] [--full]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro import optim
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="full GPT-2-small (~110M params)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    base = get_config("gpt2-small")
    if args.full:
        cfg = dataclasses.replace(base, remat=False)
    else:
        # ~20M params: 6 layers, d=384 (GPT-2 family, vexp everywhere)
        cfg = dataclasses.replace(
            base, n_layers=6, d_model=384, n_heads=6, n_kv_heads=6,
            head_dim=64, d_ff=1536, vocab=2048, remat=False,
            loss_chunk=128)
    n = cfg.n_params() / 1e6
    print(f"[example] {cfg.arch_id}: {n:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}, exp_impl={cfg.exp_impl}")
    opt_cfg = optim.OptConfig(lr=1e-3, total_steps=args.steps,
                              warmup_steps=max(10, args.steps // 20))
    params, hist = train(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, ckpt_dir=args.ckpt_dir,
                         ckpt_every=max(50, args.steps // 4),
                         opt_cfg=opt_cfg, data="structured")
    first, last = hist[0][1], hist[-1][1]
    print(f"[example] loss {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")


if __name__ == "__main__":
    main()
