"""Accuracy and semantics tests for the VEXP exponential approximation."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import vexp as V


class TestVexpF32:
    def test_paper_accuracy_envelope(self):
        """Paper §V-A: ~0.14% mean / 0.78% max relative error."""
        x = np.random.default_rng(0).uniform(-30, 10, 100000).astype(np.float32)
        y = np.asarray(V.vexp_f32(jnp.asarray(x)), np.float64)
        ref = np.exp(x.astype(np.float64))
        rel = np.abs(y - ref) / ref
        assert rel.mean() < 0.0025
        assert rel.max() < 0.01

    def test_exp_zero_is_one(self):
        assert float(V.vexp_f32(jnp.float32(0.0))) == 1.0

    def test_specials(self):
        x = jnp.asarray([np.inf, -np.inf, 1000.0, -1000.0], jnp.float32)
        y = np.asarray(V.vexp_f32(x))
        assert y[0] == np.inf and y[2] == np.inf
        assert y[1] == 0.0 and y[3] == 0.0
        assert np.isnan(float(V.vexp_f32(jnp.float32(np.nan))))

    def test_dtype_preserved(self):
        for dt in (jnp.float32, jnp.bfloat16):
            assert V.vexp_f32(jnp.ones((4,), dt)).dtype == dt

    def test_jit_and_grad_safe(self):
        f = jax.jit(lambda x: V.vexp_f32(x).sum())
        assert np.isfinite(float(f(jnp.linspace(-5, 5, 64))))

    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=-80.0, max_value=80.0, width=32))
    def test_property_relative_error(self, x):
        y = float(V.vexp_f32(jnp.float32(x)))
        ref = float(np.exp(np.float64(x)))
        assert abs(y - ref) <= 0.01 * ref + 1e-38

    @settings(max_examples=100, deadline=None)
    @given(st.floats(min_value=-50.0, max_value=50.0, width=32),
           st.floats(min_value=0.0, max_value=5.0, width=32))
    def test_property_monotone(self, x, d):
        """exp is monotone; the approximation must preserve ordering up to
        its relative error envelope (strict monotonicity holds across
        octave boundaries by construction)."""
        a = float(V.vexp_f32(jnp.float32(x)))
        b = float(V.vexp_f32(jnp.float32(x + d)))
        assert b >= a * (1 - 0.016)


class TestVexpHardwareModel:
    def test_paper_accuracy_envelope(self):
        x = np.random.default_rng(1).uniform(-30, 10, 50000).astype(np.float32)
        xb = jnp.asarray(x, jnp.bfloat16)
        y = np.asarray(V.vexp_bf16_fixedpoint(xb), np.float64)
        ref = np.exp(np.asarray(xb, np.float64))
        rel = np.abs(y - ref) / ref
        assert rel.mean() < 0.003   # paper: 0.14% (vs glibc, on their range)
        assert rel.max() < 0.01     # paper: 0.78%

    def test_matches_float_path_closely(self):
        """The deployable f32 path and the HW fixed-point model agree to
        BF16 resolution (<=1.6% = 2 bf16 ULPs)."""
        x = np.random.default_rng(2).uniform(-20, 5, 20000).astype(np.float32)
        xb = jnp.asarray(x, jnp.bfloat16)
        a = np.asarray(V.vexp_bf16_fixedpoint(xb), np.float64)
        b = np.asarray(V.vexp_bf16(xb), np.float64)
        rel = np.abs(a - b) / np.maximum(np.abs(b), 1e-38)
        assert rel.max() < 0.016

    def test_specials(self):
        xb = jnp.asarray([0.0, np.inf, -np.inf, 200.0, -200.0],
                         jnp.bfloat16)
        y = np.asarray(V.vexp_bf16_fixedpoint(xb), np.float32)
        assert y[0] == 1.0
        assert y[1] == np.inf and y[3] == np.inf
        assert y[2] == 0.0 and y[4] == 0.0
        nanv = V.vexp_bf16_fixedpoint(jnp.asarray([np.nan], jnp.bfloat16))
        assert np.isnan(np.asarray(nanv, np.float32))[0]

    def test_mse_vs_paper_table4(self):
        """Table IV reports MSE 1.62e-9; it compares *Softmax* accelerators,
        so we measure MSE of the softmax output computed with the HW exp
        model vs. the exact fp64 softmax."""
        rng = np.random.default_rng(3)
        x = rng.standard_normal((256, 512)).astype(np.float32) * 3.0
        xb = jnp.asarray(x, jnp.bfloat16)
        e = np.asarray(V.vexp_bf16_fixedpoint(
            xb - jnp.max(xb, -1, keepdims=True)), np.float64)
        sm = e / e.sum(-1, keepdims=True)
        xr = np.asarray(xb, np.float64)
        er = np.exp(xr - xr.max(-1, keepdims=True))
        ref = er / er.sum(-1, keepdims=True)
        mse = np.mean((sm - ref) ** 2)
        assert mse < 5e-9  # same order as the paper's 1.62e-9


def test_registry():
    assert V.get_exp_fn("exact") is V.exact_exp
    with pytest.raises(ValueError):
        V.get_exp_fn("nope")


class TestVexpGradients:
    def test_custom_jvp_matches_exp_derivative(self):
        """The bitcast reconstruction is non-differentiable; the custom
        JVP must supply d/dx vexp(x) = vexp(x) (zero grads here silently
        freeze attention training — regression test for that bug)."""
        x = jnp.asarray([-3.0, -1.0, 0.0, 1.0, 3.0], jnp.float32)
        g = jax.grad(lambda x: V.vexp_f32(x).sum())(x)
        np.testing.assert_allclose(np.asarray(g), np.exp(np.asarray(x)),
                                   rtol=0.01)

    def test_grad_zero_at_saturation(self):
        g = jax.grad(lambda x: V.vexp_f32(x).sum())(
            jnp.asarray([200.0, -200.0], jnp.float32))
        assert np.asarray(g)[0] == 0.0 and np.asarray(g)[1] == 0.0

    def test_attention_scores_receive_gradient(self):
        """End-to-end: grads must flow into the QK^T path (not only V)."""
        from repro.core.attention import attention_flash
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (1, 8, 2, 16))
        k = jax.random.normal(k2, (1, 8, 2, 16))
        v = jax.random.normal(k3, (1, 8, 2, 16))
        gq = jax.grad(lambda q: (attention_flash(
            q, k, v, exp_impl="vexp") ** 2).sum())(q)
        assert float(jnp.abs(gq).max()) > 1e-4
