"""Oracle tests for the recurrent families: the chunked/parallel forms must
match naive sequential recurrences, and decode must continue prefill."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
import repro.models.ssm as ssm
import repro.models.hybrid as hybrid
from repro.core.vexp import get_exp_fn


def _ssm_cfg(**kw):
    cfg = get_config("mamba2-1.3b").reduced()
    return dataclasses.replace(cfg, exp_impl="exact", **kw)


class TestSSDOracle:
    def test_chunked_equals_sequential(self):
        """Chunked SSD == per-step recurrence h = a h + dt B x."""
        cfg = _ssm_cfg(ssm_chunk=8)
        b, s = 2, 32
        p = ssm.ssm_layer_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                              jnp.float32) * 0.5
        y_chunked = ssm.ssm_layer_apply(x, p, cfg)

        # sequential oracle via the decode path
        di, nh, ds, ng, conv_dim = ssm.ssm_dims(cfg)
        state = {"h": jnp.zeros((b, nh, cfg.ssm_headdim, ds)),
                 "conv": jnp.zeros((b, cfg.conv_width - 1, conv_dim))}
        ys = []
        for t in range(s):
            y, state = ssm.ssm_layer_decode(x[:, t:t + 1], p, cfg, state)
            ys.append(y)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                                   atol=2e-3, rtol=2e-3)

    def test_chunk_size_invariance(self):
        cfg8, cfg16 = _ssm_cfg(ssm_chunk=8), _ssm_cfg(ssm_chunk=16)
        p = ssm.ssm_layer_init(jax.random.PRNGKey(2), cfg8)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg8.d_model),
                              jnp.float32)
        a = ssm.ssm_layer_apply(x, p, cfg8)
        b = ssm.ssm_layer_apply(x, p, cfg16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)

    def test_prefill_state_continues_decode(self):
        cfg = _ssm_cfg(ssm_chunk=8)
        p = ssm.ssm_layer_init(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 17, cfg.d_model),
                              jnp.float32) * 0.5
        # full pass over 17 steps == 16-step pass + 1 decode step
        y_full = ssm.ssm_layer_apply(
            jnp.pad(x, ((0, 0), (0, 7), (0, 0)))[:, :24], p,
            dataclasses.replace(cfg, ssm_chunk=8))
        _, st = ssm.ssm_layer_apply(x[:, :16], p, cfg, return_state=True)
        y_last, _ = ssm.ssm_layer_decode(x[:, 16:17], p, cfg, st)
        np.testing.assert_allclose(np.asarray(y_full[:, 16]),
                                   np.asarray(y_last[:, 0]),
                                   atol=2e-3, rtol=2e-3)


class TestRGLRUOracle:
    def test_assoc_scan_equals_sequential(self):
        cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                                  exp_impl="exact")
        p = hybrid.rec_layer_init(jax.random.PRNGKey(0), cfg)
        b, s, w = 2, 24, cfg.lru_width
        xw = jax.random.normal(jax.random.PRNGKey(1), (b, s, w),
                               jnp.float32) * 0.5
        y_par, h_last = hybrid._rg_lru(xw, p, cfg)

        exp_fn = get_exp_fn("exact")
        from repro.models.layers import vexp_sigmoid
        xf = xw
        r = vexp_sigmoid(xf @ p["w_rec_gate"], exp_fn)
        i = vexp_sigmoid(xf @ p["w_input_gate"], exp_fn)
        log_a = hybrid.RG_LRU_C * r * (-jnp.logaddexp(0.0, -p["lam"]))
        a = jnp.exp(log_a)
        bb = jnp.sqrt(jnp.maximum(1 - a ** 2, 0)) * (i * xf)
        h = jnp.zeros((b, w))
        hs = []
        for t in range(s):
            h = a[:, t] * h + bb[:, t]
            hs.append(h)
        y_seq = jnp.stack(hs, axis=1)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                                   atol=1e-4, rtol=1e-4)

    def test_initial_state_h0(self):
        cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                                  exp_impl="exact")
        p = hybrid.rec_layer_init(jax.random.PRNGKey(2), cfg)
        b, s, w = 1, 16, cfg.lru_width
        xw = jax.random.normal(jax.random.PRNGKey(3), (b, 2 * s, w)) * 0.5
        y_full, _ = hybrid._rg_lru(xw, p, cfg)
        _, h_mid = hybrid._rg_lru(xw[:, :s], p, cfg)
        y_tail, _ = hybrid._rg_lru(xw[:, s:], p, cfg, h0=h_mid)
        np.testing.assert_allclose(np.asarray(y_full[:, s:]),
                                   np.asarray(y_tail),
                                   atol=1e-4, rtol=1e-4)
