"""Paged-KV serving tests (ISSUE 6 tentpole).

The contract: a server whose KV lives in fixed-size pool pages behind
per-slot block tables — with a refcounted allocator and a shared-prefix
page cache on top — must emit exactly the greedy tokens of contiguous
per-slot serving, under every exp backend, both cache layouts, sliding
windows, the hybrid family, and the sequence-sharded decode path. The
paged pallas sweep itself is checked against its gather-then-reduce
oracle, and the prefix cache must amortize (hot attach) without ever
changing tokens — including mid-decode admission into a hot prefix and
eviction under pool pressure."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.launch.serve import Server, Request
from repro.runtime import resolve_policy

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")


@pytest.fixture(scope="module")
def cfg():
    return get_config("gpt2-small").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return api.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0, prefix=None):
    rng = np.random.default_rng(seed)
    out = []
    for n in lens:
        p = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        if prefix is not None:
            p[:len(prefix)] = prefix
        out.append(p)
    return out


def _serve(cfg, params, prompts, *, paged, max_new=5, max_batch=2,
           max_seq=64, policy=None, **kw):
    srv = Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                 policy=policy, paged=paged, **kw)
    reqs = [Request(i, p.copy(), max_new) for i, p in enumerate(prompts)]
    srv.run(reqs)
    return {r.rid: r.out for r in reqs}, srv


# --------------------------------------------------------- kernel vs oracle

class TestPagedKernelOracle:
    @pytest.mark.parametrize("layout", ["bshd", "bhsd"])
    def test_paged_sweep_matches_gather_oracle(self, layout):
        """The pallas paged sweep (block tables drive the page DMA via
        scalar prefetch) == gather-to-contiguous + core reduction, with
        ragged per-row lengths and a shuffled, alias-free table."""
        from repro.kernels.decode_attention.ops import (
            decode_attention_paged, paged_gather)
        from repro.core.attention import decode_attention
        b, h, hkv, d, page, ns = 3, 8, 4, 32, 16, 4
        n_pages = 1 + b * ns
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        shape = ((n_pages, hkv, page, d) if layout == "bhsd"
                 else (n_pages, page, hkv, d))
        kp = jax.random.normal(ks[1], shape, jnp.float32)
        vp = jax.random.normal(ks[2], shape, jnp.float32)
        rng = np.random.default_rng(0)
        tab = rng.permutation(np.arange(1, n_pages))[:b * ns]
        tab = jnp.asarray(tab.reshape(b, ns), jnp.int32)
        clen = jnp.array([1, page * 2 + 3, page * ns], jnp.int32)
        got = decode_attention_paged(q, kp, vp, tab, clen, layout=layout,
                                     interpret=True)
        ref = decode_attention(q, paged_gather(kp, tab, layout),
                               paged_gather(vp, tab, layout), clen,
                               layout=layout)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-3)


# -------------------------------------------------------- serving identity

class TestPagedIdentity:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_paged_matches_contiguous(self, cfg, params, exp):
        """Paged serving (slot churn, ragged lengths, 2-slot pool over 4
        requests) is token-identical to contiguous serving under every
        exp backend."""
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        prompts = _prompts(cfg, (5, 11, 7, 20))
        ref, _ = _serve(cfg, params, prompts, paged=False, policy=pol)
        got, srv = _serve(cfg, params, prompts, paged=True, policy=pol,
                          block_page=8)
        assert ref == got
        # drained: only the prefix cache's own references remain resident
        pool = srv.stats()["default"]["pool"]
        assert pool["pages_used"] == pool["prefix"]["pages"]

    def test_paged_matches_contiguous_bhsd(self, cfg, params):
        """Head-major (bhsd) pool layout: same identity."""
        from dataclasses import replace
        c = replace(cfg, kv_cache_layout="bhsd")
        prompts = _prompts(c, (5, 11, 7))
        ref, _ = _serve(c, params, prompts, paged=False)
        got, _ = _serve(c, params, prompts, paged=True, block_page=8)
        assert ref == got

    def test_paged_matches_contiguous_pallas(self, cfg, params):
        """The pallas-backend route (paged flash sweep inside the jitted
        decode step) agrees with pallas contiguous serving."""
        pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
        prompts = _prompts(cfg, (5, 11, 7))
        ref, _ = _serve(cfg, params, prompts, paged=False, policy=pol)
        got, _ = _serve(cfg, params, prompts, paged=True, policy=pol,
                        block_page=8)
        assert ref == got

    def test_windowed_ring_paged(self, params):
        """Sliding-window archs page the ring buffer (fixed table, wrap
        by write column): identical tokens, including post-wrap decode."""
        c = get_config("h2o-danube3-4b").reduced()   # window = 16
        p = api.init_params(c, jax.random.PRNGKey(1))
        prompts = _prompts(c, (3, 9, 13), seed=2)
        ref, _ = _serve(c, p, prompts, paged=False, max_new=12, max_seq=64)
        got, _ = _serve(c, p, prompts, paged=True, max_new=12, max_seq=64,
                        block_page=8)
        assert ref == got

    def test_hybrid_paged(self):
        """Hybrid family: KV periods page, recurrent rows stay per-slot."""
        c = get_config("recurrentgemma-9b").reduced()
        p = api.init_params(c, jax.random.PRNGKey(1))
        prompts = _prompts(c, (3, 9, 13), seed=2)
        ref, _ = _serve(c, p, prompts, paged=False, max_new=10, max_seq=64)
        got, _ = _serve(c, p, prompts, paged=True, max_new=10, max_seq=64,
                        block_page=8)
        assert ref == got


# ----------------------------------------------------------- prefix cache

class TestSharedPrefix:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_hot_prefix_matches_cold_solo(self, cfg, params, exp):
        """A request admitted onto a HOT shared prefix (its first pages
        attach to cached pages; only the suffix is prefilled) emits
        exactly the tokens it gets served cold and alone."""
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab, (16,), dtype=np.int32)
        a, b = _prompts(cfg, (24, 30), seed=6, prefix=prefix)
        cold, _ = _serve(cfg, params, [b], paged=True, policy=pol,
                         block_page=4)
        srv = Server(cfg, params, max_batch=1, max_seq=64, policy=pol,
                     paged=True, block_page=4)
        ra, rb = Request(0, a.copy(), 5), Request(1, b.copy(), 5)
        srv.run([ra, rb])              # a seeds the cache, b rides it hot
        pool = srv.stats()["default"]["pool"]
        assert pool["prefix"]["hits"] >= 4     # 16-token prefix, page 4
        assert rb.out == cold[0]

    def test_mid_decode_admission_into_hot_prefix(self, cfg, params):
        """Continuous batching: a slot freed mid-decode readmits a queued
        request whose prefix is hot in the cache — tokens must match the
        contiguous server's (which shares nothing)."""
        rng = np.random.default_rng(9)
        prefix = rng.integers(0, cfg.vocab, (12,), dtype=np.int32)
        prompts = _prompts(cfg, (20, 14, 26, 18, 22), seed=7, prefix=prefix)
        ref, _ = _serve(cfg, params, prompts, paged=False, max_batch=2,
                        max_new=4)
        got, srv = _serve(cfg, params, prompts, paged=True, max_batch=2,
                          max_new=4, block_page=4)
        assert ref == got
        assert srv.stats()["default"]["pool"]["prefix"]["hits"] > 0

    def test_eviction_under_pressure_keeps_identity(self, cfg, params):
        """A pool too small to cache every chain forces LRU evictions
        between waves; admission blocks until pages free up, tokens never
        change, and live state survives (only cache refs are evicted)."""
        prompts = _prompts(cfg, (30, 28, 26, 31, 29), seed=8)
        ref, _ = _serve(cfg, params, prompts, paged=False, max_batch=2,
                        max_new=4)
        # budget: 2 slots' full reservation + 1 spare + scratch -> the
        # published chains cannot all stay resident
        got, srv = _serve(cfg, params, prompts, paged=True, max_batch=2,
                          max_new=4, block_page=4, block_budget=2 * 8 + 2)
        assert ref == got
        pool = srv.stats()["default"]["pool"]
        assert pool["prefix"]["evictions"] > 0
        assert pool["pages_used"] <= pool["pages_allocatable"]

    def test_hot_wave_does_not_double_count_evictable(self, cfg, params):
        """A wave of requests hitting the same cache-only (refcount-1)
        prefix must not count those pages BOTH as prefix hits (no fresh
        page needed) and as evictable supply: attach pins them, so the
        old gate admitted waves the pool cannot hold and alloc raised
        OutOfBlocks mid-prefill. The gate now debits pinned pages, the
        wave splits, and tokens stay identical to contiguous serving."""
        rng = np.random.default_rng(21)
        prefix = rng.integers(0, cfg.vocab, (8,), dtype=np.int32)
        prompts = _prompts(cfg, (10, 10, 10), seed=22, prefix=prefix)

        def serve(paged, **kw):
            srv = Server(cfg, params, max_batch=2, max_seq=64, paged=paged,
                         **kw)
            reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
            srv.run([reqs[0]])         # seeds the prefix page (refcount 1)
            srv.run(reqs[1:])          # B+C both hit it in one wave
            return {r.rid: r.out for r in reqs}, srv

        ref, _ = serve(False)
        # budget: free(13) + evictable(1) + scratch(1). The buggy gate
        # admits B and C together (2*7 fresh <= 13+1), attach pins the
        # hit page, and C's 7-page alloc finds only 6 free -> crash.
        got, srv = serve(True, block_page=8, block_budget=15)
        assert got == ref
        assert srv.stats()["default"]["pool"]["prefix"]["hits"] >= 2
        assert srv.admit_log == [0, 1, 2]

    def test_short_attach_degrades_wave_depth(self, cfg, params,
                                              monkeypatch):
        """If a probed chain page vanishes before attach can pin it (the
        probe->attach window), the wave degrades to the depth every row
        actually holds — surplus attach refs released, no assert, tokens
        identical to contiguous serving."""
        rng = np.random.default_rng(31)
        prefix = rng.integers(0, cfg.vocab, (16,), dtype=np.int32)
        prompts = _prompts(cfg, (20, 20, 20), seed=32, prefix=prefix)

        def reqs():
            return [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]

        srv_ref = Server(cfg, params, max_batch=2, max_seq=64)
        rr = reqs()
        srv_ref.run([rr[0]])
        srv_ref.run(rr[1:])
        ref = {r.rid: r.out for r in rr}

        srv = Server(cfg, params, max_batch=2, max_seq=64, paged=True,
                     block_page=8)
        state = srv._groups["default"].state
        rp = reqs()
        srv.run([rp[0]])               # seed: 2 full prefix pages cached
        orig, calls = state.pcache.attach, {"n": 0}

        def short_attach(tokens, max_pages=None):
            got = orig(tokens, max_pages=max_pages)
            calls["n"] += 1
            if calls["n"] == 2 and len(got) > 1:   # 2nd row comes up short
                state.alloc.decref(got[-1])
                got = got[:-1]
            return got

        monkeypatch.setattr(state.pcache, "attach", short_attach)
        srv.run(rp[1:])                # B attaches 2 pages, C only 1
        assert calls["n"] == 2
        assert {r.rid: r.out for r in rp} == ref

    def test_prefill_outofblocks_requeues_wave(self, cfg, params,
                                               monkeypatch):
        """Backstop: an OutOfBlocks escaping prefill must not crash the
        engine while other requests are in flight — the wave re-queues
        (FIFO preserved) and admits once pages free up."""
        from repro.models.block_pool import OutOfBlocks
        prompts = _prompts(cfg, (10, 20), seed=23)   # distinct buckets
        ref, _ = _serve(cfg, params, prompts, paged=False, max_new=6)
        srv = Server(cfg, params, max_batch=2, max_seq=64, paged=True,
                     block_page=8)
        g = srv._groups["default"]
        orig, calls = g.state.prefill_into, {"n": 0}

        def flaky(*a, **kw):
            calls["n"] += 1
            if calls["n"] == 2:        # the 2nd wave's first attempt
                raise OutOfBlocks("injected")
            return orig(*a, **kw)

        monkeypatch.setattr(g.state, "prefill_into", flaky)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        srv.run(reqs)
        assert calls["n"] >= 3         # failed attempt retried
        assert {r.rid: r.out for r in reqs} == ref
        assert srv.admit_log == [0, 1]

    def test_prefix_cache_off_still_serves(self, cfg, params):
        prompts = _prompts(cfg, (24, 24), seed=11)
        ref, _ = _serve(cfg, params, prompts, paged=False)
        got, srv = _serve(cfg, params, prompts, paged=True, block_page=4,
                          prefix_cache=False)
        assert ref == got
        assert "prefix" not in srv.stats()["default"]["pool"]


# ------------------------------------------------------- splittable waves

class TestSplittableAdmission:
    def test_long_prompt_does_not_inflate_wave(self, cfg, params):
        """The wave bucket is the HEAD request's: a longer-bucket request
        queued behind a short head closes the wave and heads the next one
        at its own bucket — no padded co-prefill at the long bucket, no
        overtaking (admission order stays strictly FIFO), and tokens
        still match a run that never waved them together."""
        prompts = _prompts(cfg, (5, 40, 6), seed=12)
        ref, _ = _serve(cfg, params, prompts, paged=False, max_batch=1)
        got, srv = _serve(cfg, params, prompts, paged=False, max_batch=2)
        assert got == ref
        assert srv.admit_log == [0, 1, 2]
        # the long request (idx 1) must not ride the short head's wave:
        # three requests -> three single-request admission waves (a
        # max-width wave would have co-prefilled [0, 1] in one)
        assert len(srv._groups["default"].admit_s) == 3

    def test_admission_blocks_on_pool_budget(self, cfg, params):
        """Paged: a wave only admits what the free+evictable page budget
        affords; the rest queues (no OutOfBlocks mid-serve)."""
        prompts = _prompts(cfg, (10, 10, 10, 10), seed=13)
        # 1 reservation (8 pages) + scratch: strictly one slot at a time
        got, srv = _serve(cfg, params, prompts, paged=True, max_batch=2,
                          block_page=8, block_budget=9)
        ref, _ = _serve(cfg, params, prompts, paged=False, max_batch=2)
        assert got == ref
        assert srv._groups["default"].peak_pages <= 8


# --------------------------------------------------------- sharded paged

def _run_sub(body: str) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prelude = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_AUTOTUNE_CACHE"] = "off"
    import sys
    sys.path.insert(0, {os.path.abspath(src)!r})
    import json
    import numpy as np
    import jax
    """)
    script = prelude + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardedPaged:
    def test_sharded_paged_token_identity(self):
        """Sequence-sharded paged serving (block tables shard with the
        pool's page axis; per-shard free lists) == unsharded contiguous
        serving, with shared-prefix traffic in the mix."""
        res = _run_sub("""
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.serve import Server, Request
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import resolve_policy
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prefix = rng.integers(0, cfg.vocab, (16,), dtype=np.int32)
        prompts = []
        for n in (5, 20, 24, 30):
            p = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
            if n >= 20:
                p[:16] = prefix
            prompts.append(p)
        def serve(mesh, kv_mode, paged):
            pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
            srv = Server(cfg, params, max_batch=2, max_seq=64, mesh=mesh,
                         policy=pol, kv_mode=kv_mode, paged=paged,
                         block_page=8)
            reqs = [Request(i, p.copy(), 5) for i, p in enumerate(prompts)]
            srv.run(reqs)
            return {r.rid: r.out for r in reqs}, srv
        plain, _ = serve(make_host_mesh(1, 1), "auto", False)
        shard, srv = serve(make_host_mesh(1, 8), "seq", True)
        pool = srv.stats()["default"]["pool"]
        print(json.dumps({"kv_axis": srv.kv_axis,
                          "identical": plain == shard,
                          "hits": pool["prefix"]["hits"]}))
        """)
        assert res["kv_axis"] == "model", "paged engine did not shard"
        assert res["identical"], "sharded paged tokens diverged"
        assert res["hits"] > 0
