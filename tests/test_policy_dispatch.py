"""Execution-policy layer tests: resolution precedence, dispatch table,
autotune caching, and cross-backend numerical consistency.

The accuracy tests pin the paper's envelope: all three exp backends must
produce softmax rows within ~0.78% max relative error of the exact
transcendental (Table IV's bound, plus BF16 input quantization for the
hardware model).
"""

import glob
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.runtime import ExecPolicy, resolve_policy, ENV_PREFIX
from repro.kernels import dispatch as kd
from repro.configs import get_config


class TestPolicyResolution:
    def test_defaults(self):
        p = resolve_policy(env={})
        assert p.exp_backend == "vexp"
        assert p.kernel_backend == "pallas"

    def test_config_fields_flow_in(self):
        cfg = get_config("gpt2-small")
        p = resolve_policy(cfg, env={})
        assert p.exp_backend == cfg.exp_impl
        # attention_impl "flash" maps to the reference backend
        assert p.kernel_backend == "reference"
        assert p.block_k == cfg.attn_block_k

    def test_env_overrides_config(self):
        cfg = get_config("gpt2-small")
        env = {ENV_PREFIX + "EXP_BACKEND": "exact",
               ENV_PREFIX + "KERNEL_BACKEND": "xla",
               ENV_PREFIX + "BLOCK_Q": "256",
               ENV_PREFIX + "AUTOTUNE": "1"}
        p = resolve_policy(cfg, env=env)
        assert p.exp_backend == "exact"
        assert p.kernel_backend == "xla"
        assert p.block_q == 256
        assert p.autotune is True

    def test_call_overrides_beat_env(self):
        env = {ENV_PREFIX + "EXP_BACKEND": "exact"}
        p = resolve_policy(env=env, exp_backend="vexp_hw")
        assert p.exp_backend == "vexp_hw"

    def test_process_env_is_read(self, monkeypatch):
        monkeypatch.setenv(ENV_PREFIX + "EXP_BACKEND", "vexp_hw")
        assert resolve_policy().exp_backend == "vexp_hw"

    def test_invalid_values_raise(self):
        with pytest.raises(ValueError):
            ExecPolicy(exp_backend="fast_but_wrong")
        with pytest.raises(ValueError):
            ExecPolicy(kernel_backend="cuda")
        with pytest.raises(ValueError):
            ExecPolicy(block_q=0)
        with pytest.raises(ValueError):
            resolve_policy(env={ENV_PREFIX + "BLOCK_K": "huge"})
        with pytest.raises(ValueError):
            resolve_policy(not_a_field=1)

    def test_merge_strategy_field(self):
        """ISSUE 4: the collective merge strategy is a first-class policy
        field — defaulted to the packed single-collective form, settable
        from the environment, validated, and part of the hash/jit key."""
        assert ExecPolicy().merge_strategy == "packed"
        p = resolve_policy(env={ENV_PREFIX + "MERGE_STRATEGY": "split"})
        assert p.merge_strategy == "split"
        with pytest.raises(ValueError):
            ExecPolicy(merge_strategy="psum_of_vibes")
        assert ExecPolicy() != ExecPolicy(merge_strategy="split")
        assert "merge=packed" in ExecPolicy().describe()

    def test_sharded_autotune_candidates_cover_both_strategies(self):
        cands = kd.CANDIDATES["decode_attention_sharded"]
        assert {c["merge_strategy"] for c in cands} == {"packed", "split"}

    def test_hashable_static_arg(self):
        # policies must be usable as static jit args (jit caches per policy)
        a = ExecPolicy(exp_backend="vexp")
        b = ExecPolicy(exp_backend="vexp")
        assert hash(a) == hash(b) and a == b
        assert a != a.replace(exp_backend="exact")

    def test_config_projection_roundtrip(self):
        cfg = get_config("gpt2-small")
        p = ExecPolicy(exp_backend="vexp_hw", kernel_backend="pallas",
                       block_q=64, block_k=64)
        cfg2 = cfg.with_policy(p)
        assert cfg2.exp_impl == "vexp_hw"
        assert cfg2.attention_impl == "pallas"
        # resolving the projected config reproduces the policy fields
        p2 = resolve_policy(cfg2, env={})
        assert p2.exp_backend == p.exp_backend
        assert p2.kernel_backend == p.kernel_backend


class TestDispatch:
    def test_table_covers_all_ops_and_backends(self):
        for op in kd.OPS:
            for kb in ("pallas", "reference", "xla"):
                fn = kd.dispatch(op, ExecPolicy(kernel_backend=kb))
                assert callable(fn), (op, kb)

    def test_unknown_op_raises(self):
        with pytest.raises(ValueError):
            kd.dispatch("conv3d", ExecPolicy())

    def test_exp_callable_resolution(self):
        """The recurrent-gate exp resolution: policy.exp_backend wins,
        the legacy exp_impl string is the fallback — so --policy-groups
        flips RG-LRU / SSD gate numerics like softmax numerics."""
        from repro.core.vexp import EXP_FNS
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = ExecPolicy(exp_backend=exp)
            assert kd.exp_callable(pol) is EXP_FNS[exp]
            # policy beats the legacy string
            assert kd.exp_callable(pol, "exact") is EXP_FNS[exp]
        assert kd.exp_callable(None, "vexp_hw") is EXP_FNS["vexp_hw"]
        with pytest.raises(ValueError):
            kd.exp_callable(None, "nope")

    def test_no_hardcoded_exp_in_kernels(self):
        """Acceptance guard: no kernel body may pin vexp_f32 — the exp
        backend must arrive via the policy/registry."""
        root = os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro", "kernels")
        for path in glob.glob(os.path.join(root, "*", "kernel.py")):
            src = open(path).read()
            assert "vexp_f32" not in src, f"hardcoded exp in {path}"

    def test_softmax_backends_agree_within_envelope(self):
        """exact vs vexp vs vexp_hw softmax rows within the paper's ~0.78%
        max-relative-error envelope (relative to the row max probability,
        which is how exp error propagates through the normalization)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 4
        from repro.core.softmax import softmax
        outs = {}
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas")
            outs[exp] = np.asarray(softmax(x, policy=pol), np.float64)
            np.testing.assert_allclose(outs[exp].sum(-1), 1.0, atol=1e-3)
        ref = outs["exact"]
        rowmax = ref.max(-1, keepdims=True)
        for exp in ("vexp", "vexp_hw"):
            rel = np.abs(outs[exp] - ref) / rowmax
            assert rel.max() < 0.0078 * 2, \
                f"{exp}: rel err {rel.max():.4f} beyond envelope"

    def test_kernel_backends_agree_per_exp(self):
        """For a fixed exp backend, all three kernel backends compute the
        same function (same math, different execution)."""
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 384)) * 6
        from repro.core.softmax import softmax
        for exp in ("exact", "vexp", "vexp_hw"):
            outs = [np.asarray(softmax(
                x, policy=ExecPolicy(exp_backend=exp, kernel_backend=kb)))
                for kb in ("pallas", "reference", "xla")]
            np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)
            np.testing.assert_allclose(outs[0], outs[2], atol=1e-6)

    def test_flash_attention_policy_switch(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        from repro.kernels.flash_attention.ref import attention_exact_ref
        ref = np.asarray(attention_exact_ref(q, k, v, causal=True))
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                             block_q=64, block_k=64)
            out = kd.dispatch("flash_attention", pol)(
                q, k, v, causal=True, policy=pol)
            np.testing.assert_allclose(np.asarray(out), ref, atol=6e-3)

    def test_decode_attention_policy(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 1, 4, 64))
        kc = jax.random.normal(ks[1], (2, 2, 128, 64))
        vc = jax.random.normal(ks[2], (2, 2, 128, 64))
        from repro.core.attention import decode_attention
        ref = np.asarray(decode_attention(q, kc, vc, 100, exp_impl="vexp",
                                          layout="bhsd"))
        pol = ExecPolicy(exp_backend="vexp", kernel_backend="pallas",
                         block_s=64)
        out = kd.dispatch("decode_attention", pol)(
            q, kc, vc, 100, layout="bhsd", policy=pol)
        np.testing.assert_allclose(np.asarray(out), ref, atol=2e-3)


class TestAutotune:
    def test_repeated_shape_hits_cache(self):
        kd.autotune_cache_clear()
        x = jax.random.normal(jax.random.PRNGKey(4), (64, 256))
        pol = ExecPolicy(kernel_backend="pallas", autotune=True)
        sm = kd.dispatch("softmax", pol)
        sm(x, policy=pol)
        stats = kd.autotune_cache_stats()
        assert stats["misses"] == 1
        sm(x, policy=pol)
        stats = kd.autotune_cache_stats()
        assert stats["misses"] == 1, "repeated shape re-timed"
        assert stats["hits"] == 1

    def test_shape_buckets(self):
        kd.autotune_cache_clear()
        pol = ExecPolicy(kernel_backend="pallas", autotune=True)
        sm = kd.dispatch("softmax", pol)
        # 200 and 250 rows bucket to the same pow2 (256): one miss total
        sm(jax.random.normal(jax.random.PRNGKey(5), (200, 256)), policy=pol)
        sm(jax.random.normal(jax.random.PRNGKey(6), (250, 256)), policy=pol)
        assert kd.autotune_cache_stats()["misses"] == 1
        # 300 rows buckets to 512: a new miss
        sm(jax.random.normal(jax.random.PRNGKey(7), (300, 256)), policy=pol)
        assert kd.autotune_cache_stats()["misses"] == 2

    def test_no_timing_under_jit_trace(self):
        """Inside an outer jit trace wall-clock timing is meaningless
        (tracers, not device work): the tuner must not time or pollute
        the cache, only reuse an eagerly-tuned winner if one exists."""
        kd.autotune_cache_clear()
        pol = ExecPolicy(kernel_backend="pallas", autotune=True)
        sm = kd.dispatch("softmax", pol)
        x = jax.random.normal(jax.random.PRNGKey(9), (64, 256))
        traced = jax.jit(lambda x: sm(x, policy=pol))(x)
        assert kd.autotune_cache_stats()["misses"] == 0
        # eager tune, then the jitted path picks up the cached winner
        sm(x, policy=pol)
        assert kd.autotune_cache_stats()["misses"] == 1
        jax.jit(lambda x: sm(x + 1.0, policy=pol))(x)
        stats = kd.autotune_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] >= 1
        np.testing.assert_allclose(
            np.asarray(traced),
            np.asarray(sm(x, policy=pol.replace(autotune=False))),
            atol=1e-6)

    def test_autotuned_result_matches_untuned(self):
        kd.autotune_cache_clear()
        x = jax.random.normal(jax.random.PRNGKey(8), (96, 256)) * 3
        base = ExecPolicy(kernel_backend="pallas")
        tuned = base.replace(autotune=True)
        sm = kd.dispatch("softmax", base)
        np.testing.assert_allclose(
            np.asarray(sm(x, policy=tuned)),
            np.asarray(sm(x, policy=base)), atol=1e-6)


class TestAutotunePersistence:
    """The block-size cache persists to disk keyed by (device_kind, op,
    shape_bucket, policy): a fresh process (simulated by clearing the
    in-memory cache) must reuse the winners without re-timing."""

    def test_save_load_roundtrip_skips_retiming(self, tmp_path, monkeypatch):
        path = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        kd.autotune_cache_clear()
        x = jax.random.normal(jax.random.PRNGKey(21), (64, 256))
        pol = ExecPolicy(kernel_backend="pallas", autotune=True)
        sm = kd.dispatch("softmax", pol)
        sm(x, policy=pol)
        assert kd.autotune_cache_stats()["misses"] == 1
        assert os.path.exists(path), "tuning winner was not persisted"
        # "restart": drop all in-process state; the disk entry must turn
        # the first lookup into a hit instead of a timing pass.
        kd.autotune_cache_clear()
        sm(x, policy=pol)
        stats = kd.autotune_cache_stats()
        assert stats["misses"] == 0, "disk-cached shape was re-timed"
        assert stats["hits"] == 1
        assert stats["disk_loaded"] >= 1

    def test_disabled_by_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "off")
        kd.autotune_cache_clear()
        assert kd.autotune_cache_path() is None
        x = jax.random.normal(jax.random.PRNGKey(22), (64, 256))
        pol = ExecPolicy(kernel_backend="pallas", autotune=True)
        kd.dispatch("softmax", pol)(x, policy=pol)
        kd.autotune_cache_clear()
        kd.dispatch("softmax", pol)(x, policy=pol)
        assert kd.autotune_cache_stats()["misses"] == 1, \
            "persistence leaked through REPRO_AUTOTUNE_CACHE=off"

    def test_corrupt_cache_file_ignored(self, tmp_path, monkeypatch):
        path = str(tmp_path / "autotune.json")
        with open(path, "w") as fh:
            fh.write("{not json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        kd.autotune_cache_clear()
        assert kd.load_autotune_cache() == 0

    def test_concurrent_save_merges_not_clobbers(self, tmp_path,
                                                 monkeypatch):
        """Two serve processes racing the JSON: a save must fold in the
        entries a concurrent process persisted after our last read —
        last-writer-wins would silently drop the other engine's winners —
        and our own timing of the same key must take precedence."""
        import json
        path = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        kd.autotune_cache_clear()
        kd._AUTOTUNE_CACHE["ours"] = {"block_s": 256}
        kd._AUTOTUNE_CACHE["shared"] = {"block_s": 512}
        # "process B" wrote between our load and our save
        with open(path, "w") as fh:
            json.dump({"version": 1,
                       "entries": {"theirs": {"block_rows": 128},
                                   "shared": {"block_s": 1024}}}, fh)
        assert kd.save_autotune_cache() == path
        with open(path) as fh:
            entries = json.load(fh)["entries"]
        assert entries["ours"] == {"block_s": 256}
        assert entries["theirs"] == {"block_rows": 128}   # merged, not lost
        assert entries["shared"] == {"block_s": 512}      # in-process wins
        assert not [f for f in os.listdir(str(tmp_path))
                    if f.startswith(".autotune-")], "tmp file leaked"
        kd.autotune_cache_clear()

    def test_save_is_atomic_rename(self, tmp_path, monkeypatch):
        """A reader must never observe a torn file: the write lands via a
        same-directory tempfile + os.replace (asserted on the source — a
        behavioural check would need fault injection)."""
        import inspect
        src = inspect.getsource(kd.save_autotune_cache)
        assert "mkstemp" in src and "os.replace" in src
        # and a corrupt concurrent file must not break saving
        path = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        kd.autotune_cache_clear()
        kd._AUTOTUNE_CACHE["k"] = {"block_s": 256}
        with open(path, "w") as fh:
            fh.write("{torn write from a dying process")
        assert kd.save_autotune_cache() == path
        import json
        with open(path) as fh:
            assert json.load(fh)["entries"] == {"k": {"block_s": 256}}
        kd.autotune_cache_clear()


class TestAccumDtype:
    """accum_dtype is honored by the Pallas kernels (scratch statistics)
    and rejected wherever no kernel would honor it."""

    def test_rejected_on_non_pallas_backends(self):
        for kb in ("reference", "xla"):
            with pytest.raises(ValueError, match="accum_dtype"):
                ExecPolicy(kernel_backend=kb, accum_dtype="bfloat16")
        with pytest.raises(ValueError, match="accum_dtype"):
            resolve_policy(env={}, kernel_backend="xla",
                           accum_dtype="bfloat16")

    def test_unknown_value_rejected(self):
        with pytest.raises(ValueError, match="accum_dtype"):
            ExecPolicy(accum_dtype="float16")

    def test_flash_attention_bf16_accum_distinct_but_close(self):
        ks = jax.random.split(jax.random.PRNGKey(23), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32))
        k = jax.random.normal(ks[1], (1, 64, 2, 32))
        v = jax.random.normal(ks[2], (1, 64, 2, 32))
        from repro.kernels.flash_attention.ops import flash_attention_policy
        f32 = flash_attention_policy(
            q, k, v, causal=True,
            policy=ExecPolicy(kernel_backend="pallas", block_q=32,
                              block_k=32))
        bf16 = flash_attention_policy(
            q, k, v, causal=True,
            policy=ExecPolicy(kernel_backend="pallas", block_q=32,
                              block_k=32, accum_dtype="bfloat16"))
        assert not np.array_equal(np.asarray(f32), np.asarray(bf16)), \
            "accum_dtype=bfloat16 compiled an identical program"
        np.testing.assert_allclose(np.asarray(bf16), np.asarray(f32),
                                   atol=5e-2, rtol=5e-2)


class TestEndToEnd:
    def test_model_forward_policy_flip(self):
        """One ExecPolicy switch flips the exp backend through the whole
        model: forward logits differ between exact and vexp policies but
        stay close (the envelope), and each policy is deterministic."""
        from repro.models import api
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        losses = {}
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = resolve_policy(cfg, env={}, exp_backend=exp)
            losses[exp] = float(api.loss_fn(params, cfg, batch, policy=pol))
        assert losses["exact"] != losses["vexp"]   # backend really flipped
        for exp in ("vexp", "vexp_hw"):
            assert abs(losses[exp] - losses["exact"]) < 0.05, losses

    def test_serve_runs_under_all_policies(self):
        from repro.launch.serve import Server, Request
        from repro.models import api
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = resolve_policy(cfg, env={}, exp_backend=exp,
                                 kernel_backend="pallas")
            server = Server(cfg, params, policy=pol)
            reqs = [Request(0, rng.integers(0, cfg.vocab, (8,),
                                            dtype=np.int32), max_new=2)]
            out = server.run(reqs)
            assert len(out[0].out) == 2
