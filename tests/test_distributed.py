"""Distribution tests.

In-process: sharding rules produce valid NamedShardings for every arch.
Sub-process (8 fake host devices, set via XLA_FLAGS before jax imports):
sharded train-step/decode numerically match single-device execution, and
the sequence-parallel (KV-sharded) decode path agrees with the replicated
one — the SPMD partial-softmax merge is exercised for real.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest
import jax

from repro.configs import REGISTRY, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


class TestShardingRules:
    @pytest.mark.parametrize("arch", sorted(REGISTRY))
    def test_param_specs_match_structure(self, arch):
        cfg = get_config(arch)
        mesh = make_host_mesh()
        specs = shd.param_specs(cfg, mesh, fsdp=False)
        import jax.numpy as jnp
        shapes = jax.eval_shape(
            lambda: __import__("repro.models.api", fromlist=["api"])
            .init_params(cfg, jax.random.PRNGKey(0)))
        # structures must match exactly
        assert (jax.tree_util.tree_structure(specs)
                == jax.tree_util.tree_structure(
                    jax.tree.map(lambda _: 0, shapes)))
        # every spec must be applicable (rank <= leaf rank)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        flat_l = jax.tree.leaves(shapes)
        for sp, leaf in zip(flat_s, flat_l):
            assert len(sp) <= leaf.ndim, f"{arch}: spec {sp} rank > {leaf.shape}"

    @pytest.mark.parametrize("arch", ["command-r-35b", "grok-1-314b"])
    def test_fsdp_augments(self, arch):
        cfg = get_config(arch)
        mesh = make_host_mesh()
        plain = shd.param_specs(cfg, mesh, fsdp=False)
        fsdp = shd.param_specs(cfg, mesh, fsdp=True)
        n_data = sum("data" in str(s) for s in jax.tree.leaves(
            fsdp, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        n_plain = sum("data" in str(s) for s in jax.tree.leaves(
            plain, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
        assert n_data > n_plain

    def test_cache_specs_modes(self):
        cfg = get_config("phi3-medium-14b")
        mesh = make_host_mesh()   # (1,1): dp_size=1, so force modes
        seq = shd.cache_specs(cfg, mesh, 1, kv_mode="seq")
        assert "model" in str(seq["k"])
        bat = shd.cache_specs(cfg, mesh, 1024, kv_mode="batch")
        assert str(bat["k"]).count("model") == 0


@pytest.mark.slow
class TestFsdpMultiPod:
    """fsdp_augment must shard over *all* data axes: hardcoding "data"
    left the "pod" axis replicated on the multi-pod mesh — 2× the
    per-device parameter memory dp_axes implies."""

    def test_fsdp_uses_full_dp_tuple(self):
        res = _run_sub("""
        import json
        cfg = get_config("grok-1-314b")
        kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 3}
              if hasattr(jax.sharding, "AxisType") else {})
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), **kw)
        specs = shd.param_specs(cfg, mesh, fsdp=True)
        flat = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        n_pod = sum("pod" in str(s) for s in flat)
        n_data = sum("data" in str(s) for s in flat)
        # every fsdp-augmented spec must name pod AND data together
        both = sum(("pod" in str(s)) == ("data" in str(s)) for s in flat)
        print(json.dumps({"n_pod": n_pod, "n_data": n_data,
                          "n": len(flat), "both": both}))
        """)
        assert res["n_pod"] > 0, "pod axis never participates in FSDP"
        assert res["n_pod"] == res["n_data"]
        assert res["both"] == res["n"]

    def test_fsdp_multipod_memory_and_numerics(self):
        """On a ("pod","data","model") mesh the fsdp-sharded parameters
        must (a) occupy 1/4 of the replicated per-device bytes for the
        augmented leaves and (b) leave a forward pass numerically
        unchanged."""
        res = _run_sub("""
        import json
        cfg = get_config("gpt2-small").reduced()
        # reduced dims are small; lower the fsdp threshold by checking
        # shardings directly on the big-enough leaves
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        loss1 = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)
        kw = ({"axis_types": (jax.sharding.AxisType.Auto,) * 3}
              if hasattr(jax.sharding, "AxisType") else {})
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"), **kw)
        specs = shd.param_specs(cfg, mesh, fsdp=True)
        with mesh:
            pp = jax.device_put(params, shd.named(mesh, specs))
            bb = jax.device_put(batch, NamedSharding(mesh, P(("pod",
                                                              "data"))))
            loss2 = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(pp, bb)
        # per-device fraction for leaves that picked up the dp tuple
        fracs = []
        for leaf, spec in zip(jax.tree.leaves(pp), jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))):
            if "pod" in str(spec):
                shard = leaf.addressable_shards[0].data
                fracs.append(shard.size / leaf.size)
        print(json.dumps({"l1": float(loss1), "l2": float(loss2),
                          "n_aug": len(fracs),
                          "max_frac": max(fracs) if fracs else None}))
        """)
        assert abs(res["l1"] - res["l2"]) < 2e-2
        if res["n_aug"]:       # reduced dims may fall under the 1024 gate
            assert res["max_frac"] <= 0.25 + 1e-6


_SUBPROCESS_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import api
from repro.distributed import sharding as shd
from repro import optim

def mesh2x4():
    kw = ({{"axis_types": (jax.sharding.AxisType.Auto,) * 2}}
          if hasattr(jax.sharding, "AxisType") else {{}})
    return jax.make_mesh((2, 4), ("data", "model"), **kw)
"""


def _run_sub(body: str) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SUBPROCESS_PRELUDE.format(src=os.path.abspath(src)) \
        + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardedExecution:
    def test_sharded_train_step_matches_single(self):
        res = _run_sub("""
        import json
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = optim.OptConfig(total_steps=10, warmup_steps=0)
        opt = optim.init(params, opt_cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}

        def step(p, o, b):
            loss, g = jax.value_and_grad(
                lambda p: api.loss_fn(p, cfg, b))(p)
            np_, no_, _ = optim.update(g, o, p, opt_cfg)
            return loss, np_

        loss1, p1 = jax.jit(step)(params, opt, batch)

        mesh = mesh2x4()
        ps = shd.param_specs(cfg, mesh)
        with mesh:
            pp = jax.device_put(params, shd.named(mesh, ps))
            oo = jax.device_put(opt, shd.named(mesh, shd.opt_specs(cfg, mesh, ps)))
            bb = jax.device_put(batch, NamedSharding(mesh, P("data")))
            loss2, p2 = jax.jit(step)(pp, oo, bb)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print(json.dumps({"loss1": float(loss1), "loss2": float(loss2),
                          "max_param_delta": d}))
        """)
        assert abs(res["loss1"] - res["loss2"]) < 2e-2
        assert res["max_param_delta"] < 2e-2

    def test_seq_sharded_decode_matches_replicated(self):
        """Sequence-parallel flash-decode (KV cache sharded along S over
        'model') must equal the replicated decode — the partial-softmax
        merge as an SPMD collective."""
        res = _run_sub("""
        import json
        cfg = get_config("gpt2-small").reduced()
        b, s = 2, 32
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
        _, cache = api.prefill(params, cfg, {"tokens": toks})
        ck = jnp.zeros((cfg.n_layers, b, 40, cfg.n_kv_heads, cfg.hd),
                       jnp.bfloat16).at[:, :, :s].set(cache["k"])
        cv = jnp.zeros_like(ck).at[:, :, :s].set(cache["v"])
        cache = {"k": ck, "v": cv}
        tok = toks[:, -1:]
        f = lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos)
        ref, _ = jax.jit(f)(params, tok, cache, jnp.int32(s - 1))

        mesh = mesh2x4()
        with mesh:
            cs = {"k": P(None, None, "model", None, None),
                  "v": P(None, None, "model", None, None)}
            cc = jax.device_put(cache, shd.named(mesh, cs))
            pp = jax.device_put(params, shd.named(
                mesh, shd.param_specs(cfg, mesh)))
            out, _ = jax.jit(f)(pp, tok, cc, jnp.int32(s - 1))
        print(json.dumps({"delta": float(jnp.abs(ref - out).max())}))
        """)
        assert res["delta"] < 1e-2

    def test_moe_expert_parallel_matches(self):
        res = _run_sub("""
        import json
        cfg = get_config("dbrx-132b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        loss1 = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)
        mesh = mesh2x4()
        with mesh:
            pp = jax.device_put(params, shd.named(
                mesh, shd.param_specs(cfg, mesh)))
            bb = jax.device_put(batch, NamedSharding(mesh, P("data")))
            loss2 = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(pp, bb)
        print(json.dumps({"l1": float(loss1), "l2": float(loss2)}))
        """)
        assert abs(res["l1"] - res["l2"]) < 2e-2
