"""Allclose tests for the fused flash-decode Pallas kernel: plain sweep,
both cache layouts, sliding windows, partial-statistics mode (+ the
stats_merge algebra), and policy-selected accumulation dtypes."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_partial,
                                            decode_attention_ref)
from repro.runtime import ExecPolicy


@pytest.mark.parametrize("b,h,hkv,d,smax,clen", [
    (2, 8, 8, 64, 512, 300),      # MHA
    (1, 8, 2, 64, 1024, 1024),    # GQA 4:1, full cache
    (2, 4, 1, 80, 640, 17),       # MQA, unaligned head dim, short ctx
    (1, 16, 4, 128, 512, 511),
])
def test_allclose_vs_ref(b, h, hkv, d, smax, clen):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
    out = decode_attention(q, kc, vc, clen, block_s=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_per_slot_cache_len_vector():
    """(B,) cache_len: each batch row is masked against its own length
    (the serving engine's ragged continuous-batching contract)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, h, hkv, d, smax = 4, 8, 4, 64, 768
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
    clen = jnp.array([1, 255, 500, 768], jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_s=256, interpret=True)
    ref = decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_bf16_cache():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 2, 256, 64)).astype(jnp.bfloat16)
    vc = jax.random.normal(ks[2], (1, 2, 256, 64)).astype(jnp.bfloat16)
    out = decode_attention(q, kc, vc, 200, block_s=128, interpret=True)
    ref = decode_attention_ref(q, kc.astype(jnp.float32),
                               vc.astype(jnp.float32), 200)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-2)


def _rand_cache(seed, b, h, hkv, d, smax, layout="bhsd"):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    shape = (b, hkv, smax, d) if layout == "bhsd" else (b, smax, hkv, d)
    kc = jax.random.normal(ks[1], shape, jnp.float32)
    vc = jax.random.normal(ks[2], shape, jnp.float32)
    return q, kc, vc


def test_bshd_layout():
    """The sequence-major cache feeds the kernel through layout-aware
    index maps — no transpose, same numbers as head-major."""
    q, kc, vc = _rand_cache(3, 2, 8, 4, 64, 512)
    clen = jnp.array([77, 512], jnp.int32)
    ref = decode_attention(q, kc, vc, clen, block_s=128, interpret=True)
    out = decode_attention(q, kc.transpose(0, 2, 1, 3),
                           vc.transpose(0, 2, 1, 3), clen, layout="bshd",
                           block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window,clen", [
    (1, 300), (64, 300), (127, 512), (128, 512), (512, 512), (700, 300),
])
def test_windowed_vs_ref(window, clen):
    """Sliding-window sweep == windowed reference reduction, including
    window == 1, block-straddling windows and window > cache_len."""
    q, kc, vc = _rand_cache(4, 2, 8, 4, 64, 512)
    cl = jnp.array([clen, max(1, clen - 37)], jnp.int32)
    out = decode_attention(q, kc, vc, cl, window=window, block_s=128,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, cl, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_partial_stats_merge_matches_full():
    """Manually split the cache into 4 slices, run each in
    partial-statistics mode with its seq_offset, fold with stats_merge
    (the pairwise rule) — the result must equal the one-shot kernel."""
    from repro.core.softmax import SoftmaxStats, stats_merge
    from repro.core.vexp import get_exp_fn
    b, h, hkv, d, smax = 2, 8, 4, 64, 512
    q, kc, vc = _rand_cache(5, b, h, hkv, d, smax)
    clen = jnp.array([1, 389], jnp.int32)
    full = decode_attention(q, kc, vc, clen, block_s=64, interpret=True)
    exp_fn = get_exp_fn("vexp")
    nsh, loc = 4, smax // 4
    stats, acc = None, None
    # fold in a deliberately shuffled order: the merge is commutative
    for i in (2, 0, 3, 1):
        m, l, a = decode_attention_partial(
            q, kc[:, :, i * loc:(i + 1) * loc],
            vc[:, :, i * loc:(i + 1) * loc], clen, i * loc,
            block_s=64, interpret=True)
        if stats is None:
            stats, acc = SoftmaxStats(m=m, l=l), a
        else:
            merged, aa, ab = stats_merge(stats, SoftmaxStats(m=m, l=l),
                                         exp_fn=exp_fn)
            acc = acc * aa + a * ab
            stats = merged
    out = (acc * (1.0 / jnp.maximum(stats.l, 1e-30))).reshape(b, 1, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_partial_empty_shard_is_merge_identity():
    """A slice entirely past cache_len returns (NEG_INF, 0, 0)."""
    q, kc, vc = _rand_cache(6, 1, 4, 2, 64, 256)
    m, l, acc = decode_attention_partial(
        q, kc, vc, jnp.array([100], jnp.int32), 512, block_s=128,
        interpret=True)
    assert float(jnp.max(m)) <= -1e29
    assert float(jnp.abs(l).max()) == 0.0
    assert float(jnp.abs(acc).max()) == 0.0


def test_accum_dtype_bf16_close_but_distinct():
    """accum_dtype="bfloat16" must actually change the compiled program
    (satellite: it used to be hashed into the jit key and ignored) while
    staying within bf16 round-off of the f32 accumulation."""
    q, kc, vc = _rand_cache(7, 2, 8, 4, 64, 512)
    clen = jnp.array([300, 512], jnp.int32)
    f32 = decode_attention(
        q, kc, vc, clen,
        policy=ExecPolicy(kernel_backend="pallas", block_s=128))
    bf16 = decode_attention(
        q, kc, vc, clen,
        policy=ExecPolicy(kernel_backend="pallas", block_s=128,
                          accum_dtype="bfloat16"))
    assert not np.array_equal(np.asarray(f32), np.asarray(bf16)), \
        "bfloat16 accumulation compiled an identical program to float32"
    np.testing.assert_allclose(np.asarray(bf16), np.asarray(f32),
                               atol=5e-2, rtol=5e-2)
