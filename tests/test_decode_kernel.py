"""Allclose tests for the fused flash-decode Pallas kernel."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)


@pytest.mark.parametrize("b,h,hkv,d,smax,clen", [
    (2, 8, 8, 64, 512, 300),      # MHA
    (1, 8, 2, 64, 1024, 1024),    # GQA 4:1, full cache
    (2, 4, 1, 80, 640, 17),       # MQA, unaligned head dim, short ctx
    (1, 16, 4, 128, 512, 511),
])
def test_allclose_vs_ref(b, h, hkv, d, smax, clen):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
    out = decode_attention(q, kc, vc, clen, block_s=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_per_slot_cache_len_vector():
    """(B,) cache_len: each batch row is masked against its own length
    (the serving engine's ragged continuous-batching contract)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, h, hkv, d, smax = 4, 8, 4, 64, 768
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
    clen = jnp.array([1, 255, 500, 768], jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_s=256, interpret=True)
    ref = decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_bf16_cache():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 2, 256, 64)).astype(jnp.bfloat16)
    vc = jax.random.normal(ks[2], (1, 2, 256, 64)).astype(jnp.bfloat16)
    out = decode_attention(q, kc, vc, 200, block_s=128, interpret=True)
    ref = decode_attention_ref(q, kc.astype(jnp.float32),
                               vc.astype(jnp.float32), 200)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-2)
