"""Benchmark-layer tests: the Snitch cost model must reproduce the paper's
headline numbers; accuracy benchmarks must hit the paper's envelopes."""

import sys
import os

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import snitch_model as sm
from benchmarks import exp_accuracy


class TestSnitchModel:
    def test_softmax_speedup_paper(self):
        """Paper: 162.7x (Fig. 6a). Model: 360 / 2.125 cycles."""
        assert 140 <= sm.softmax_speedup() <= 190

    def test_softmax_energy_paper(self):
        """Paper: 74.3x (Fig. 6c)."""
        assert 55 <= sm.softmax_energy_reduction() <= 90

    def test_exp_energy_table3(self):
        assert sm.E_EXP_BASE / sm.E_EXP_HW > 500   # "two orders of magnitude"

    def test_fa2_speedup_paper(self):
        """Paper: up to 8.2x (Fig. 6d)."""
        assert 6 <= sm.fa2_speedup() <= 13

    def test_fa2_softmax_share(self):
        """Paper Fig. 6e: softmax dominates baseline, ~6% optimized."""
        base = sm.fa2_softmax_share(sm.AttnShape(2048), "baseline")
        opt = sm.fa2_softmax_share(sm.AttnShape(2048), "sw_exp_hw_optim")
        assert base > 0.5
        assert opt < 0.12

    def test_e2e_ordering_paper_fig8(self):
        """Fig. 8 ordering: GPT-2 > GPT-3 > ViT-B > ViT-H speedups."""
        sp = {n: sm.e2e_speedup(n) for n in sm.E2E_MODELS}
        assert sp["gpt2-small"] > sp["gpt3-xl"] > sp["vit-base"] \
            > sp["vit-huge"]
        assert sp["gpt2-small"] > 3.0          # paper: 5.8x
        assert sp["vit-huge"] > 1.05           # paper: 1.4x

    def test_e2e_energy_positive_gains(self):
        for n in sm.E2E_MODELS:
            assert sm.e2e_energy_ratio(n) > 1.0


class TestAccuracyBench:
    def test_exp_accuracy_paper_envelope(self):
        errs = exp_accuracy.exp_relative_error(n=50_000)
        for impl, e in errs.items():
            assert e["mean_rel"] < 0.0030, impl     # paper: 0.14%
            assert e["max_rel"] < 0.010, impl       # paper: 0.78%

    def test_softmax_mse_paper_order(self):
        for impl, mse in exp_accuracy.softmax_mse().items():
            assert mse < 5e-9, impl                 # paper: 1.62e-9
