"""Slot-level continuous-batching serving engine tests.

The headline regression: a batch mixing prompt lengths must produce
exactly the greedy tokens each request gets when served alone — the old
driver left-padded with token 0, attended the padding during prefill and
decoded every slot at the longest request's position, so any unequal-length
batch silently produced wrong tokens.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.launch.serve import Server, Request, _len_bucket
from repro.models.transformer import cache_seq_axis
from repro.runtime import resolve_policy, parse_policy_groups

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")


@pytest.fixture(scope="module")
def cfg():
    return get_config("gpt2-small").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return api.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,), dtype=np.int32) for n in lens]


def _serve(cfg, params, prompts, idxs, *, max_new=6, max_batch=4,
           max_seq=64, policy=None, policy_groups=None, groups_of=None):
    srv = Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                 policy=policy, policy_groups=policy_groups)
    reqs = [Request(i, prompts[i].copy(), max_new,
                    group=(groups_of or {}).get(i, "default"))
            for i in idxs]
    srv.run(reqs)
    return {r.rid: r.out for r in reqs}, srv


# ------------------------------------------------------- headline regression

class TestMixedLengthOracle:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_unequal_batch_matches_solo(self, cfg, params, exp):
        """2-request unequal-length batch == each request served alone,
        token for token, under every exp backend."""
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        prompts = _prompts(cfg, (5, 11))
        together, _ = _serve(cfg, params, prompts, [0, 1], policy=pol)
        solo0, _ = _serve(cfg, params, prompts, [0], policy=pol)
        solo1, _ = _serve(cfg, params, prompts, [1], policy=pol)
        assert together[0] == solo0[0]
        assert together[1] == solo1[1]

    def test_uniform_full_pool_fast_path_matches_solo(self, cfg, params):
        """A full-width exact-bucket wave takes the plain-prefill + padded
        cache fast path; its tokens must equal solo serving (which runs
        the masked ragged path)."""
        prompts = _prompts(cfg, (8, 8, 8, 8))   # bucket(8) == 8, pool of 4
        together, srv = _serve(cfg, params, prompts, [0, 1, 2, 3],
                               max_batch=4)
        assert srv.admit_log == [0, 1, 2, 3]
        for i in range(4):
            solo, _ = _serve(cfg, params, prompts, [i])
            assert together[i] == solo[i], i

    def test_uniform_full_pool_pallas_matches_solo(self, cfg, params):
        """Under a pallas policy a full exact-bucket wave must not take
        the unmasked fast path (which would prefill through the real
        Pallas kernel while solo serving runs the demoted reference scan
        — a different fp accumulation order that can flip a near-tie
        argmax)."""
        pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
        prompts = _prompts(cfg, (8, 8, 8, 8))
        together, _ = _serve(cfg, params, prompts, [0, 1, 2, 3],
                             max_batch=4, policy=pol)
        for i in range(4):
            solo, _ = _serve(cfg, params, prompts, [i], policy=pol)
            assert together[i] == solo[i], i

    def test_bhsd_pallas_per_slot_kernel(self, cfg, params):
        """The head-major cache + per-slot (B,) cache_len Pallas decode
        route must also match solo serving (exercises the slot-pool insert
        along the bhsd sequence axis and the vectorized-length kernel)."""
        ocfg = cfg.optimized()
        assert ocfg.kv_cache_layout == "bhsd"
        oparams = api.init_params(ocfg, jax.random.PRNGKey(0))
        pol = resolve_policy(ocfg, env={}, kernel_backend="pallas")
        prompts = _prompts(ocfg, (5, 11))
        together, _ = _serve(ocfg, oparams, prompts, [0, 1],
                             max_new=5, policy=pol)
        solo0, _ = _serve(ocfg, oparams, prompts, [0], max_new=5, policy=pol)
        solo1, _ = _serve(ocfg, oparams, prompts, [1], max_new=5, policy=pol)
        assert together[0] == solo0[0]
        assert together[1] == solo1[1]

    def test_windowed_arch_pallas_ring_buffer(self):
        """Ring-buffer windowed serving under a pallas policy: the fused
        kernel now covers windowed decode (no reference fallback), and a
        mixed-length windowed batch must still match solo serving token
        for token — including past the window roll-over."""
        wcfg = get_config("h2o-danube3-4b").reduced()
        assert wcfg.sliding_window
        wparams = api.init_params(wcfg, jax.random.PRNGKey(0))
        pol = resolve_policy(wcfg, env={}, kernel_backend="pallas")
        prompts = _prompts(wcfg, (5, 11))
        # max_new past the window (16) forces the ring-buffer wrap
        together, _ = _serve(wcfg, wparams, prompts, [0, 1],
                             max_new=10, max_seq=wcfg.sliding_window * 3,
                             policy=pol)
        solo0, _ = _serve(wcfg, wparams, prompts, [0], max_new=10,
                          max_seq=wcfg.sliding_window * 3, policy=pol)
        solo1, _ = _serve(wcfg, wparams, prompts, [1], max_new=10,
                          max_seq=wcfg.sliding_window * 3, policy=pol)
        assert together[0] == solo0[0]
        assert together[1] == solo1[1]


# --------------------------------------------------------- ragged prefill api

class TestRaggedPrefill:
    def test_prompt_len_masks_padding(self, cfg, params):
        """api.prefill with prompt_len: per-row last-real logits equal the
        solo prefill logits and pad K/V cache rows are zeroed."""
        prompts = _prompts(cfg, (5, 11))
        toks = np.zeros((2, 16), np.int32)
        toks[0, :5], toks[1, :11] = prompts[0], prompts[1]
        lb, cb = api.prefill(params, cfg, {"tokens": jnp.asarray(toks),
                                           "prompt_len": jnp.array([5, 11])})
        for i, p in enumerate(prompts):
            ls, _ = api.prefill(params, cfg, {"tokens": jnp.asarray(p[None])})
            np.testing.assert_array_equal(np.asarray(lb[i, 0]),
                                          np.asarray(ls[0, 0]))
        k = np.asarray(cb["k"], np.float32)
        assert (k[:, 0, 5:] == 0).all() and (k[:, 1, 11:] == 0).all()

    def test_prompt_len_accepted_for_recurrent_families(self):
        """Ragged prefill is family-uniform now (the DecodeState refactor):
        an ssm prompt_len batch must not raise and must return per-row
        last-real-token logits (full coverage in
        tests/test_recurrent_serving.py)."""
        mcfg = get_config("mamba2-1.3b").reduced()
        mparams = api.init_params(mcfg, jax.random.PRNGKey(0))
        logits, state = api.prefill(
            mparams, mcfg, {"tokens": jnp.zeros((2, 8), jnp.int32),
                            "prompt_len": jnp.array([4, 8])})
        assert logits.shape == (2, 1, mcfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


# --------------------------------------------------- scheduler / slot algebra

class TestScheduler:
    def test_admission_order_and_slot_reuse(self, cfg, params):
        """5 requests through 2 slots: FIFO admission, every request
        completes with exactly max_new tokens."""
        lens = (5, 9, 7, 6, 8)
        news = (2, 5, 3, 4, 1)
        prompts = _prompts(cfg, lens)
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        reqs = [Request(i, prompts[i].copy(), news[i]) for i in range(5)]
        srv.run(reqs)
        assert srv.admit_log == [0, 1, 2, 3, 4]
        for r in reqs:
            assert len(r.out) == r.max_new, r.rid
            assert r.finish_reason == "max_new"
            assert r.t_done >= r.t_first >= r.t_submit > 0

    def test_finished_slots_freed_not_burned(self, cfg, params):
        """A slot whose request finishes is freed for the queue instead of
        decoding dead tokens until the batch-wide max: serving (1, 8, 1)
        max_new through 2 slots needs ~7 decode steps, not 8 * 3."""
        prompts = _prompts(cfg, (5, 7, 6))
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        reqs = [Request(0, prompts[0].copy(), 1),
                Request(1, prompts[1].copy(), 8),
                Request(2, prompts[2].copy(), 1)]
        srv.run(reqs)
        assert [len(r.out) for r in reqs] == [1, 8, 1]
        # req 0 finishes at admission (token from prefill); req 2 rides in
        # the freed slot while req 1 keeps decoding.
        assert srv.stats()["default"]["decode_steps"] <= 8

    def test_decode_past_capacity_stops_slot(self, cfg, params):
        """A request that would decode past max_seq is stopped with
        finish_reason="length_cap" instead of silently overwriting the
        last cache row (the old dynamic_update_slice clamp)."""
        prompts = _prompts(cfg, (11,))
        srv = Server(cfg, params, max_batch=2, max_seq=16)
        r = Request(0, prompts[0].copy(), 50)
        srv.run([r])
        # 1 prefill token + (16 - 11) decode writes at positions 11..15
        assert len(r.out) == 6
        assert r.finish_reason == "length_cap"

    def test_submit_validation(self, cfg, params):
        srv = Server(cfg, params, max_batch=2, max_seq=16)
        with pytest.raises(ValueError):   # prompt longer than the cache
            srv.submit(Request(0, np.zeros(17, np.int32), 4))
        with pytest.raises(ValueError):   # unknown group
            srv.submit(Request(1, np.zeros(4, np.int32), 4, group="nope"))
        with pytest.raises(ValueError):   # encoder-only: no decode state
            Server(get_config("hubert-xlarge").reduced(), params)

    def test_len_bucket(self):
        assert [_len_bucket(n, 512) for n in (1, 8, 9, 100)] == \
            [8, 8, 16, 128]
        assert _len_bucket(400, 96) == 96   # capped at cache capacity


# ----------------------------------------------------------- policy groups

class TestPolicyGroups:
    def test_exact_slots_isolated_from_vexp(self, cfg, params):
        """In a mixed-policy server, the exact group's tokens equal a
        pure-exact server's tokens (a vexp slot never contaminates an
        exact slot's numerics), and vice versa."""
        prompts = _prompts(cfg, (5, 11, 7))
        groups = {"eval": resolve_policy(cfg, env={}, exp_backend="exact"),
                  "bulk": resolve_policy(cfg, env={}, exp_backend="vexp")}
        mixed, _ = _serve(cfg, params, prompts, [0, 1, 2],
                          policy_groups=groups,
                          groups_of={0: "eval", 1: "bulk", 2: "eval"})
        pure_exact, _ = _serve(cfg, params, prompts, [0, 2],
                               policy=groups["eval"])
        pure_vexp, _ = _serve(cfg, params, prompts, [1],
                              policy=groups["bulk"])
        assert mixed[0] == pure_exact[0]
        assert mixed[2] == pure_exact[2]
        assert mixed[1] == pure_vexp[1]

    def test_parse_policy_groups(self, cfg):
        g = parse_policy_groups("eval=exact,bulk=vexp_hw/xla", cfg, env={})
        assert g["eval"].exp_backend == "exact"
        assert g["bulk"].exp_backend == "vexp_hw"
        assert g["bulk"].kernel_backend == "xla"
        for bad in ("", "noequals", "x=,", "a=exact,a=vexp"):
            with pytest.raises(ValueError):
                parse_policy_groups(bad, cfg, env={})

    def test_parse_policy_groups_base_beats_cfg_and_env(self, cfg):
        """A resolved base policy already encodes config/env/CLI
        precedence; neither cfg fields nor stale env vars may shadow it
        (e.g. a CLI --kernel-backend xla must survive into every group)."""
        base = resolve_policy(cfg, env={}, kernel_backend="xla")
        g = parse_policy_groups("eval=exact", cfg, base=base)
        assert g["eval"].kernel_backend == "xla"
        assert g["eval"].exp_backend == "exact"
        g2 = parse_policy_groups("eval=exact", cfg, base=base,
                                 env={"REPRO_KERNEL_BACKEND": "reference"})
        assert g2["eval"].kernel_backend == "reference"  # explicit env wins


# ------------------------------------------------- per-slot decode kernel

class TestPerSlotDecodeKernel:
    def test_vector_cache_len_vs_reference(self):
        """The Pallas flash-decode kernel with a (B,) cache_len vector
        must match the reference reduction row for row."""
        from repro.kernels.decode_attention import (decode_attention,
                                                    decode_attention_ref)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        b, h, hkv, d, smax = 3, 8, 2, 64, 512
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        kc = jax.random.normal(ks[1], (b, hkv, smax, d), jnp.float32)
        vc = jax.random.normal(ks[2], (b, hkv, smax, d), jnp.float32)
        clen = jnp.array([300, 17, 512], jnp.int32)
        out = decode_attention(q, kc, vc, clen, block_s=128, interpret=True)
        ref = decode_attention_ref(q, kc, vc, clen)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)
        # each row must equal the same row decoded alone at its own length
        for i, cl in enumerate((300, 17, 512)):
            solo = decode_attention(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                                    cl, block_s=128, interpret=True)
            np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                       np.asarray(solo), atol=2e-3,
                                       rtol=2e-3)


# -------------------------------------------------- donated zero-copy decode

class TestDonatedDecodeStep:
    def test_cache_and_pos_buffers_donated(self, cfg, params):
        """The decode step donates the KV cache and the slot-position
        vector: the pre-step buffers must be consumed (reused in place),
        not left alive next to freshly allocated outputs."""
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        prompts = _prompts(cfg, (5,))
        srv.submit(Request(0, prompts[0].copy(), 8))
        g = srv._groups["default"]
        g.admit()
        cache_before = g.state.data["k"]
        pos_before = g.state.pos_dev
        g.decode_once()
        assert cache_before.is_deleted(), "KV cache was re-allocated"
        assert pos_before.is_deleted(), "position buffer was copied"
        srv.drain()

    def test_positions_advance_device_side(self, cfg, params):
        """Slot positions live on device and advance by the liveness
        vector inside the decode program — the host mirrors (lens) must
        stay in lockstep without ever being shipped down."""
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        prompts = _prompts(cfg, (5, 9))
        srv.submit(Request(0, prompts[0].copy(), 6))
        srv.submit(Request(1, prompts[1].copy(), 3))
        g = srv._groups["default"]
        g.admit()
        for _ in range(4):
            g.decode_once()
        live = [j for j in range(2) if g.reqs[j] is not None]
        pos = np.asarray(g.state.pos_dev)
        for j in range(2):
            expect = g.lens[j] if j in live else 0   # parked at finish
            assert pos[j] == expect, (j, pos, g.lens)
        srv.drain()


def test_write_token_kv_oob_drop_negative_positions():
    """The sharded decode write hands every shard the same token with
    shard-local positions: anything outside [0, S) — including *negative*
    positions, which a bare mode="drop" scatter would wrap numpy-style —
    must leave the cache untouched."""
    from repro.models.transformer import _write_token_kv
    for layout in ("bshd", "bhsd"):
        shape = (2, 5, 3, 8) if layout == "bshd" else (2, 3, 5, 8)
        kv_shape = (2, 1, 3, 8) if layout == "bshd" else (2, 3, 1, 8)
        cache = jnp.zeros(shape, jnp.float32)
        kv = jnp.ones(kv_shape, jnp.float32)
        # row 0 in-slice at 1; row 1 below the slice (the owner's
        # neighbour shard sees lpos in [-S, 0)) — must drop, not wrap
        out = _write_token_kv(cache, kv, jnp.array([1, -2]), layout,
                              oob_drop=True)
        s_ax = cache_seq_axis(layout, stacked=False)
        rows = np.asarray(jnp.moveaxis(out, s_ax, 1))    # (B, S, ...)
        assert (rows[0, 1] == 1).all(), layout
        assert (rows[1] == 0).all(), f"{layout}: negative pos wrapped"
        # above the slice: also dropped
        out2 = _write_token_kv(cache, kv, jnp.array([5, 7]), layout,
                               oob_drop=True)
        assert (np.asarray(out2) == 0).all(), layout


# ------------------------------------------------------- cache layout axis

def test_cache_seq_axis():
    """"bshd" stacked caches are (L, B, S, Hkv, hd) -> axis 2; "bhsd" are
    (L, B, Hkv, S, hd) -> axis 3 (the old _grow_cache hardcoded -3, which
    padded Hkv on head-major caches)."""
    assert cache_seq_axis("bshd") == 2
    assert cache_seq_axis("bhsd") == 3
    assert cache_seq_axis("bshd", stacked=False) == 1
    assert cache_seq_axis("bhsd", stacked=False) == 2
    with pytest.raises(ValueError):
        cache_seq_axis("sbhd")
    import dataclasses
    cfg = get_config("gpt2-small").reduced()
    for lay in ("bshd", "bhsd"):
        c = api.init_cache(dataclasses.replace(cfg, kv_cache_layout=lay),
                           2, 32)
        assert c["k"].shape[cache_seq_axis(lay)] == 32
