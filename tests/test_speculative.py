"""Policy-speculative decoding tests.

The contract under test: k draft steps under the draft policy followed
by ONE batched exact-policy verify must leave the serving engine in a
state indistinguishable from plain greedy decode — same tokens (scan
verify is bitwise-identical by construction), same finish reasons, same
cache/pos/recurrent state after rollback. Acceptance is a throughput
knob, never a correctness knob.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.models.decode_state import (
    KVDecodeState, RecurrentDecodeState, SPEC_PAD, _spec_programs,
    decode_state_for)
from repro.launch.serve import Server, Request
from repro.runtime import resolve_policy


@pytest.fixture(scope="module")
def cfg():
    return get_config("gpt2-small").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return api.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
            for n in lens]


def _serve(cfg, params, prompts, *, max_new=12, max_batch=4, max_seq=64,
           policy=None, **kw):
    srv = Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                 policy=policy, **kw)
    reqs = [Request(i, p.copy(), max_new) for i, p in enumerate(prompts)]
    srv.run(reqs)
    return {r.rid: (r.out, r.finish_reason) for r in reqs}, srv


# ------------------------------------------------ speculative == plain

class TestSpeculativeIdentity:
    """Scan-verify speculative serving emits exactly the plain greedy
    stream — every family, every request, token for token."""

    @pytest.mark.parametrize("spec_k", (2, 4))
    def test_contiguous_kv(self, cfg, params, spec_k):
        prompts = _prompts(cfg, (5, 11, 17, 8, 26, 7))
        base = resolve_policy(cfg, env={})
        plain, _ = _serve(cfg, params, prompts, policy=base)
        spol = base.replace(spec_k=spec_k, draft_exp_backend="vexp_hw")
        spec, srv = _serve(cfg, params, prompts, policy=spol)
        assert spec == plain
        st = srv.stats()["default"]
        assert st["spec_bursts"] > 0
        assert st["spec_accepted"] + st["spec_rolled_back"] == \
            st["spec_drafted"]

    def test_paged_kv(self, cfg, params):
        prompts = _prompts(cfg, (5, 11, 17, 8, 26, 7))
        base = resolve_policy(cfg, env={})
        kw = dict(paged=True, block_page=8)
        plain, _ = _serve(cfg, params, prompts, policy=base, **kw)
        spol = base.replace(spec_k=2, draft_exp_backend="vexp")
        spec, srv = _serve(cfg, params, prompts, policy=spol, **kw)
        assert spec == plain
        srv.assert_idle_clean()      # rollback leaked no pages

    @pytest.mark.parametrize("arch", ("mamba2-1.3b", "recurrentgemma-9b"))
    def test_recurrent_families(self, arch):
        cfg = get_config(arch).reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        lens = (5, 11, 17, 8)
        if cfg.sliding_window:
            lens = tuple(min(n, cfg.sliding_window) for n in lens)
        prompts = _prompts(cfg, lens)
        base = resolve_policy(cfg, env={})
        plain, _ = _serve(cfg, params, prompts, policy=base)
        spec, _ = _serve(cfg, params, prompts,
                         policy=base.replace(spec_k=2))
        assert spec == plain

    def test_chunked_prefill_composes(self, cfg, params):
        """Speculative decode downstream of chunked prefill admission."""
        prompts = _prompts(cfg, (5, 21, 17, 8))
        base = resolve_policy(cfg, env={}).replace(prefill_chunk=8)
        plain, _ = _serve(cfg, params, prompts, policy=base)
        spec, _ = _serve(cfg, params, prompts,
                         policy=base.replace(spec_k=4))
        assert spec == plain

    def test_spec_groups_opt_in(self, cfg, params):
        """Only named groups speculate; others run the plain loop."""
        base = resolve_policy(cfg, env={})
        spol = base.replace(spec_k=2)
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=spol,
                     policy_groups={"aux": base},
                     spec_groups=("default",))
        assert srv._groups["default"].spec_k == 2
        assert srv._groups["aux"].spec_k == 0


# -------------------------------------------- draft/verify agreement

class TestDraftAgreement:
    """The draft policy's argmax agrees with the exact policy's at most
    positions — that agreement rate IS the acceptance rate, so pin it
    above a floor to catch a draft wiring regression (a broken draft
    decodes garbage and acceptance collapses to ~1/vocab)."""

    @pytest.mark.parametrize("draft", ("vexp", "vexp_hw"))
    def test_acceptance_floor(self, cfg, params, draft):
        prompts = _prompts(cfg, (5, 11, 17, 8))
        base = resolve_policy(cfg, env={})
        spol = base.replace(spec_k=4, draft_exp_backend=draft)
        _, srv = _serve(cfg, params, prompts, policy=spol, max_new=16)
        st = srv.stats()["default"]
        assert st["spec_drafted"] > 0
        assert st["spec_acceptance"] > 0.25

    @pytest.mark.parametrize("draft", ("vexp", "vexp_hw"))
    def test_per_position_argmax_agreement(self, cfg, params, draft):
        """Direct check: draft-policy logits argmax == exact argmax on
        most decode positions of a running state."""
        base = resolve_policy(cfg, env={})
        prompts = _prompts(cfg, (6, 13, 9, 20))
        B, S, n = 4, 64, 12
        agree = 0
        for pol in (base, base.replace(exp_backend=draft)):
            st = KVDecodeState(cfg, params, pol, B, S)
            sp = st.prefill_width(max(len(p) for p in prompts))
            toks = np.zeros((B, sp), np.int32)
            plens = np.zeros((B,), np.int32)
            for j, p in enumerate(prompts):
                toks[j, :len(p)] = p
                plens[j] = len(p)
            last = st.prefill_into(list(range(B)), toks, plens, full=True)
            live = jnp.ones((B,), jnp.int32)
            outs = [np.asarray(last)[:, 0]]
            for _ in range(n - 1):
                last = st.step(last, live)
                outs.append(np.asarray(last)[:, 0])
            if pol is base:
                exact = np.stack(outs, 1)
            else:
                agree = (np.stack(outs, 1) == exact).mean()
        assert agree > 0.5, f"{draft} drafts diverge from exact: {agree}"


# ----------------------------------------------- rollback state purity

class TestRollbackPurity:
    def test_kv_restore_position_and_behavior(self, cfg, params):
        """KV rollback is the cursor rewind: positions restore bitwise,
        stale draft rows past the cursor stay cache_len-masked, and the
        restored state decodes EXACTLY like a state that never drafted
        (the observable-state identity the protocol relies on)."""
        base = resolve_policy(cfg, env={})

        def mk():
            st = KVDecodeState(cfg, params, base.replace(spec_k=4), 2, 64)
            toks = np.zeros((2, st.prefill_width(9)), np.int32)
            plens = np.array([9, 5], np.int32)
            rng = np.random.default_rng(0)
            toks[0, :9] = rng.integers(0, cfg.vocab, 9)
            toks[1, :5] = rng.integers(0, cfg.vocab, 5)
            last = st.prefill_into([0, 1], toks, plens, full=True)
            return st, last

        live = jnp.ones((2,), jnp.int32)
        st, last = mk()
        st.enable_speculative(4)
        snap = st.spec_snapshot()
        pos_before = np.asarray(st.pos_dev).copy()
        cur = last
        for _ in range(4):
            cur = st.draft_step(cur, live)
        st.spec_restore(snap)
        assert np.array_equal(np.asarray(st.pos_dev), pos_before)
        ctrl, clast = mk()          # never drafted
        a, b = last, clast
        for _ in range(6):
            a, b = st.step(a, live), ctrl.step(b, live)
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_recurrent_snapshot_restore_bitwise(self):
        cfg = get_config("mamba2-1.3b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        base = resolve_policy(cfg, env={})
        st = RecurrentDecodeState(cfg, params, base.replace(spec_k=2),
                                  2, 64)
        st.enable_speculative(2)
        toks = np.zeros((2, st.prefill_width(7)), np.int32)
        plens = np.array([7, 4], np.int32)
        rng = np.random.default_rng(0)
        toks[0, :7] = rng.integers(0, cfg.vocab, 7)
        toks[1, :4] = rng.integers(0, cfg.vocab, 4)
        last = st.prefill_into([0, 1], toks, plens, full=True)
        live = jnp.ones((2,), jnp.int32)
        before = jax.tree_util.tree_map(
            lambda x: np.asarray(x).copy(), st.data)
        snap = st.spec_snapshot()
        cur = last
        for _ in range(2):
            cur = st.draft_step(cur, live)
        st.spec_restore(snap)
        same = jax.tree_util.tree_map(
            lambda a, b: np.array_equal(np.asarray(a), b),
            st.data, before)
        assert all(jax.tree_util.tree_leaves(same))


# --------------------------------------------------------- validation

class TestSpecValidation:
    def test_spec_k_one_rejected(self, cfg):
        with pytest.raises(ValueError, match="spec_k"):
            resolve_policy(cfg, env={}).replace(spec_k=1)

    def test_spec_verify_rejected(self, cfg):
        with pytest.raises(ValueError, match="spec_verify"):
            resolve_policy(cfg, env={}).replace(spec_verify="fused")

    def test_draft_backend_rejected(self, cfg):
        with pytest.raises(ValueError, match="draft_exp_backend"):
            resolve_policy(cfg, env={}).replace(draft_exp_backend="fast")

    def test_chunk_verify_recurrent_rejected(self):
        cfg = get_config("mamba2-1.3b").reduced()
        pol = resolve_policy(cfg, env={}).replace(spec_k=2,
                                                  spec_verify="chunk")
        with pytest.raises(ValueError, match="chunk"):
            _spec_programs(cfg, pol, 3, "recurrent", 64, impl="chunk")

    def test_enable_speculative_validates_k(self, cfg, params):
        base = resolve_policy(cfg, env={})
        st = KVDecodeState(cfg, params, base, 2, 64)
        with pytest.raises(ValueError):
            st.enable_speculative(1)

    def test_unsupported_state_rejected(self, cfg, params):
        """A ring-buffered (windowed) KV state cannot roll back past a
        wrapped write — the wrap DESTROYS the pre-burst row it lands
        on; enable_speculative must refuse."""
        import dataclasses
        wcfg = dataclasses.replace(cfg, sliding_window=16)
        pol = resolve_policy(wcfg, env={})
        st = KVDecodeState(wcfg, params, pol, 2, 32)  # full-window ring
        assert not st.supports_speculative()
        with pytest.raises(ValueError):
            st.enable_speculative(2)

    def test_server_spec_group_validation(self, cfg, params):
        base = resolve_policy(cfg, env={})
        with pytest.raises(ValueError, match="spec"):
            Server(cfg, params, max_batch=2, max_seq=64, policy=base,
                   spec_groups=("nope",))
        with pytest.raises(ValueError, match="spec"):
            Server(cfg, params, max_batch=2, max_seq=64, policy=base,
                   spec_groups=("default",))   # spec_k unset


# ------------------------------------------------------ chunk verify

class TestChunkVerify:
    def test_chunk_tokens_are_exact_argmaxes(self, cfg, params):
        """Chunk verify scores candidates with the exact policy's
        all-lanes chunk pass; every emitted token must be an exact-policy
        argmax given the (chunk-scored) prefix — check by re-scoring the
        emitted stream with plain chunk prefill."""
        prompts = _prompts(cfg, (5, 11, 17, 8))
        base = resolve_policy(cfg, env={})
        plain, _ = _serve(cfg, params, prompts, policy=base)
        cpol = base.replace(spec_k=4, spec_verify="chunk")
        out, srv = _serve(cfg, params, prompts, policy=cpol)
        st = srv.stats()["default"]
        assert st["spec_verify"] == "chunk"
        assert st["spec_bursts"] > 0
        for i in range(len(prompts)):
            toks, reason = out[i]
            assert len(toks) == len(plain[i][0])
            assert reason == plain[i][1]
            assert all(t >= 0 for t in toks)

    def test_chunk_paged_leak_free(self, cfg, params):
        prompts = _prompts(cfg, (5, 11, 17, 8))
        cpol = resolve_policy(cfg, env={}).replace(spec_k=4,
                                                   spec_verify="chunk")
        _, srv = _serve(cfg, params, prompts, policy=cpol,
                        paged=True, block_page=8)
        srv.assert_idle_clean()
