"""repro.analysis Layer-2 tests: the serving programs the engine
actually builds hold their lowered-program contracts —

* unsharded decode programs are collective-free, fully consume their
  donated carry, and keep the (state, positions) carry pytree stable
  (dtype/shape) across the step — for the KV, recurrent and hybrid
  families and the paged variants, under all three exp backends;
* the sharded decode program spends exactly ONE all_gather per layer
  (subprocess, 8 host devices);
* the planted fixtures (dtype-drifting carry, two-collective step,
  dropped donation) are each caught by the corresponding audit.

Audits run on *lowered* programs and ``eval_shape`` — no XLA
compilation, so the full family x backend matrix stays cheap.
"""

import importlib.util
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.models.decode_state import _paged_programs, _programs
from repro.runtime import resolve_policy
from repro.analysis import jaxpr_audit as ja

pytestmark = pytest.mark.analysis

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")
FAMILY_ARCH = {"kv": "gpt2-small", "recurrent": "mamba2-1.3b",
               "hybrid": "recurrentgemma-9b"}
FIX = Path(__file__).parent / "fixtures" / "analysis"

_cfg_cache, _params_cache = {}, {}


def _cfg(arch):
    if arch not in _cfg_cache:
        _cfg_cache[arch] = get_config(arch).reduced()
    return _cfg_cache[arch]


def _params(arch):
    if arch not in _params_cache:
        _params_cache[arch] = api.init_params(_cfg(arch),
                                              jax.random.PRNGKey(0))
    return _params_cache[arch]


def _load_fixture(name):
    spec = importlib.util.spec_from_file_location(name,
                                                  FIX / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _decode_args(arch, b=2, s=64):
    cfg = _cfg(arch)
    cache = api.init_cache(cfg, b, s)
    tok = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.ones((b,), jnp.int32)
    live = jnp.ones((b,), jnp.int32)
    return (_params(arch), tok, cache, pos, live)


# ----------------------------------------------- engine programs (unsharded)

class TestEngineDecodePrograms:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    @pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
    def test_decode_is_collective_free_donated_and_carry_stable(
            self, family, exp):
        """One parametrization per (family, exp backend): the decode
        program the slot engine runs must be collective-free, alias
        every donated (state, positions) leaf, and return its carry
        with identical treedef/dtypes/shapes."""
        arch = FAMILY_ARCH[family]
        cfg = _cfg(arch)
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        _, _, decode, _ = _programs(cfg, pol)
        args = _decode_args(arch)
        txt = decode.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})           # zero collectives
        n_carry = len(jax.tree_util.tree_leaves(args[2])) + 1
        ja.assert_all_donated(txt, n_carry)            # cache + positions
        ja.assert_carry_stable(decode, args, {2: 1, 3: 2})

    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_paged_decode_program(self, exp):
        """Paged KV decode: collective-free, carry-stable for the pool,
        tables and positions; positions always donate (the pool donates
        everywhere but XLA-CPU, where the page scatter materializes the
        pool regardless — mirrored here exactly as the builder does)."""
        arch = FAMILY_ARCH["kv"]
        cfg = _cfg(arch)
        b, s, page = 2, 64, 16
        ns = -(-s // page)
        pool = api.init_paged_cache(cfg, b, 1 + b * ns, page)
        tab = jnp.zeros((b, ns), jnp.int32)
        args = (_params(arch), jnp.zeros((b, 1), jnp.int32), pool, tab,
                jnp.ones((b,), jnp.int32), jnp.ones((b,), jnp.int32))

        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        _, decode, _ = _paged_programs(cfg, pol, page)
        txt = decode.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        pool_leaves = len(jax.tree_util.tree_leaves(pool))
        donated = (1 if jax.default_backend() == "cpu"
                   else pool_leaves + 1)
        ja.assert_all_donated(txt, donated)
        # carry stability is unconditional — pool AND positions
        ja.assert_carry_stable(decode, args, {2: 1, 4: 2})

    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    @pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
    def test_chunk_prefill_program(self, family, exp):
        """The resumable chunk-prefill program (PR-8) is held to the
        decode-step contracts: collective-free, fully donates its cache
        carry, and returns the pool pytree structurally unchanged —
        rows with ``clens == 0`` ride along bit-untouched, which starts
        with the carry coming back identical in treedef/shape/dtype."""
        arch = FAMILY_ARCH[family]
        cfg = _cfg(arch)
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        _, _, _, chunk = _programs(cfg, pol)
        b, c = 2, 8
        s = cfg.sliding_window or 64    # hybrid pool = its window
        cache = api.init_cache(cfg, b, s)
        args = (_params(arch), jnp.zeros((b, c), jnp.int32), cache,
                jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32))
        txt = chunk.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        ja.assert_all_donated(txt, len(jax.tree_util.tree_leaves(cache)))
        ja.assert_carry_stable(chunk, args, {2: 1})

    @pytest.mark.parametrize("family", ("kv", "hybrid"))
    def test_paged_chunk_prefill_program(self, family):
        """Paged chunk prefill: collective-free and pool-carry-stable;
        donation mirrors the paged decode builder (the pool donates
        everywhere but XLA-CPU, where the page scatter materializes the
        pool regardless)."""
        arch = FAMILY_ARCH[family]
        cfg = _cfg(arch)
        b, page = 2, 8
        s = cfg.sliding_window or 64
        ns = -(-s // page)
        pool = api.init_paged_cache(cfg, b, 1 + b * ns, page)
        tab = jnp.zeros((b, ns), jnp.int32)
        pol = resolve_policy(cfg, env={})
        _, _, chunk = _paged_programs(cfg, pol, page)
        args = (_params(arch), jnp.zeros((b, 8), jnp.int32), pool, tab,
                jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32))
        txt = chunk.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        donated = (0 if jax.default_backend() == "cpu"
                   else len(jax.tree_util.tree_leaves(pool)))
        ja.assert_all_donated(txt, donated)
        ja.assert_carry_stable(chunk, args, {2: 1})

    W = 4                               # spec_k = 3 draft lanes + bonus

    def _spec_args(self, arch, b=2, s=64):
        cache = api.init_cache(_cfg(arch), b, s)
        toks = jnp.zeros((b, self.W), jnp.int32)
        pos0 = jnp.ones((b,), jnp.int32)
        rem = jnp.full((b,), 8, jnp.int32)
        live = jnp.ones((b,), jnp.int32)
        return (_params(arch), toks, cache, pos0, rem, live)

    @pytest.mark.parametrize("impl", ["scan", "chunk"])
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_spec_verify_kv_program(self, exp, impl):
        """The speculative verify program (PR-10) is held to the decode
        contracts under both impls: collective-free, fully consumes the
        donated (cache, positions, remaining-budget) carry, and keeps
        it dtype/shape-stable — acceptance folds into the carry, so any
        drift here would defeat donation for EVERY burst."""
        from repro.models.decode_state import _spec_programs
        arch = FAMILY_ARCH["kv"]
        cfg = _cfg(arch)
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        verify = _spec_programs(cfg, pol, self.W, "kv", 64, impl=impl)
        args = self._spec_args(arch)
        txt = verify.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        n = len(jax.tree_util.tree_leaves(args[2])) + 2
        ja.assert_all_donated(txt, n)           # cache + pos + rem
        # verify returns (block, nlast, cache, pos, rem)
        ja.assert_carry_stable(verify, args, {2: 2, 3: 3, 4: 4})

    @pytest.mark.parametrize("family", ["recurrent", "hybrid"])
    def test_spec_verify_recurrent_program(self, family):
        """Recurrent/hybrid verify (two-scan: score + replay from the
        snapshot): collective-free; the snapshot c0 is deliberately NOT
        donated (the replay reads it twice) but positions and budget
        are; the replayed state must come back carry-stable."""
        from repro.models.decode_state import _spec_programs
        arch = FAMILY_ARCH[family]
        cfg = _cfg(arch)
        pol = resolve_policy(cfg, env={}, exp_backend="exact")
        cap = None if family == "recurrent" else 64
        verify = _spec_programs(cfg, pol, self.W, "recurrent", cap)
        args = self._spec_args(arch)
        txt = verify.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        ja.assert_all_donated(txt, 2)           # pos + rem only
        ja.assert_carry_stable(verify, args, {2: 2, 3: 3, 4: 4})

    @pytest.mark.parametrize("impl", ["scan", "chunk"])
    def test_spec_verify_paged_program(self, impl):
        """Paged verify: donation mirrors the paged decode builder (the
        pool donates everywhere but XLA-CPU); pool, tables, positions
        and budget all come back carry-stable, and the program never
        touches the allocator — it is pure device code."""
        from repro.models.decode_state import _spec_programs
        arch = FAMILY_ARCH["kv"]
        cfg = _cfg(arch)
        b, s, page = 2, 64, 16
        ns = -(-s // page)
        pool = api.init_paged_cache(cfg, b, 1 + b * ns, page)
        tab = jnp.zeros((b, ns), jnp.int32)
        pol = resolve_policy(cfg, env={}, exp_backend="exact")
        verify = _spec_programs(cfg, pol, self.W, "kv_paged", s,
                                page=page, impl=impl)
        args = (_params(arch), jnp.zeros((b, self.W), jnp.int32), pool,
                tab, jnp.ones((b,), jnp.int32),
                jnp.full((b,), 8, jnp.int32), jnp.ones((b,), jnp.int32))
        txt = verify.lower(*args).as_text()

        ja.assert_collective_budget(txt, {})
        donated = (2 if jax.default_backend() == "cpu"
                   else len(jax.tree_util.tree_leaves(pool)) + 2)
        ja.assert_all_donated(txt, donated)
        ja.assert_carry_stable(verify, args, {2: 2, 4: 3, 5: 4})

    def test_paged_hybrid_decode_program(self):
        """The hybrid family through the paged program builder (its KV
        periods page; recurrent periods carry their snapshots)."""
        arch = FAMILY_ARCH["hybrid"]
        cfg = _cfg(arch)
        b, s, page = 2, 64, 16
        ns = -(-s // page)
        pool = api.init_paged_cache(cfg, b, 1 + b * ns, page)
        tab = jnp.zeros((b, ns), jnp.int32)
        args = (_params(arch), jnp.zeros((b, 1), jnp.int32), pool, tab,
                jnp.ones((b,), jnp.int32), jnp.ones((b,), jnp.int32))
        pol = resolve_policy(cfg, env={})
        _, decode, _ = _paged_programs(cfg, pol, page)
        ja.assert_collective_budget(decode.lower(*args).as_text(), {})
        ja.assert_carry_stable(decode, args, {2: 1, 4: 2})


# ------------------------------------------------------- sharded (8 devices)

@pytest.mark.slow
def test_sharded_decode_one_collective_per_layer_and_donation():
    """The PR-4 budget through the audit API: the engine's seq-sharded
    decode program spends exactly one all_gather (layers are scanned, so
    the loop body lowers once) and nothing else, and every donated
    carry leaf is aliased."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_AUTOTUNE_CACHE"] = "off"
        import sys
        sys.path.insert(0, {src!r})
        import json
        import numpy as np
        import jax
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.serve import Server, Request
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import resolve_policy
        from repro.analysis import jaxpr_audit as ja

        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        pol = resolve_policy(cfg, env={{}}, kernel_backend="pallas")
        srv = Server(cfg, params, max_batch=2, max_seq=64,
                     mesh=make_host_mesh(1, 8), policy=pol, kv_mode="seq")
        rng = np.random.default_rng(0)
        srv.submit(Request(0, rng.integers(0, cfg.vocab, (5,),
                                           dtype=np.int32), 4))
        g = srv._groups["default"]
        g.admit()
        st = g.state
        args = (st.params_decode, g.last, st.data, st.pos_dev, g.live_dev)
        txt = st._decode.lower(*args).as_text()
        counts = ja.collective_counts(txt)
        ja.assert_collective_budget(txt, {{"all_gather": 1}})
        rep = ja.donation_report(
            txt, len(jax.tree_util.tree_leaves(st.data)) + 1)
        stable = ja.carry_report(st._decode, args, {{2: 1, 3: 2}})
        print(json.dumps({{"counts": counts,
                           "donated": rep.fully_consumed,
                           "carry_msgs": stable}}))
    """).format(src=src)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["counts"] == {"all_gather": 1}
    assert res["donated"]
    assert res["carry_msgs"] == []


@pytest.mark.slow
def test_sharded_chunk_prefill_outputs_carry_pool_sharding():
    """PR-8 re-placement contract (subprocess, 8 host devices): the
    sharded chunk-prefill program's cache output carries exactly the
    pool sharding (``serve_cache_sharding``), so chunked admission
    writes prefill rows into the sharded pool IN PLACE — the engine
    performs no post-prefill ``device_put`` of cache rows. Also pins
    carry stability (sharding included: ``carry_report`` compares
    shardings on live arrays) and sanity-checks the audit itself
    rejects a deliberately wrong expectation."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_AUTOTUNE_CACHE"] = "off"
        import sys
        sys.path.insert(0, {src!r})
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.serve import Server, Request
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import resolve_policy
        from repro.distributed.sharding import serve_cache_sharding
        from repro.analysis import jaxpr_audit as ja

        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        pol = resolve_policy(cfg, env={{}}, kernel_backend="pallas",
                             prefill_chunk=8)
        srv = Server(cfg, params, max_batch=2, max_seq=64,
                     mesh=make_host_mesh(1, 8), policy=pol, kv_mode="seq")
        assert srv.kv_axis is not None
        rng = np.random.default_rng(0)
        out = srv.run([Request(i, rng.integers(0, cfg.vocab, (p,),
                                               dtype=np.int32), 4)
                       for i, p in enumerate((21, 5))])
        g = srv._groups["default"]
        st = g.state
        want = serve_cache_sharding(cfg, srv.mesh, srv.kv_axis)
        args = (st.params_decode, jnp.zeros((2, 8), jnp.int32), st.data,
                jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32))
        msgs = ja.output_sharding_report(st._chunk, 1, want, *args)
        ja.assert_output_sharding(st._chunk, 1, want, *args)
        # the audit must actually discriminate: a wrong expectation
        # (head-axis sharding instead of the pool's seq axis) fails
        wrong = {{k: NamedSharding(srv.mesh,
                                   P(None, None, None, "model", None))
                  for k in want}}
        bad = ja.output_sharding_report(st._chunk, 1, wrong, *args)
        # the live pool ended chunked serving under the pool sharding
        # (produced in place by the chunk program, never re-placed)
        pool_in_place = all(
            st.data[k].sharding.is_equivalent_to(want[k], st.data[k].ndim)
            for k in ("k", "v"))
        print(json.dumps({{
            "chunks": len(g.chunk_s),
            "served": sorted(len(r.out) for r in out),
            "msgs": msgs, "bad_nonempty": bool(bad),
            "pool_in_place": pool_in_place}}))
    """).format(src=src)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["chunks"] >= 3          # prompts streamed across ticks
    assert res["served"] == [4, 4]
    assert res["msgs"] == []
    assert res["bad_nonempty"]
    assert res["pool_in_place"]


# --------------------------------------------------------- planted fixtures

class TestPlantedProgramViolations:
    def _carry_args(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = {"h": jnp.zeros((2, 4), jnp.float32),
                 "conv": jnp.zeros((2, 3), jnp.float32)}
        return (params, jnp.zeros((2, 1), jnp.int32), state,
                jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32))

    def test_dtype_drifting_carry_caught(self):
        bad = _load_fixture("bad_carry")
        args = self._carry_args()
        msgs = ja.carry_report(bad.drifting_step, args, {2: 1, 3: 2})
        assert any("dtype" in m and "bfloat16" in m for m in msgs)
        with pytest.raises(ja.CarryStabilityError, match="dtype"):
            ja.assert_carry_stable(bad.drifting_step, args, {2: 1, 3: 2})

    def test_shape_drifting_carry_caught(self):
        bad = _load_fixture("bad_carry")
        with pytest.raises(ja.CarryStabilityError, match="shape"):
            ja.assert_carry_stable(bad.shape_drifting_step,
                                   self._carry_args(), {2: 1, 3: 2})

    def test_clean_fixture_carry_is_stable(self):
        clean = _load_fixture("clean")
        args = self._carry_args()
        assert ja.carry_report(clean.stable_step, args, {2: 1, 3: 2}) == []

    def test_two_collective_program_caught(self):
        """shard_map on a 1-device mesh still lowers real collective ops,
        so the budget check needs no multi-device subprocess."""
        bad = _load_fixture("bad_collectives")
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
        x = jnp.arange(8, dtype=jnp.float32)
        two = bad.build_two_collective_step(mesh)
        assert ja.collective_counts(two, x) == {"all_reduce": 2}
        with pytest.raises(ja.CollectiveBudgetError):
            ja.assert_collective_budget(two, {"all_reduce": 1}, x)
        one = bad.build_one_collective_step(mesh)
        ja.assert_collective_budget(one, {"all_reduce": 1}, x)

    @pytest.mark.filterwarnings("ignore::UserWarning")
    def test_dropped_donation_caught(self):
        """The PR-5 failure mode in miniature: the output dtype no longer
        matches the donated input aval, so the donation silently drops —
        and the audit fails it."""
        def drift(s):
            return s.astype(jnp.bfloat16) * 2
        f = jax.jit(drift, donate_argnums=(0,))
        s = jnp.zeros((8,), jnp.float32)
        rep = ja.donation_report(f, (0,), s)
        assert rep.donated_leaves == 1 and rep.aliased_params == 0
        with pytest.raises(ja.DonationError):
            ja.assert_all_donated(f, (0,), s)

    def test_consumed_donation_passes(self):
        f = jax.jit(lambda s: s * 2, donate_argnums=(0,))
        ja.assert_all_donated(f, (0,), jnp.zeros((8,), jnp.float32))
