"""Recurrent-family serving through the family-agnostic DecodeState engine.

The headline guarantees, mirroring the transformer serving tests:

* slot-engine serving of ssm (mamba2) and hybrid (recurrentgemma) reduced
  configs is token-identical to solo decoding under all three exp
  backends, with mid-decode admission exercised;
* admission into a freed slot never sees the previous occupant's state
  (stale recurrent ``h``/``conv`` is read unconditionally every step, so
  the reset is load-bearing, unlike KV rows masked by cache_len);
* ragged right-padded prefill returns each row's state/logits at its
  *last real token* — bitwise equal to prefilling the row alone (ssm);
* ``ssm_layer_apply`` accepts arbitrary sequence lengths (chunk padding +
  dt masking replaced the old ``s % ssm_chunk == 0`` assert);
* ``init_cache(cfg, batch, seq_len)`` is family-uniform (the old
  ``ssm.init_state(cfg, batch)`` signature survives as a deprecation
  shim);
* ``launch/serve.py`` itself contains no family branch and no
  not-implemented escape hatch — the acceptance criterion, literally.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
import repro.models.ssm as ssm
from repro.launch.serve import Server, Request
from repro.runtime import resolve_policy

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")
ARCHS = {"ssm": "mamba2-1.3b", "hybrid": "recurrentgemma-9b"}


@pytest.fixture(scope="module")
def setups():
    out = {}
    for fam, arch in ARCHS.items():
        cfg = get_config(arch).reduced()
        out[fam] = (cfg, api.init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,), dtype=np.int32) for n in lens]


def _serve(cfg, params, prompts, idxs, *, max_new=5, max_batch=2,
           max_seq=64, policy=None):
    srv = Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                 policy=policy)
    reqs = [Request(i, prompts[i].copy(), max_new) for i in idxs]
    srv.run(reqs)
    return {r.rid: r.out for r in reqs}, srv


# ------------------------------------------------------- token identity

class TestTokenIdentity:
    @pytest.mark.parametrize("family", sorted(ARCHS))
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_mixed_lengths_match_solo(self, setups, family, exp):
        """2-request unequal-length batch == each request served alone,
        token for token, under every exp backend."""
        cfg, params = setups[family]
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        prompts = _prompts(cfg, (5, 11))
        together, _ = _serve(cfg, params, prompts, [0, 1], policy=pol)
        solo0, _ = _serve(cfg, params, prompts, [0], policy=pol)
        solo1, _ = _serve(cfg, params, prompts, [1], policy=pol)
        assert together[0] == solo0[0]
        assert together[1] == solo1[1]

    @pytest.mark.parametrize("family", sorted(ARCHS))
    def test_mid_decode_admission_matches_solo(self, setups, family):
        """3 requests through 2 slots: the third rides into a freed slot
        mid-decode and must still match solo serving token for token."""
        cfg, params = setups[family]
        prompts = _prompts(cfg, (5, 9, 7))
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        reqs = [Request(0, prompts[0].copy(), 2),
                Request(1, prompts[1].copy(), 6),
                Request(2, prompts[2].copy(), 4)]
        srv.run(reqs)
        assert srv.admit_log == [0, 1, 2]
        assert reqs[2].t_first > reqs[0].t_done   # actually mid-decode
        for i, r in enumerate(reqs):
            solo, _ = _serve(cfg, params, prompts, [i],
                             max_new=r.max_new)
            assert r.out == solo[i], i

    def test_ssm_engine_matches_raw_decode_loop(self, setups):
        """Engine serving == a raw api prefill + decode_step loop at the
        prompt's exact length (no engine, no bucketing) — the fixed-chunk
        SSD decomposition makes the bucket path bitwise equal to the
        unpadded ground truth."""
        cfg, params = setups["ssm"]
        prompt = _prompts(cfg, (7,))[0]
        engine, _ = _serve(cfg, params, [prompt], [0], max_new=5)
        logits, state = api.prefill(params, cfg,
                                    {"tokens": jnp.asarray(prompt[None])})
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(4):
            logits, state = api.decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), state,
                jnp.int32(0))
            toks.append(int(jnp.argmax(logits[0, 0])))
        assert engine[0] == toks

    def test_hybrid_pallas_decode_kernel(self, setups):
        """Hybrid decode under a pallas policy routes its local attention
        through the fused flash-decode kernel with per-slot (B,) lengths
        — tokens must still match solo serving."""
        cfg, params = setups["hybrid"]
        pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
        prompts = _prompts(cfg, (5, 11))
        together, _ = _serve(cfg, params, prompts, [0, 1], policy=pol)
        solo0, _ = _serve(cfg, params, prompts, [0], policy=pol)
        solo1, _ = _serve(cfg, params, prompts, [1], policy=pol)
        assert together[0] == solo0[0]
        assert together[1] == solo1[1]

    def test_policy_groups_isolated(self, setups):
        """Per-request policy groups on a recurrent family: the exact
        group's tokens equal a pure-exact server's (the vexp group's gate
        exponentials never contaminate them), and vice versa."""
        cfg, params = setups["ssm"]
        groups = {"eval": resolve_policy(cfg, env={}, exp_backend="exact"),
                  "bulk": resolve_policy(cfg, env={}, exp_backend="vexp")}
        prompts = _prompts(cfg, (5, 11))
        srv = Server(cfg, params, max_batch=2, max_seq=64,
                     policy_groups=groups)
        reqs = [Request(0, prompts[0].copy(), 5, group="eval"),
                Request(1, prompts[1].copy(), 5, group="bulk")]
        srv.run(reqs)
        pure_exact, _ = _serve(cfg, params, prompts, [0],
                               policy=groups["eval"])
        pure_vexp, _ = _serve(cfg, params, prompts, [1],
                              policy=groups["bulk"])
        assert reqs[0].out == pure_exact[0]
        assert reqs[1].out == pure_vexp[1]


# ------------------------------------------------- freed-slot state reset

class TestFreedSlotReset:
    @pytest.mark.parametrize("family", sorted(ARCHS))
    def test_admission_into_freed_slot_no_state_bleed(self, setups, family):
        """A request admitted into a freed slot must produce exactly the
        tokens it gets on a fresh server — the previous occupant's
        h/conv (and cache rows) must not leak through."""
        cfg, params = setups[family]
        prompts = _prompts(cfg, (11, 6))
        srv = Server(cfg, params, max_batch=1, max_seq=64)
        reqs = [Request(0, prompts[0].copy(), 6),
                Request(1, prompts[1].copy(), 5)]
        srv.run(reqs)      # r1 reuses r0's only slot
        fresh, _ = _serve(cfg, params, [prompts[1]], [0], max_new=5,
                          max_batch=1)
        assert reqs[1].out == fresh[0]

    @pytest.mark.parametrize("family", sorted(ARCHS))
    def test_recurrent_state_donated(self, setups, family):
        """The decode step donates the whole state pytree + positions for
        recurrent families too (in-place carried state, zero per-step
        re-allocation) — this regressed silently before: ssm's decode
        returned its conv state in compute dtype, flipping the carried
        pytree's dtype after step one and defeating donation."""
        cfg, params = setups[family]
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        srv.submit(Request(0, _prompts(cfg, (5,))[0], 8))
        g = srv._groups["default"]
        g.admit()
        before = jax.tree.leaves(g.state.data) + [g.state.pos_dev]
        g.decode_once()
        for leaf in before:
            assert leaf.is_deleted(), "state buffer was re-allocated"
        srv.drain()

    def test_finish_zeroes_recurrent_slot_state(self, setups):
        """reset_slots: a finished slot's recurrent state rows are zeroed
        (they are read unconditionally every step, unlike KV rows)."""
        cfg, params = setups["ssm"]
        prompts = _prompts(cfg, (7,))
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        srv.submit(Request(0, prompts[0].copy(), 3))
        g = srv._groups["default"]
        g.admit()
        g.decode_once()
        assert not np.allclose(np.asarray(g.state.data["h"][:, 0]), 0.0)
        g.decode_once()    # finishes the request -> reset_slots([0])
        assert g.reqs[0] is None
        assert (np.asarray(g.state.data["h"][:, 0]) == 0).all()
        assert (np.asarray(g.state.data["conv"][:, 0]) == 0).all()
        assert int(g.state.pos_dev[0]) == 0


# ------------------------------------------------------- ragged prefill

class TestRaggedPrefill:
    def test_ssm_prompt_len_matches_solo_bitwise(self, setups):
        """api.prefill with prompt_len: per-row logits AND per-row
        (h, conv) states equal prefilling each row alone at its exact
        length — bitwise (dt-masked pads contribute exactly 0 and the
        chunk decomposition is width-independent)."""
        cfg, params = setups["ssm"]
        prompts = _prompts(cfg, (5, 11))
        toks = np.zeros((2, 16), np.int32)
        toks[0, :5], toks[1, :11] = prompts[0], prompts[1]
        lb, sb = api.prefill(params, cfg,
                             {"tokens": jnp.asarray(toks),
                              "prompt_len": jnp.array([5, 11])})
        for i, p in enumerate(prompts):
            ls, ss = api.prefill(params, cfg,
                                 {"tokens": jnp.asarray(p[None])})
            np.testing.assert_array_equal(np.asarray(lb[i, 0]),
                                          np.asarray(ls[0, 0]))
            for leaf in ("h", "conv"):
                np.testing.assert_array_equal(
                    np.asarray(sb[leaf][:, i]), np.asarray(ss[leaf][:, 0]),
                    err_msg=f"row {i} {leaf}")

    def test_hybrid_prompt_len_matches_solo(self, setups):
        """Hybrid ragged prefill: per-row last-real-token logits match a
        solo prefill padded to the same width (the RG-LRU scan length is
        part of the fp contract, so compare at equal widths)."""
        cfg, params = setups["hybrid"]
        prompts = _prompts(cfg, (5, 11))
        toks = np.zeros((2, 16), np.int32)
        toks[0, :5], toks[1, :11] = prompts[0], prompts[1]
        lb, cb = api.prefill(params, cfg,
                             {"tokens": jnp.asarray(toks),
                              "prompt_len": jnp.array([5, 11])})
        for i, p in enumerate(prompts):
            solo = np.zeros((1, 16), np.int32)
            solo[0, :len(p)] = p
            ls, _ = api.prefill(params, cfg,
                                {"tokens": jnp.asarray(solo),
                                 "prompt_len": jnp.array([len(p)])})
            np.testing.assert_array_equal(np.asarray(lb[i, 0]),
                                          np.asarray(ls[0, 0]))
        # pad K/V rows are zeroed (freed-slot hygiene)
        k = np.asarray(cb["periods"]["k"], np.float32)
        assert (k[:, 0, 5:] == 0).all() and (k[:, 1, 11:] == 0).all()

    def test_hybrid_pool_smaller_than_window_length_caps(self, setups):
        """A pool allocated below the sliding window (max_seq < window)
        cannot wrap its ring buffer (the write cursor is pos % window,
        which runs past the pool) — slots must stop at capacity with
        "length_cap" instead of silently dropping K/V writes and
        attending a frozen window."""
        cfg, params = setups["hybrid"]
        assert cfg.sliding_window == 16
        srv = Server(cfg, params, max_batch=1, max_seq=8)
        r = Request(0, _prompts(cfg, (5,))[0], 50)
        srv.run([r])
        # 1 prefill token + decode writes at positions 5..7
        assert len(r.out) == 4
        assert r.finish_reason == "length_cap"
        # full-window pools keep decoding through the ring unbounded
        srv2 = Server(cfg, params, max_batch=1, max_seq=64)
        r2 = Request(0, _prompts(cfg, (5,))[0], 30)
        srv2.run([r2])
        assert len(r2.out) == 30 and r2.finish_reason == "max_new"

    def test_hybrid_ragged_over_window_rejected(self, setups):
        cfg, params = setups["hybrid"]
        assert cfg.sliding_window
        s = cfg.sliding_window * 2
        with pytest.raises(ValueError):
            api.prefill(params, cfg,
                        {"tokens": jnp.zeros((1, s), jnp.int32),
                         "prompt_len": jnp.array([4])})


# ------------------------------------------- arbitrary-length SSD prefill

class TestChunkPadding:
    def test_non_multiple_length_matches_sequential(self):
        """s not divisible by ssm_chunk no longer crashes and equals the
        naive per-step recurrence (the old assert rejected it)."""
        cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(),
                                  exp_impl="exact", ssm_chunk=8)
        b, s = 2, 13
        p = ssm.ssm_layer_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                              jnp.float32) * 0.5
        y = ssm.ssm_layer_apply(x, p, cfg)
        assert y.shape == (b, s, cfg.d_model)
        di, nh, ds, ng, conv_dim = ssm.ssm_dims(cfg)
        state = {"h": jnp.zeros((b, nh, cfg.ssm_headdim, ds)),
                 "conv": jnp.zeros((b, cfg.conv_width - 1, conv_dim))}
        ys = []
        for t in range(s):
            yt, state = ssm.ssm_layer_decode(x[:, t:t + 1], p, cfg, state)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jnp.concatenate(ys, 1)),
                                   atol=2e-3, rtol=2e-3)

    def test_prefill_state_continues_decode_at_odd_length(self):
        """Prefill at a non-chunk-multiple length, then one decode step,
        equals the full pass over s+1 tokens."""
        cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(),
                                  exp_impl="exact", ssm_chunk=8)
        p = ssm.ssm_layer_init(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 14, cfg.d_model),
                              jnp.float32) * 0.5
        y_full = ssm.ssm_layer_apply(x, p, cfg)
        _, st = ssm.ssm_layer_apply(x[:, :13], p, cfg, return_state=True)
        y_last, _ = ssm.ssm_layer_decode(x[:, 13:14], p, cfg, st)
        np.testing.assert_allclose(np.asarray(y_full[:, 13]),
                                   np.asarray(y_last[:, 0]),
                                   atol=2e-3, rtol=2e-3)

    def test_width_invariance_bitwise(self):
        """The same row right-padded to different widths produces
        identical outputs/state bit for bit — the property the serving
        engine's pow2 admission buckets rely on."""
        cfg = get_config("mamba2-1.3b").reduced()
        p = ssm.ssm_layer_init(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 5, cfg.d_model),
                              jnp.float32) * 0.5
        plen = jnp.array([5])
        y8, st8 = ssm.ssm_layer_apply(
            jnp.pad(x, ((0, 0), (0, 3), (0, 0))), p, cfg,
            return_state=True, prompt_len=plen)
        y32, st32 = ssm.ssm_layer_apply(
            jnp.pad(x, ((0, 0), (0, 27), (0, 0))), p, cfg,
            return_state=True, prompt_len=plen)
        np.testing.assert_array_equal(np.asarray(y8[:, :5]),
                                      np.asarray(y32[:, :5]))
        for leaf in ("h", "conv"):
            np.testing.assert_array_equal(np.asarray(st8[leaf]),
                                          np.asarray(st32[leaf]))


# ------------------------------------------------- uniform init_cache api

class TestInitCacheUnification:
    def test_family_uniform_signature(self, setups):
        for fam in sorted(ARCHS):
            cfg, _ = setups[fam]
            state = api.init_cache(cfg, 3, 32)
            for leaf in jax.tree.leaves(state):
                assert leaf.ndim >= 2

    def test_ssm_init_state_deprecation_shim(self, setups):
        cfg, _ = setups["ssm"]
        with pytest.warns(DeprecationWarning):
            old = ssm.init_state(cfg, 2)
        new = ssm.init_cache(cfg, 2, 64)
        assert jax.tree.structure(old) == jax.tree.structure(new)
        for a, b in zip(jax.tree.leaves(old), jax.tree.leaves(new)):
            assert a.shape == b.shape and a.dtype == b.dtype


# ------------------------------------------------- engine source contract

def test_serve_source_is_family_agnostic():
    """The acceptance criterion, as an AST rule: the analyzer's
    engine-family-branch contract flags any ``*.family`` attribute
    access and any NotImplemented escape hatch in the slot engine —
    stronger than the old source-string grep (no false pass if the
    branch is spelled ``self.cfg.family``), and the same rule CI runs
    via `make analyze`."""
    import repro.launch.serve as serve_mod
    from repro.analysis.rules import EngineContractRule, run_rules
    findings, n_files = run_rules([serve_mod.__file__],
                                  rules=[EngineContractRule()])
    assert n_files == 1
    assert findings == [], "\n".join(f.render() for f in findings)


def test_decode_state_kinds():
    from repro.models.decode_state import (decode_state_for, KVDecodeState,
                                           RecurrentDecodeState,
                                           HybridDecodeState)
    assert decode_state_for(get_config("gpt2-small")) is KVDecodeState
    assert decode_state_for(get_config("mamba2-1.3b")) \
        is RecurrentDecodeState
    assert decode_state_for(get_config("recurrentgemma-9b")) \
        is HybridDecodeState
    with pytest.raises(ValueError):
        decode_state_for(get_config("hubert-xlarge"))
    # the SPMD serve loop is a linear-KV-only capability, probed through
    # the protocol (not the family)
    assert KVDecodeState.supports_seq_sharding(get_config("gpt2-small"))
    assert not KVDecodeState.supports_seq_sharding(
        get_config("h2o-danube3-4b"))      # windowed: ring wrap straddles
    assert not RecurrentDecodeState.supports_seq_sharding(
        get_config("mamba2-1.3b"))
    assert not HybridDecodeState.supports_seq_sharding(
        get_config("recurrentgemma-9b"))
