"""Property tests (hypothesis) for the paged-KV host bookkeeping:
``models.block_pool.BlockAllocator`` (refcounted free-list page allocator,
optionally partitioned for sequence-sharded pools) and ``PrefixCache``
(content-addressed full-page prompt sharing with LRU leaf eviction).

Everything here is pure host-side numpy — no jax programs — so the suite
sweeps many random traces cheaply. The allocator's ``check()`` verifies
the structural invariants (free pages have no refs, no page is both free
and live, nothing leaks) after every trace."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.block_pool import (BlockAllocator, BlockPoolError,
                                     OutOfBlocks, PrefixCache)


# ---------------------------------------------------------------- allocator

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.integers(0, 2 ** 31 - 1))
def test_alloc_free_roundtrip_any_trace(per_part, seed):
    """A random alloc/incref/decref trace never corrupts the allocator:
    refcounts and free lists stay consistent, and releasing every
    outstanding reference returns the pool to fully free."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(per_part)
    held = []                          # one entry per outstanding reference
    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:                    # allocate one page
            try:
                held.append(alloc.alloc_cols([0])[0])
            except OutOfBlocks:
                assert alloc.n_free() == 0
        elif op == 1 and held:         # share an existing reference
            gid = held[int(rng.integers(len(held)))]
            alloc.incref(gid)
            held.append(gid)
        elif op == 2 and held:         # drop a reference
            gid = held.pop(int(rng.integers(len(held))))
            alloc.decref(gid)
        alloc.check()
        # refcounts must equal the references this trace holds
        for g in set(held):
            assert alloc.refcount(g) == held.count(g)
    for gid in held:
        alloc.decref(gid)
    alloc.check()
    assert alloc.n_free() == per_part - 1 and alloc.n_used() == 0


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 16))
def test_double_free_and_scratch_free_raise(per_part):
    alloc = BlockAllocator(per_part)
    gid = alloc.alloc_cols([0])[0]
    alloc.decref(gid)
    with pytest.raises(BlockPoolError):
        alloc.decref(gid)              # double free
    with pytest.raises(BlockPoolError):
        alloc.decref(alloc.scratch_id())   # the reserved page is untouchable
    with pytest.raises(BlockPoolError):
        alloc.incref(gid)              # incref of an unallocated page
    alloc.check()


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_partitioned_alloc_cols_respects_ownership(per_part, n_parts):
    """Sharded pools: every page allocated for table column ``c`` must
    come from the partition owning that column slice, and all-or-nothing
    allocation rolls back cleanly on partition exhaustion."""
    cols_per_part = 3
    alloc = BlockAllocator(per_part * n_parts, n_partitions=n_parts,
                           cols_per_part=cols_per_part)
    cols = list(range(n_parts * cols_per_part))
    if alloc.can_alloc_cols(cols):
        got = alloc.alloc_cols(cols)
        for c, gid in zip(cols, got):
            assert alloc.part_of(gid) == c // cols_per_part
        for gid in got:
            alloc.decref(gid)
    # exhaust partition 0, then ask for more than it has: nothing sticks
    free0 = int(alloc.free_counts()[0])
    with pytest.raises(OutOfBlocks):
        alloc.alloc_cols([0] * (free0 + 1))
    assert int(alloc.free_counts()[0]) == free0     # rollback complete
    alloc.check()


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 16), st.integers(2, 5))
def test_cow_never_touches_the_shared_page(per_part, sharers):
    """Copy-on-write: a writer holding a shared page gets a FRESH page
    (same partition), the shared page keeps its other references, and an
    exclusively-held page is returned as-is (no copy)."""
    alloc = BlockAllocator(per_part)
    gid = alloc.alloc_cols([0])[0]
    for _ in range(sharers - 1):
        alloc.incref(gid)
    new = alloc.cow(gid)
    assert new != gid                       # shared -> private clone
    assert alloc.part_of(new) == alloc.part_of(gid)
    assert alloc.refcount(gid) == sharers - 1   # writer's ref moved off
    assert alloc.refcount(new) == 1
    alloc.check()
    # exclusive page: write in place
    assert alloc.cow(new) == new
    assert alloc.refcount(new) == 1


def test_cow_under_eviction_pressure_frees_last_ref():
    """cow on an exhausted pool: _alloc_one's eviction hook can drop the
    cache's reference on the very page being cloned, making the writer's
    release the LAST reference — the page must hit the free list, not
    leak with refcount 0."""
    page = 4
    alloc = BlockAllocator(3)              # 2 allocatable
    cache = PrefixCache(alloc, page)
    rng = np.random.default_rng(2)
    g = _prompt(rng, page)                 # chain G: 1 page
    x = _prompt(rng, page)                 # chain X: 1 page
    gid_g = alloc.alloc_cols([0])[0]       # the writer's page...
    cache.insert(g, 0, gid_g)              # ...also cached: refcount 2
    gid_x = alloc.alloc_cols([0])[0]
    cache.insert(x, 0, gid_x)
    alloc.decref(gid_x)                    # X cache-only, NEWER than G
    # pool exhausted; the writer clones its shared page. Eviction walks
    # LRU order: G's entry goes first (drops the cache ref, frees
    # nothing), then X (frees the page the clone takes). The writer's
    # release of gid_g is now the last reference.
    new = alloc.cow(gid_g)
    assert new == gid_x                    # clone landed on X's freed page
    alloc.check()                          # raw decrement leaked gid_g here
    assert alloc.refcount(gid_g) == 0
    alloc.decref(new)
    alloc.check()
    assert alloc.n_free() == 2 and alloc.n_used() == 0


def test_reset_returns_every_page():
    """A full-reservation slot release (decref of its whole table) puts
    every non-shared page back on the free list."""
    alloc = BlockAllocator(16)
    tabs = [alloc.alloc_cols(range(5)) for _ in range(3)]
    assert alloc.n_free() == 15 - 15
    for tab in tabs:
        for gid in tab:
            alloc.decref(gid)
    assert alloc.n_free() == 15 and alloc.n_used() == 0
    alloc.check()


# ------------------------------------------------------------- prefix cache

def _prompt(rng, n):
    return rng.integers(0, 997, (n,), dtype=np.int32)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_prefix_cache_probe_attach_insert(page, seed):
    """insert -> probe/attach round-trip: a prompt re-seen after caching
    attaches to exactly its full pages, each attach incref'ing the page;
    a diverging prompt attaches only through the common full-page prefix."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(64)
    cache = PrefixCache(alloc, page)
    prompt = _prompt(rng, page * 3 + page // 2)     # 3 full pages + tail
    gids = alloc.alloc_cols(range(4))
    for i in range(3):
        assert cache.insert(prompt, i, gids[i])
    assert cache.probe(prompt) == 3
    got = cache.attach(prompt)
    assert got == gids[:3]
    for g in got:
        assert alloc.refcount(g) == 3   # slot + cache + attacher
    # divergence inside page 1: only page 0 is shared
    fork = prompt.copy()
    fork[page + 1] = (fork[page + 1] + 1) % 997
    assert cache.probe(fork) == 1
    assert cache.attach(fork) == gids[:1]
    # re-inserting an already-cached position takes no extra reference
    before = alloc.refcount(gids[0])
    assert not cache.insert(prompt, 0, gids[0])
    assert alloc.refcount(gids[0]) == before
    alloc.check()


def test_prefix_cache_eviction_is_lru_leaf_first():
    """Pressure evicts least-recently-used LEAF entries (chain tails), so
    interior pages never orphan their descendants; live-slot pages lose
    only the cache's reference and stay allocated."""
    page = 4
    alloc = BlockAllocator(8)          # 7 allocatable
    cache = PrefixCache(alloc, page)
    rng = np.random.default_rng(0)
    a = _prompt(rng, page * 2)         # chain A: 2 pages
    b = _prompt(rng, page * 2)         # chain B: 2 pages
    ga = alloc.alloc_cols(range(2))
    gb = alloc.alloc_cols(range(2))
    for i in range(2):
        cache.insert(a, i, ga[i])
        cache.insert(b, i, gb[i])
    cache.attach(a)                    # A is hot; also: a live slot holds A
    for g in ga + gb:
        alloc.decref(g)                # admitting slots released
    assert alloc.n_free() == 3
    # demand 4 fresh pages: eviction is lazy (one page at a time) and must
    # pick the cold chain B's TAIL first — never A (hot) and never an
    # interior page before its descendant.
    got = alloc.alloc_cols(range(4))
    assert len(got) == 4
    assert cache.evictions == 1 and cache.probe(b) == 1
    # one more: B's root goes next (now a leaf)
    got += alloc.alloc_cols([0])
    assert cache.probe(b) == 0 and cache.probe(a) == 2
    # pool exhausted and only live-slot pages remain cached: the cache
    # gives up its references (A's entries go tail-first) but the pages
    # stay allocated — live state is NEVER evicted, allocation fails.
    with pytest.raises(OutOfBlocks):
        alloc.alloc_cols([0])
    assert all(alloc.refcount(g) == 1 for g in ga)   # attach refs survive
    for g in got + ga:
        alloc.decref(g)
    alloc.check()
    assert alloc.n_free() == 7


def test_starved_partition_spares_unrelated_chains():
    """Eviction for a starved partition must not drain chains that never
    reach it: a chain confined to partition 0's columns cannot relieve
    partition 1, so exhausting partition 1 fails WITHOUT stripping the
    partition-0 chain from the cache."""
    page = 4
    alloc = BlockAllocator(8, n_partitions=2, cols_per_part=3)
    cache = PrefixCache(alloc, page)
    rng = np.random.default_rng(3)
    p = _prompt(rng, page * 2)             # 2 pages: columns 0-1, part 0
    gids = alloc.alloc_cols([0, 1])
    for i in range(2):
        cache.insert(p, i, gids[i])
    for g in gids:
        alloc.decref(g)                    # cache-only chain in partition 0
    held = alloc.alloc_cols([3, 4, 5])     # exhaust partition 1
    with pytest.raises(OutOfBlocks):
        alloc.alloc_cols([3])
    assert cache.probe(p) == 2, "unrelated chain was drained"
    for g in held:
        alloc.decref(g)
    cache.drop_all()
    alloc.check()


def test_cross_partition_peel_reaches_starved_partition():
    """The converse: a chain that spans partitions IS peeled from its
    deepest (later-partition) leaf down, until a page of the starved
    partition frees — cross-partition eviction bounded to chains that
    actually pass through the shortage."""
    page = 4
    alloc = BlockAllocator(8, n_partitions=2, cols_per_part=3)
    cache = PrefixCache(alloc, page)
    rng = np.random.default_rng(4)
    p = _prompt(rng, page * 4)             # 4 pages: columns 0-3, parts 0+1
    gids = alloc.alloc_cols([0, 1, 2, 3])
    for i in range(4):
        cache.insert(p, i, gids[i])
    for g in gids:
        alloc.decref(g)                    # partition 0 fully cached
    got = alloc.alloc_cols([0])            # starve partition 0
    assert alloc.part_of(got[0]) == 0
    assert cache.probe(p) == 2             # tail peeled through part 1
    alloc.decref(got[0])
    cache.drop_all()
    alloc.check()


def test_prefix_cache_drop_all_releases_everything():
    page = 4
    alloc = BlockAllocator(16)
    cache = PrefixCache(alloc, page)
    rng = np.random.default_rng(1)
    p = _prompt(rng, page * 4)
    gids = alloc.alloc_cols(range(4))
    for i in range(4):
        cache.insert(p, i, gids[i])
    for g in gids:
        alloc.decref(g)                # slot gone; cache holds the chain
    assert alloc.n_used() == 4
    cache.drop_all()
    assert alloc.n_used() == 0 and alloc.n_free() == 15
    alloc.check()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_prefix_cache_random_trace_invariants(seed):
    """Random interleaving of insert/attach/evict/release keeps the
    allocator consistent and the cache's chains walkable (probe never
    sees a gap: if page i hits, pages 0..i-1 hit too)."""
    rng = np.random.default_rng(seed)
    page = 4
    alloc = BlockAllocator(24)
    cache = PrefixCache(alloc, page)
    prompts = [_prompt(rng, page * int(rng.integers(1, 4))) for _ in range(4)]
    held = []
    for _ in range(40):
        op = rng.integers(0, 3)
        p = prompts[int(rng.integers(len(prompts)))]
        n_full = len(p) // page
        if op == 0:                    # admit: attach hits, alloc the rest
            h = cache.probe(p)
            try:
                fresh = alloc.alloc_cols(range(h, n_full))
            except OutOfBlocks:
                continue
            gids = cache.attach(p, max_pages=h) + fresh
            for i in range(h, n_full):
                cache.insert(p, i, gids[i])
            held.append(gids)
        elif op == 1 and held:         # finish: release a random slot
            for g in held.pop(int(rng.integers(len(held)))):
                alloc.decref(g)
        else:                          # chain walkability under any state
            hits = [h in cache._entries for h in cache.chain(p)]
            assert hits == sorted(hits, reverse=True), "gap in cached chain"
        alloc.check()
    for gids in held:
        for g in gids:
            alloc.decref(g)
    cache.drop_all()
    assert alloc.n_used() == 0


# ------------------------------------------------------------- chaos storms

def _held_counts(held):
    counts = {}
    for gids in held:
        for g in gids:
            counts[g] = counts.get(g, 0) + 1
    return counts


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 32))
def test_chaos_storm_conserves_refcounts(seed, n_pages):
    """PR-9 chaos storm: random admit/share/cow/release traffic with a
    seeded FaultInjector wired into the allocator (forced OutOfBlocks)
    and corrupting cached prefix chains mid-flight. After EVERY op the
    allocator stays structurally consistent and each page's refcount is
    exactly slot-holds + cache-holds; at the end nothing leaks."""
    from repro.ft import FaultInjector

    rng = np.random.default_rng(seed)
    inj = FaultInjector(seed=seed, rates={"alloc.out_of_blocks": 0.15,
                                          "prefix.corrupt": 0.10})
    page = 4
    alloc = BlockAllocator(n_pages)
    alloc.injector = inj
    cache = PrefixCache(alloc, page)
    prompts = [_prompt(rng, page * int(rng.integers(1, 5)))
               for _ in range(5)]
    held = []                          # one gid-list per live "slot"
    for _ in range(80):
        op = int(rng.integers(0, 4))
        if op == 0:                    # admit: attach shared prefix, alloc rest
            p = prompts[int(rng.integers(len(prompts)))]
            n_full = len(p) // page
            h = cache.probe(p)
            got = cache.attach(p, max_pages=h)   # pin refs before eviction
            try:
                fresh = alloc.alloc_cols(range(h, n_full))
            except OutOfBlocks:        # injected or real: all-or-nothing
                for g in got:
                    alloc.decref(g)
            else:
                gids = got + fresh
                for i in range(h, n_full):
                    # eviction during alloc_cols may have peeled the
                    # chain below h; only extend a still-walkable chain
                    if cache.probe(p) >= i:
                        cache.insert(p, i, gids[i])
                held.append(gids)
        elif op == 1 and held:         # finish/abort a random slot
            for g in held.pop(int(rng.integers(len(held)))):
                alloc.decref(g)
        elif op == 2 and held:         # cow write on a random held page
            slot = held[int(rng.integers(len(held)))]
            k = int(rng.integers(len(slot)))
            try:
                slot[k] = alloc.cow(slot[k])
            except OutOfBlocks:
                pass
        elif inj.fire("prefix.corrupt"):   # detected corruption: drop chains
            cache.invalidate(n=1 + int(rng.integers(3)), rng=inj.rng)
        alloc.check()
        holds = _held_counts(held)
        cached = {}
        for gid, _, _ in cache._entries.values():
            cached[gid] = cached.get(gid, 0) + 1
        for g in set(holds) | set(cached):
            assert alloc.refcount(g) == holds.get(g, 0) + cached.get(g, 0)
    for gids in held:
        for g in gids:
            alloc.decref(g)
    cache.drop_all()
    alloc.check()
    assert alloc.n_used() == 0 and alloc.n_free() == n_pages - 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_chaos_storm_partitioned_pool(seed):
    """Same storm against a sequence-sharded (partitioned) allocator:
    injected allocation faults in one partition never corrupt another,
    and the all-or-nothing alloc_cols rollback holds under injection."""
    from repro.ft import FaultInjector

    rng = np.random.default_rng(seed)
    inj = FaultInjector(seed=seed, rates={"alloc.out_of_blocks": 0.2})
    alloc = BlockAllocator(24, n_partitions=2, cols_per_part=3)
    alloc.injector = inj
    held = []
    for _ in range(60):
        op = int(rng.integers(0, 2))
        if op == 0:
            cols = list(range(int(rng.integers(1, 6))))
            before = alloc.free_counts().copy()
            try:
                held.append(alloc.alloc_cols(cols))
            except OutOfBlocks:
                assert (alloc.free_counts() == before).all(), \
                    "injected fault broke alloc_cols rollback"
        elif held:
            for g in held.pop(int(rng.integers(len(held)))):
                alloc.decref(g)
        alloc.check()
    for gids in held:
        for g in gids:
            alloc.decref(g)
    alloc.check()
    assert alloc.n_used() == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(16, 48))
def test_speculative_rollback_storm_conserves_refcounts(seed, n_pages):
    """PR-10 rollback storm: speculative decode appends up to W = spec_k+1
    tokens per burst and rewinds the rejected suffix, under the paged
    pool's full-reservation contract — every page a slot can EVER touch
    is allocated at admission, so a burst (append then partial rollback,
    including the dangerous page-straddling rewind where the cursor
    crosses a page boundary) must leave the allocator bitwise untouched:
    no page freed (the accepted prefix keeps its pages; the rejected
    suffix's pages stay reserved for the next burst), no page allocated,
    no refcount moved. Interleaved cow/finish/invalidate traffic and a
    seeded FaultInjector keep the exact-conservation invariant honest
    after every op."""
    from repro.ft import FaultInjector

    rng = np.random.default_rng(seed)
    inj = FaultInjector(seed=seed, rates={"alloc.out_of_blocks": 0.15,
                                          "prefix.corrupt": 0.10})
    page = 4
    spec_w = 6                          # draft burst width (> page: straddles)
    alloc = BlockAllocator(n_pages)
    alloc.injector = inj
    cache = PrefixCache(alloc, page)
    prompts = [_prompt(rng, page * int(rng.integers(1, 4)))
               for _ in range(4)]
    slots = []           # {"gids": full reservation, "cur": token cursor}
    for _ in range(100):
        op = int(rng.integers(0, 5))
        if op == 0:                    # admit under FULL reservation
            p = prompts[int(rng.integers(len(prompts)))]
            room = int(rng.integers(1, 3)) * page   # decode growth budget
            ns = -(-(len(p) + room) // page)        # ceil: whole table now
            n_full = len(p) // page
            h = cache.probe(p)
            got = cache.attach(p, max_pages=h)
            try:
                fresh = alloc.alloc_cols(range(h, ns))
            except OutOfBlocks:        # all-or-nothing admission
                for g in got:
                    alloc.decref(g)
            else:
                gids = got + fresh
                for i in range(h, n_full):
                    if cache.probe(p) >= i:
                        cache.insert(p, i, gids[i])
                slots.append({"gids": gids, "cur": len(p)})
        elif op == 1 and slots:        # speculative burst: append + rollback
            slot = slots[int(rng.integers(len(slots)))]
            cap = len(slot["gids"]) * page
            w = int(min(rng.integers(1, spec_w + 1), cap - slot["cur"]))
            if w > 0:
                snap = slot["cur"]
                before_free = alloc.n_free()
                before_refs = {g: alloc.refcount(g)
                               for g in set(slot["gids"])}
                slot["cur"] += w               # multi-token draft append
                # exact verify accepts m, rejects the rest: cursor rewind
                # IS the rollback — often straddling back across a page
                # boundary. The allocator must not notice any of it.
                m = int(rng.integers(0, w + 1))
                slot["cur"] = snap + m
                assert -(-slot["cur"] // page) <= len(slot["gids"]), \
                    "cursor escaped the full reservation"
                assert alloc.n_free() == before_free, \
                    "burst/rollback freed or allocated a page"
                for g, r in before_refs.items():
                    assert alloc.refcount(g) == r, \
                        "burst/rollback moved a reserved page's refcount"
        elif op == 2 and slots:        # finish: release the whole table
            for g in slots.pop(int(rng.integers(len(slots))))["gids"]:
                alloc.decref(g)
        elif op == 3 and slots:        # cow a shared page under the cursor
            slot = slots[int(rng.integers(len(slots)))]
            k = int(rng.integers(len(slot["gids"])))
            try:
                slot["gids"][k] = alloc.cow(slot["gids"][k])
            except OutOfBlocks:
                pass
        elif inj.fire("prefix.corrupt"):   # detected corruption: drop chains
            cache.invalidate(n=1 + int(rng.integers(3)), rng=inj.rng)
        alloc.check()
        holds = _held_counts([s["gids"] for s in slots])
        cached = {}
        for gid, _, _ in cache._entries.values():
            cached[gid] = cached.get(gid, 0) + 1
        for g in set(holds) | set(cached):
            assert alloc.refcount(g) == holds.get(g, 0) + cached.get(g, 0)
    for s in slots:
        for g in s["gids"]:
            alloc.decref(g)
    cache.drop_all()
    alloc.check()
    assert alloc.n_used() == 0 and alloc.n_free() == n_pages - 1


def test_page_straddling_rollback_frees_nothing():
    """The single dangerous case, deterministically: a slot whose cursor
    sits one token into page 2 drafts W=4 tokens (crossing into page 3)
    and has them ALL rejected. The rewind crosses a page boundary
    backwards; a naive rollback would free the straddled page (still
    covering reserved-but-unwritten columns) and a later burst would
    write into a page the allocator re-issued to another slot. Under
    full reservation the rollback must not touch the allocator at all."""
    page, ns = 4, 4
    alloc = BlockAllocator(16)
    other = alloc.alloc_cols(range(2))          # a neighbour slot
    gids = alloc.alloc_cols(range(ns))          # full reservation, cap=16
    cur = 2 * page + 1                          # one token into page 2
    snap = cur
    cur += 4                                    # draft burst -> page 3
    assert (cur - 1) // page == 3
    before = ([alloc.refcount(g) for g in gids], alloc.n_free())
    cur = snap                                  # verify rejects everything
    assert ([alloc.refcount(g) for g in gids], alloc.n_free()) == before
    alloc.check()
    # the next burst reuses the same reserved pages without allocating
    cur += 4
    assert -(-cur // page) <= ns and alloc.n_free() == before[1]
    for g in gids + other:
        alloc.decref(g)
    assert alloc.n_used() == 0


def test_injector_is_deterministic():
    """Two injectors with the same seed fire identically; a different
    seed diverges somewhere. (The replay contract behind
    REPRO_FAULT_SEED.)"""
    from repro.ft import FaultInjector, default_chaos_rates

    a = FaultInjector(seed=7, rates=default_chaos_rates())
    b = FaultInjector(seed=7, rates=default_chaos_rates())
    points = list(default_chaos_rates())
    rng = np.random.default_rng(0)
    trace = [points[int(rng.integers(len(points)))] for _ in range(300)]
    assert [a.fire(p) for p in trace] == [b.fire(p) for p in trace]
    assert a.stats() == b.stats()
    c = FaultInjector(seed=8, rates=default_chaos_rates())
    assert [c.fire(p) for p in trace] != [a.fire(p) for p in trace]
