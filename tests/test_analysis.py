"""repro.analysis Layer-1 tests: every planted fixture violation is
caught, the clean fixture stays quiet, the repo gate holds, and the
baseline machinery (justifications, step-strict rejection, staleness,
exit codes) behaves."""

import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import Severity, run_rules
from repro.analysis.baseline import (BaselineError, load_baseline,
                                     write_baseline)
from repro.analysis.cli import main, run_analysis
from repro.analysis.findings import Finding
from repro.analysis.registry import hot_path, is_hot_path
from repro.analysis.rules import canon_path

pytestmark = pytest.mark.analysis

FIX = Path(__file__).parent / "fixtures" / "analysis"
SRC = Path(__file__).parent.parent / "src"


def _findings(name):
    findings, n_files = run_rules([str(FIX / name)])
    assert n_files == 1
    return findings


def _details(findings, rule):
    return sorted(f.detail for f in findings if f.rule == rule)


# ------------------------------------------------------------ rule catches

class TestPlantedViolations:
    def test_host_sync_fixture(self):
        fs = _findings("bad_host_sync.py")
        assert _details(fs, "host-sync-in-hot-path") == [
            ".item()", ".tolist()", "int()", "jax.block_until_ready",
            "jax.device_get", "np.asarray"]
        by_detail = {f.detail: f for f in fs}
        # nested defs inherit hotness, reported under their own qualname
        assert by_detail[".tolist()"].symbol == "outer.inner"
        # int() may be a host scalar: warn, not error
        assert by_detail["int()"].severity is Severity.WARN
        assert by_detail[".item()"].severity is Severity.ERROR

    def test_host_sync_unmarked_and_literals_quiet(self):
        fs = _findings("bad_host_sync.py")
        # the same calls in an UNMARKED function are not findings, and
        # np.asarray on a literal comprehension is host-side by nature
        assert not [f for f in fs if f.symbol in ("cold_path",
                                                  "literal_ok")]

    def test_refcount_fixture(self):
        fs = _findings("bad_refcount.py")
        assert _details(fs, "refcount-pairing") == [
            "refs[...]-mutation", "unguarded-incref-loop"]
        syms = {f.detail: f.symbol for f in fs}
        assert syms["refs[...]-mutation"] == "LeakyPool.cow_leak"
        assert syms["unguarded-incref-loop"] == "LeakyPool.attach_leak"
        # the guarded loop and the primitives themselves stay quiet
        assert not [f for f in fs
                    if f.symbol in ("LeakyPool.attach_guarded",
                                    "LeakyPool.incref",
                                    "LeakyPool.decref")]

    def test_retrace_fixture(self):
        fs = _findings("bad_retrace.py")
        assert _details(fs, "jit-retrace-hazard") == [
            "lru_cache-array-arg", "mutable-default", "mutable-default"]
        syms = sorted(f.symbol for f in fs)
        assert syms == ["assigned_later", "cached_norm",
                        "jitted_mutable_default"]
        # hashable-config memoization is the blessed idiom
        assert not [f for f in fs if f.symbol == "cached_program"]

    def test_family_branch_fixture(self):
        fs = _findings("bad_family_branch.py")
        assert _details(fs, "engine-family-branch") == [
            ".family", "NotImplementedError"]

    def test_fallback_fixture(self):
        fs = _findings("bad_fallback.py")
        det = _details(fs, "silent-fallback")
        assert det == ["call-core_decode", "call-core_decode",
                       "if-layout", "if-window"]

    def test_slot_leak_fixture(self):
        fs = _findings("bad_slot_leak.py")
        assert _details(fs, "refcount-pairing") == ["unguarded-slot-reserve"]
        f = fs[0]
        assert f.symbol == "BadEngine.admit_chunked"
        assert f.severity is Severity.ERROR

    def test_snapshot_leak_fixture(self):
        fs = _findings("bad_snapshot_leak.py")
        assert _details(fs, "refcount-pairing") == \
            ["unguarded-spec-snapshot"] * 2
        assert {f.symbol for f in fs} == \
            {"BadSpecEngine.decode_spec_once",
             "BadSpecEngine.logging_is_not_a_guard"}
        assert all(f.severity is Severity.ERROR for f in fs)

    def test_spec_snapshot_guarded_in_engine(self):
        """The real speculative burst wraps snapshot..verify in a try
        whose handler routes through the step-fault recovery — the
        snapshot rule must see it as clean."""
        findings, _ = run_rules([str(SRC / "repro" / "launch"
                                     / "serve.py")])
        assert not [f for f in findings
                    if f.detail == "unguarded-spec-snapshot"]

    def test_slot_reserve_guarded_in_engine(self):
        """The real admission loop publishes reservations under a guard
        that aborts the chunk on the exception path — the slot rule must
        see it as clean (it applies to serve.py, so any regression in
        that structure fails the repo gate)."""
        findings, _ = run_rules([str(SRC / "repro" / "launch"
                                     / "serve.py")])
        assert not [f for f in findings
                    if f.detail == "unguarded-slot-reserve"]

    def test_clean_fixture_quiet(self):
        assert _findings("clean.py") == []


# ------------------------------------------------------------- repo gate

class TestRepoGate:
    def test_src_repro_is_green(self):
        """The acceptance criterion: the repo lints clean against its own
        (fully justified) baseline."""
        res = run_analysis([str(SRC / "repro")])
        assert not res.failed, "\n".join(f.render() for f in res.new)
        assert not res.stale

    def test_suppressions_are_scheduling_events_only(self):
        """Every baseline entry covers serve.py scheduling-event code —
        none touches a per-decode-step symbol (the loader enforces the
        step-strict list; this pins the current shape of the debt)."""
        base = load_baseline()
        assert base.entries, "baseline unexpectedly empty"
        for e in base.entries:
            assert e["path"] == "repro/launch/serve.py"
            assert e["symbol"] in ("_Group.admit", "_Group._finish")

    def test_decode_step_symbols_have_no_findings(self):
        """Stronger than suppression policy: the per-token symbols have
        zero findings at all, suppressed or not."""
        findings, _ = run_rules([str(SRC / "repro")])
        step_syms = [f for f in findings
                     if "decode_once" in f.symbol
                     or f.symbol.endswith(".step")
                     or f.symbol.startswith(("_programs.",
                                             "_paged_programs.",
                                             "decode_step"))]
        assert step_syms == []


# ----------------------------------------------------------- CLI contract

class TestCli:
    @pytest.mark.parametrize("name", [
        "bad_host_sync.py", "bad_refcount.py", "bad_retrace.py",
        "bad_family_branch.py", "bad_fallback.py", "bad_slot_leak.py",
        "bad_snapshot_leak.py"])
    def test_nonzero_on_each_planted_fixture(self, name):
        assert main([str(FIX / name), "--no-baseline"]) == 1

    def test_zero_on_clean_fixture(self):
        assert main([str(FIX / "clean.py"), "--no-baseline"]) == 0

    def test_zero_on_repo(self):
        assert main([str(SRC / "repro")]) == 0

    def test_module_entry_point(self):
        """`python -m repro.analysis src/repro` — the exact CI command."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(SRC / "repro")],
            capture_output=True, text=True,
            env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})
        assert out.returncode == 0, out.stdout + out.stderr
        assert "repro.analysis: ok" in out.stdout

    def test_list_rules(self, capsys):
        assert main(["--list-rules", "unused"]) == 0
        out = capsys.readouterr().out
        for rule in ("host-sync-in-hot-path", "refcount-pairing",
                     "jit-retrace-hazard", "engine-family-branch",
                     "silent-fallback"):
            assert rule in out


# ------------------------------------------------------ baseline mechanics

def _entry(f, reason="one sync per scheduling event by design"):
    return "\n".join([
        "", "[[suppress]]",
        f'rule = "{f.rule}"',
        f'path = "{canon_path(f.path)}"',
        f'symbol = "{f.symbol}"',
        f'detail = "{f.detail}"',
        f'reason = "{reason}"',
    ])


class TestBaseline:
    def test_suppression_and_staleness(self, tmp_path):
        fs = _findings("bad_refcount.py")
        ghost = Finding(rule="refcount-pairing", path="fixtures/analysis/"
                        "bad_refcount.py", line=0, symbol="gone",
                        detail="refs[...]-mutation", message="",
                        severity=Severity.ERROR)
        b = tmp_path / "b.toml"
        b.write_text("version = 1\n"
                     + "".join(_entry(f) for f in fs + [ghost]))
        # all findings suppressed -> 0; the ghost entry reported stale
        assert main([str(FIX / "bad_refcount.py"),
                     "--baseline", str(b)]) == 0
        res = run_analysis([str(FIX / "bad_refcount.py")],
                           baseline_path=str(b))
        assert not res.new and len(res.suppressed) == 2
        assert [e["symbol"] for e in res.stale] == ["gone"]

    def test_line_insensitive_identity(self):
        a = Finding(rule="r", path="p.py", line=10, symbol="f",
                    detail="d", message="m", severity=Severity.ERROR)
        b = Finding(rule="r", path="p.py", line=99, symbol="f",
                    detail="d", message="other", severity=Severity.WARN)
        assert a.key == b.key

    def test_placeholder_reason_is_config_error(self, tmp_path):
        fs = _findings("bad_refcount.py")
        b = tmp_path / "b.toml"
        b.write_text(_entry(fs[0], reason="TODO: justify"))
        with pytest.raises(BaselineError, match="placeholder"):
            load_baseline(str(b))
        assert main([str(FIX / "bad_refcount.py"),
                     "--baseline", str(b)]) == 2

    def test_missing_reason_is_config_error(self, tmp_path):
        b = tmp_path / "b.toml"
        b.write_text('[[suppress]]\nrule = "r"\npath = "p.py"\n'
                     'symbol = "f"\ndetail = "d"\n')
        with pytest.raises(BaselineError, match="reason"):
            load_baseline(str(b))

    def test_step_strict_symbols_unsuppressable(self, tmp_path):
        """A baseline entry over per-decode-step code is rejected — the
        decode step has no acceptable host work, so the debt file must
        not be able to absorb it."""
        b = tmp_path / "b.toml"
        b.write_text(
            '[[suppress]]\nrule = "host-sync-in-hot-path"\n'
            'path = "repro/launch/serve.py"\n'
            'symbol = "_Group.decode_once"\ndetail = ".item()"\n'
            'reason = "a perfectly worded but inadmissible excuse"\n')
        with pytest.raises(BaselineError, match="step-strict"):
            load_baseline(str(b))

    def test_write_baseline_needs_human_followup(self, tmp_path):
        fs = _findings("bad_refcount.py")
        b = tmp_path / "b.toml"
        assert write_baseline(str(b), fs) == 2
        with pytest.raises(BaselineError, match="placeholder"):
            load_baseline(str(b))   # not a green-button: justify first

    def test_mini_toml_rejects_junk(self, tmp_path):
        from repro.analysis.baseline import _parse_mini_toml
        with pytest.raises(BaselineError, match="cannot parse"):
            _parse_mini_toml("not toml at all", "x.toml")
        doc = _parse_mini_toml(
            '# c\nversion = 1\n\n[[suppress]]\nrule = "r"\n', "x.toml")
        assert doc["version"] == 1
        assert doc["suppress"] == [{"rule": "r"}]


# ----------------------------------------------------------- marker runtime

def test_hot_path_marker_is_identity_and_introspectable():
    @hot_path
    def f(x):
        return x
    assert is_hot_path(f) and f(3) == 3

    from repro.launch.serve import Server, _Group
    from repro.models import transformer
    from repro.models.decode_state import DecodeState
    assert is_hot_path(_Group.decode_once)
    assert is_hot_path(_Group.admit)
    assert is_hot_path(Server.step)
    assert is_hot_path(Server.stats)
    assert is_hot_path(DecodeState.step)
    assert is_hot_path(transformer.decode_step)
    assert is_hot_path(transformer.decode_step_paged)
