"""Fault-injected serving: request lifecycle (deadline/cancel), chaos
harness determinism, quarantine isolation, step-fault self-healing,
bounded admission retry, and the degradation ladder.

The load-bearing contract everywhere: a fault may cost the FAULTED
request its tokens, but never changes any other request's tokens, and
never leaks a page or a slot — every test ends on the engine's own
invariant sweep (``check_invariants`` / ``assert_idle_clean``)."""

import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.ft import FaultInjector, default_chaos_rates
from repro.launch.serve import (ADMIT_BACKOFF_S, DEGRADE_AFTER,
                                MAX_ADMIT_RETRIES, RESTORE_AFTER,
                                Request, Server)
from repro.models import api
from repro.runtime import resolve_policy

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")


@pytest.fixture(scope="module")
def cfg():
    return get_config("gpt2-small").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return api.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,), dtype=np.int32) for n in lens]


def _oracle(cfg, params, prompts, *, max_new=6, max_batch=4, max_seq=64,
            policy=None, **kw):
    """Fault-free tokens, one request per rid."""
    srv = Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                 policy=policy, **kw)
    reqs = [Request(i, p.copy(), max_new) for i, p in enumerate(prompts)]
    srv.run(reqs)
    return {r.rid: list(r.out) for r in reqs}


# ------------------------------------------------------- request lifecycle

class TestLifecycle:
    def test_deadline_expires_queued_requests(self, cfg, params):
        prompts = _prompts(cfg, (5, 7, 9))
        srv = Server(cfg, params, max_batch=2, max_seq=64,
                     deadline_s=1e-6)
        reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        import time
        time.sleep(0.01)                  # everyone is past their TTL
        srv.drain()
        for r in reqs:
            assert r.finish_reason == "deadline" and r.out == []
            assert r.t_done > 0
        assert srv.stats()["default"]["deadline_missed"] == 3
        srv.check_invariants()
        srv.assert_idle_clean()

    def test_per_request_deadline_overrides_server_default(self, cfg,
                                                           params):
        prompts = _prompts(cfg, (5, 5))
        srv = Server(cfg, params, max_batch=1, max_seq=64, deadline_s=60.0)
        a = Request(0, prompts[0].copy(), 4)
        b = Request(1, prompts[1].copy(), 4, deadline_s=1e-6)
        srv.run([a, b])
        assert a.finish_reason == "max_new" and len(a.out) == 4
        assert b.finish_reason == "deadline" and b.out == []
        srv.assert_idle_clean()

    def test_cancel_queued_and_mid_decode(self, cfg, params):
        prompts = _prompts(cfg, (5, 11, 7))
        oracle = _oracle(cfg, params, prompts, max_batch=1, max_new=6)
        srv = Server(cfg, params, max_batch=1, max_seq=64)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        for _ in range(3):                # req 0 is now mid-decode
            srv.step()
        assert srv._groups["default"].reqs[0] is not None
        assert srv.cancel(0)              # mid-decode
        assert srv.cancel(2)              # still queued
        assert not srv.cancel(99)         # unknown rid
        srv.drain()
        assert reqs[0].finish_reason == "cancelled"
        assert reqs[2].finish_reason == "cancelled" and reqs[2].out == []
        # the untouched request is token-identical to a fault-free run
        assert reqs[1].finish_reason == "max_new"
        assert list(reqs[1].out) == oracle[1]
        assert srv.stats()["default"]["cancelled"] == 2
        srv.assert_idle_clean()

    def test_cancel_mid_chunk_releases_paged_reservation(self, cfg,
                                                         params):
        """Cancel a request while its prompt is mid-chunked-prefill in a
        paged pool: ``abort_chunk`` must hand back the slot's pages and
        prefix refs (this is the new DecodeState protocol capability)."""
        pol = resolve_policy(cfg, env={}, prefill_chunk=16)
        prompts = _prompts(cfg, (40, 5))
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol,
                     paged=True, block_page=8)
        reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        g = srv._groups["default"]
        srv.step()                        # req 0 enters chunked prefill
        assert 0 in [r.rid for r, _ in g.prefilling.values()]
        held = g.state.alloc.n_used()
        assert held > 0                   # the reservation is real
        assert srv.cancel(0)
        srv.drain()
        assert reqs[0].finish_reason == "cancelled" and reqs[0].out == []
        assert reqs[1].finish_reason == "max_new" and len(reqs[1].out) == 4
        srv.check_invariants()
        srv.assert_idle_clean()           # zero pages outlive the cancel


# -------------------------------------------------- quarantine / isolation

class TestQuarantine:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    def test_poisoned_slot_quarantined_others_exact(self, cfg, params,
                                                    exp):
        """Non-finite logits in one slot quarantine THAT request; the
        other slot's tokens stay identical to a fault-free run — under
        every exp backend (the sticky sentinel rides the decode carry,
        so this also pins that no garbage token is ever streamed)."""
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        prompts = _prompts(cfg, (5, 11))
        oracle = _oracle(cfg, params, prompts, policy=pol)
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        srv.step()                        # both admitted, decoding
        g = srv._groups["default"]
        j = next(j for j in range(2)
                 if g.reqs[j] is not None and g.reqs[j].rid == 0)
        assert g.state.poison_slot(j)
        srv.drain()
        assert reqs[0].finish_reason == "quarantined" and reqs[0].out == []
        assert reqs[1].finish_reason == "max_new"
        assert list(reqs[1].out) == oracle[1]
        assert srv.stats()["default"]["quarantined"] == 1
        srv.assert_idle_clean()

    def test_paged_poison_and_slot_reuse_after_scrub(self, cfg, params):
        """Paged pool: poison a slot with a private (partial) page, let
        quarantine scrub it, then serve ANOTHER request through the same
        pool — it must match fault-free tokens (the scrub zeroes the
        NaN'd pages before the free list can hand them out again)."""
        prompts = _prompts(cfg, (11, 11))    # 11 % 8 != 0: private page
        oracle = _oracle(cfg, params, prompts, max_batch=1, paged=True,
                         block_page=8, prefix_cache=False)
        srv = Server(cfg, params, max_batch=1, max_seq=64, paged=True,
                     block_page=8, prefix_cache=False)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        srv.step()
        g = srv._groups["default"]
        assert g.state.poison_slot(0)
        srv.drain()
        assert reqs[0].finish_reason == "quarantined"
        assert reqs[1].finish_reason == "max_new"
        assert list(reqs[1].out) == oracle[1]
        srv.assert_idle_clean()


# ------------------------------------------------------ step-fault healing

class TestStepFaultRecovery:
    def test_injected_step_error_reserves_token_identically(self, cfg,
                                                            params):
        """A decode-dispatch fault drops the pool; every in-flight
        request is re-queued and re-served from scratch — finishing with
        EXACTLY the tokens of an undisturbed run."""
        prompts = _prompts(cfg, (5, 11))
        oracle = _oracle(cfg, params, prompts)
        inj = FaultInjector(seed=0, schedule={"decode.step_error": [2]})
        srv = Server(cfg, params, max_batch=2, max_seq=64, injector=inj)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        srv.run(reqs)
        st = srv.stats()["default"]
        assert st["step_faults"] == 1 and st["requeued"] == 2
        for r in reqs:
            assert r.finish_reason == "max_new" and r.retries == 1
            assert list(r.out) == oracle[r.rid], r.rid
        srv.assert_idle_clean()

    def test_repeat_offender_is_shed_not_retried_forever(self, cfg,
                                                         params):
        """A request whose slot keeps killing the step burns its
        MAX_STEP_RETRIES budget and is shed with finish_reason="failed"
        — the drain loop terminates instead of thrashing recovery."""
        prompts = _prompts(cfg, (5,))
        inj = FaultInjector(seed=0,
                            schedule={"decode.step_error": range(100)})
        srv = Server(cfg, params, max_batch=1, max_seq=64, injector=inj)
        r = Request(0, prompts[0].copy(), 6)
        srv.run([r])
        assert r.finish_reason == "failed" and r.out == []
        st = srv.stats()["default"]
        assert st["shed"] == 1 and st["step_faults"] == 4  # 1 + 3 retries
        srv.assert_idle_clean()


# ------------------------------------------------- bounded admission retry

class TestBoundedAdmission:
    def test_unservable_requests_shed_not_hung(self, cfg, params):
        """The nothing-in-flight starvation case. Paged admission
        reserves a slot's full table (``ns`` pages minus prefix hits),
        so a pool whose budget is below one cold reservation can NEVER
        admit anything and no page will ever free on its own. The old
        split spun the drain loop forever (monolithic wave gate) or
        raised out of it (chunked); both paths now take the one bounded
        retry/backoff helper and shed with finish_reason="failed"."""
        prompts = _prompts(cfg, (40, 9))
        # cache_s=64 / page=8 -> 8 pages per cold reservation; the pool
        # allocates at most 3
        srv = Server(cfg, params, max_batch=2, max_seq=64, paged=True,
                     block_page=8, block_budget=4)
        reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
        srv.run(reqs)                         # must terminate
        for r in reqs:
            assert r.finish_reason == "failed" and r.out == []
        st = srv.stats()["default"]
        assert st["shed"] == 2
        assert st["admit_retries"] >= MAX_ADMIT_RETRIES
        srv.assert_idle_clean()

    def test_unservable_requests_shed_chunked(self, cfg, params):
        """Same starvation case through the chunked-admission path
        (there it surfaces as OutOfBlocks from ``begin_chunk``)."""
        pol = resolve_policy(cfg, env={}, prefill_chunk=16)
        prompts = _prompts(cfg, (40, 9))
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol,
                     paged=True, block_page=8, block_budget=4)
        reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
        srv.run(reqs)
        for r in reqs:
            assert r.finish_reason == "failed" and r.out == []
        assert srv.stats()["default"]["shed"] == 2
        srv.assert_idle_clean()

    def test_transient_rejection_retries_with_work_in_flight(self, cfg,
                                                             params):
        """An injected admission rejection with decode in flight: retry
        next tick (pages WILL free), and every request still completes
        with fault-free tokens — the retry is invisible to correctness.
        Scheduled on the SECOND admission wave, which lands while the
        first wave is still decoding."""
        prompts = _prompts(cfg, (5, 7, 9, 11))
        oracle = _oracle(cfg, params, prompts, max_batch=2)
        inj = FaultInjector(seed=0, schedule={"admit.out_of_blocks": [1]})
        srv = Server(cfg, params, max_batch=2, max_seq=64, injector=inj)
        reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
        srv.run(reqs)
        for r in reqs:
            assert r.finish_reason == "max_new"
            assert list(r.out) == oracle[r.rid], r.rid
        assert inj.stats()["fired"] == {"admit.out_of_blocks": 1}
        assert srv.stats()["default"]["admit_retries"] >= 1
        srv.assert_idle_clean()


# ------------------------------------------------------ degradation ladder

class TestDegradationLadder:
    def test_escalates_and_restores_with_hysteresis(self, cfg, params):
        pol = resolve_policy(cfg, env={}, exp_backend="exact",
                             prefill_chunk=16)
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol,
                     degrade_groups=("default",))
        g = srv._groups["default"]
        base_chunk = g.chunk_c
        assert g.degradable and srv.degrade_level == 0

        def tick(pressured):
            g._admit_pressure = pressured
            srv._degradation_tick()

        for _ in range(DEGRADE_AFTER - 1):
            tick(True)
        assert srv.degrade_level == 0     # hysteresis: not yet
        tick(True)
        assert srv.degrade_level == 1     # L1: narrower prefill chunks
        assert 0 < g.chunk_c < base_chunk
        assert g.policy.exp_backend == "exact"
        for _ in range(DEGRADE_AFTER):
            tick(True)
        assert srv.degrade_level == 2     # L2: cheaper exp backend
        assert g.policy.exp_backend == pol.degrade_exp_backend == "vexp_hw"
        # sustained clear pressure walks the ladder back down
        for _ in range(RESTORE_AFTER):
            tick(False)
        assert srv.degrade_level == 1
        for _ in range(RESTORE_AFTER):
            tick(False)
        assert srv.degrade_level == 0
        assert g.chunk_c == base_chunk
        assert g.policy.exp_backend == "exact"

    def test_non_degradable_group_keeps_its_backend(self, cfg, params):
        """Without --degrade-groups membership, L2 still shrinks chunks
        but NEVER swaps the exp backend (an eval group's numerics are
        not the scheduler's to trade away)."""
        pol = resolve_policy(cfg, env={}, exp_backend="exact")
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol)
        g = srv._groups["default"]
        g.set_degraded(2)
        assert g.policy.exp_backend == "exact"

    def test_unknown_degrade_group_rejected(self, cfg, params):
        with pytest.raises(ValueError, match="unknown degrade group"):
            Server(cfg, params, max_batch=2, max_seq=64,
                   degrade_groups=("nope",))

    def test_degraded_serving_matches_degraded_oracle(self, cfg, params):
        """Tokens served at L2 equal a server RUN at vexp_hw outright —
        degradation swaps programs through the cache, it does not invent
        a third numerics path."""
        pol = resolve_policy(cfg, env={}, exp_backend="exact")
        hw = _oracle(cfg, params, _prompts(cfg, (5, 11)),
                     policy=pol.replace(exp_backend="vexp_hw"))
        srv = Server(cfg, params, max_batch=2, max_seq=64, policy=pol,
                     degrade_groups=("default",))
        srv._groups["default"].set_degraded(2)
        reqs = [Request(i, p.copy(), 6)
                for i, p in enumerate(_prompts(cfg, (5, 11)))]
        srv.run(reqs)
        for r in reqs:
            assert list(r.out) == hw[r.rid], r.rid
        srv.assert_idle_clean()


# --------------------------------------------------------- chaos storms

def _storm(cfg, params, *, seed, paged, prompts, oracle, max_batch=4):
    inj = FaultInjector(seed=seed, rates=default_chaos_rates())
    kw = dict(paged=True, block_page=8) if paged else {}
    srv = Server(cfg, params, max_batch=max_batch, max_seq=64,
                 injector=inj, **kw)
    reqs = [Request(i, p.copy(), 6) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.cancel(3)                         # a cancellation mid-storm too
    srv.drain()
    for r in reqs:                        # nobody is left in limbo
        assert r.finish_reason is not None, r.rid
    # unaffected requests are token-identical to the fault-free run
    for r in reqs:
        if r.finish_reason in ("max_new", "length_cap"):
            assert list(r.out) == oracle[r.rid], r.rid
    srv.check_invariants()
    srv.assert_idle_clean()               # zero leaked pages/slots
    return srv, reqs


class TestChaosStorm:
    @pytest.mark.parametrize("paged", (False, True),
                             ids=("contiguous", "paged"))
    def test_seeded_storm_clean_shutdown(self, cfg, params, paged):
        lens = (5, 11, 7, 9, 13, 6, 8, 10)
        prompts = _prompts(cfg, lens)
        kw = dict(paged=True, block_page=8) if paged else {}
        oracle = _oracle(cfg, params, prompts, **kw)
        srv, _ = _storm(cfg, params, seed=11, paged=paged,
                        prompts=prompts, oracle=oracle)
        fired = srv.fault_stats()["injector"]["fired"]
        assert sum(fired.values()) >= 1   # the storm actually stormed

    def test_storm_is_replayable_by_seed(self, cfg, params):
        """Same seed -> same fired counts and same per-request outcomes;
        the REPRO_FAULT_SEED contract at the engine level."""
        lens = (5, 11, 7, 9, 13, 6)
        prompts = _prompts(cfg, lens)
        oracle = _oracle(cfg, params, prompts)
        runs = []
        for _ in range(2):
            srv, reqs = _storm(cfg, params, seed=5, paged=False,
                               prompts=prompts, oracle=oracle)
            runs.append((srv.fault_stats()["injector"]["fired"],
                         [(r.rid, r.finish_reason, list(r.out))
                          for r in reqs]))
        assert runs[0] == runs[1]
