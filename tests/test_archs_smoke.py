"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus prefill/decode round-trips
for the families that serve."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_config
from repro.models import api

ARCHS = sorted(REGISTRY)


def _smoke_batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    s_txt = s - cfg.n_vision_tokens if cfg.family == "vlm" else s
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s_txt), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s_txt), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["extra"] = jax.random.normal(
            ks[2], (b, cfg.n_vision_tokens, cfg.vision_embed_dim))
    if cfg.family == "audio":
        batch["extra"] = jax.random.normal(ks[2], (b, s, cfg.frame_input_dim))
        batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, cfg, batch))(params)
        new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
        return loss, new

    loss, new_params = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # loss should be ~ln(vocab) for a random init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    leaves = jax.tree.leaves(new_params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves), \
        f"{arch}: NaN in updated params"
    # a second step must reduce nothing structurally (shapes preserved)
    for a, b in zip(jax.tree.leaves(params), leaves):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if REGISTRY[a].family != "audio"])
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    b, s = 2, 32
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), b, s)
    logits, cache = api.prefill(params, cfg, batch)
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN prefill"

    if cache is None:
        cache = api.init_cache(cfg, b, 64)
    # continue decoding two tokens
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    pos = s if cfg.family != "vlm" else s  # absolute position
    for i in range(2):
        logits2, cache = api.decode_step(params, cfg, tok, cache,
                                         jnp.int32(pos + i))
        assert logits2.shape == (b, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: NaN decode"
        tok = jnp.argmax(logits2, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_shapes(arch):
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    for name in cfg.shapes:
        specs = api.input_specs(cfg, SHAPES[name])
        assert specs, f"{arch}/{name}: empty specs"
    # every non-applicable assigned shape has a recorded skip reason
    for name in SHAPES:
        if name not in cfg.shapes:
            assert name in cfg.skip_notes, f"{arch}: {name} skipped w/o note"


def test_decode_matches_prefill_tail():
    """Decoding token t with a cache == prefilling through t (dense)."""
    cfg = get_config("gpt2-small").reduced()
    b, s = 1, 16
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full_logits, _ = api.prefill(params, cfg, {"tokens": toks})

    # prefill first s-1 tokens, then decode the last one
    head_logits, cache = api.prefill(params, cfg, {"tokens": toks[:, :-1]})
    # grow cache to length s
    ck = jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.hd),
                   jnp.bfloat16).at[:, :, :s - 1].set(cache["k"])
    cv = jnp.zeros_like(ck).at[:, :, :s - 1].set(cache["v"])
    dec_logits, _ = api.decode_step(params, cfg, toks[:, -1:],
                                    {"k": ck, "v": cv}, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                               np.asarray(dec_logits[:, 0]),
                               atol=0.15, rtol=0.05)
