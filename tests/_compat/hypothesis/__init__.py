"""Minimal stand-in for the `hypothesis` API surface these tests use.

The container does not ship hypothesis and nothing may be pip-installed, so
`conftest.py` puts this package on sys.path only when the real library is
missing. It implements deterministic example generation (seeded per test)
for the small strategy subset the suite uses: integers, floats,
sampled_from, booleans, lists, tuples, just. Shrinking, assume(), and the
database are intentionally absent — failures report the drawn example in
the assertion context instead.
"""

from __future__ import annotations

import functools
import inspect
import random as _random
import zlib

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw_fn, desc=""):
        self._draw = draw_fn
        self._desc = desc

    def example_for(self, rng: _random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"strategy({self._desc})"


class strategies:
    """Namespace mirroring `hypothesis.strategies` (imported as `st`)."""

    @staticmethod
    def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value=-1e9, max_value=1e9, width=64,
               allow_nan=False, allow_infinity=False):
        lo, hi = float(min_value), float(max_value)

        def draw(r):
            # Bias toward the endpoints: boundary values are where the
            # numeric kernels actually break.
            roll = r.random()
            if roll < 0.05:
                return lo
            if roll < 0.10:
                return hi
            return r.uniform(lo, hi)

        return _Strategy(draw, f"floats({lo}, {hi})")

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements),
                         f"sampled_from(<{len(elements)}>)")

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5, "booleans()")

    @staticmethod
    def just(value):
        return _Strategy(lambda r: value, f"just({value!r})")

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elements.example_for(r) for _ in range(n)]

        return _Strategy(draw, "lists(...)")

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda r: tuple(s.example_for(r) for s in strats),
                         "tuples(...)")


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is conventionally applied *above* @given, so it
            # stamps the attribute on this wrapper; check both.
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            # Deterministic per-test seed so failures reproduce.
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = _random.Random(seed)
            for i in range(n):
                drawn = tuple(s.example_for(rng) for s in strats)
                drawn_kw = {k: s.example_for(rng)
                            for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **drawn_kw, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed on example {i}: "
                        f"args={drawn} kwargs={drawn_kw}") from e

        # pytest introspects signatures for fixtures; the wrapper consumes
        # the strategy parameters, so expose only the remainder (e.g. self).
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_consumed = len(strats)
        kept = []
        for p in params:
            if p.name == "self":
                kept.append(p)
            elif n_consumed > 0:
                n_consumed -= 1
            elif p.name not in kw_strats:
                kept.append(p)
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco


def assume(condition):
    if not condition:
        raise AssertionError("assumption failed (shim treats as failure)")
