"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression, end-to-end train loop."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.data import SyntheticLM, StructuredLM
from repro import ckpt as ckpt_lib
from repro.ft import PreemptionGuard, StragglerDetector, run_supervised
from repro.configs import get_config


class TestOptim:
    def _toy(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        grads = {"w": jnp.full((4, 4), 0.5), "b": jnp.ones((4,))}
        return params, grads

    def test_update_moves_params(self):
        cfg = optim.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10)
        params, grads = self._toy()
        state = optim.init(params, cfg)
        new, state, stats = optim.update(grads, state, params, cfg)
        assert float(stats["grad_norm"]) > 0
        assert not np.allclose(np.asarray(new["w"]), 1.0)
        assert int(state["step"]) == 1

    def test_clipping(self):
        cfg = optim.OptConfig(clip_norm=0.1, warmup_steps=0)
        params, grads = self._toy()
        grads = jax.tree.map(lambda g: g * 1e6, grads)
        state = optim.init(params, cfg)
        _, _, stats = optim.update(grads, state, params, cfg)
        assert float(stats["clip_scale"]) < 1e-5

    def test_schedule_shape(self):
        cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
        assert float(optim.schedule(cfg, 0)) == 0.0
        assert abs(float(optim.schedule(cfg, 10)) - 1.0) < 1e-6
        assert abs(float(optim.schedule(cfg, 100)) - 0.1) < 1e-6

    def test_bf16_moments(self):
        cfg = optim.OptConfig(moment_dtype="bfloat16", warmup_steps=0)
        params, grads = self._toy()
        state = optim.init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16
        new, state, _ = optim.update(grads, state, params, cfg)
        assert np.isfinite(np.asarray(new["w"])).all()

    def test_sgd_convergence_quadratic(self):
        """Adam minimizes a simple quadratic."""
        cfg = optim.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                              weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = optim.init(params, cfg)
        for _ in range(200):
            g = {"x": 2 * params["x"]}
            params, state, _ = optim.update(g, state, params, cfg)
        assert float(jnp.abs(params["x"]).max()) < 0.1


class TestData:
    def test_deterministic_replay(self):
        cfg = get_config("gpt2-small").reduced()
        a = SyntheticLM(cfg, 4, 16, seed=7).batch(123)
        b = SyntheticLM(cfg, 4, 16, seed=7).batch(123)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_steps_differ(self):
        cfg = get_config("gpt2-small").reduced()
        pipe = SyntheticLM(cfg, 4, 16, seed=7)
        assert not np.array_equal(pipe.batch(0)["tokens"],
                                  pipe.batch(1)["tokens"])

    def test_structured_learnable(self):
        b = StructuredLM(64, 2, 32, seed=0, noise=0.0).batch(0)
        t, l = b["tokens"], b["labels"]
        # labels are next-token of a period-16 motif: token[i] == token[i+16]
        np.testing.assert_array_equal(t[:, :16], t[:, 16:32])

    def test_modality_stubs(self):
        vlm = get_config("internvl2-1b").reduced()
        bv = SyntheticLM(vlm, 2, 16).batch(0)
        assert bv["extra"].shape == (2, vlm.n_vision_tokens,
                                     vlm.vision_embed_dim)
        au = get_config("hubert-xlarge").reduced()
        ba = SyntheticLM(au, 2, 16).batch(0)
        assert ba["extra"].shape == (2, 16, au.frame_input_dim)


class TestCheckpoint:
    def _tree(self):
        return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                           "b": jnp.ones((3,), jnp.bfloat16)},
                "opt": {"step": jnp.int32(5)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt_lib.save(tree, str(tmp_path), 10)
        flat, manifest = ckpt_lib.restore(str(tmp_path))
        assert manifest["step"] == 10
        back = ckpt_lib.unflatten_like(flat, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_and_atomicity(self, tmp_path):
        tree = self._tree()
        ckpt_lib.save(tree, str(tmp_path), 1)
        ckpt_lib.save(tree, str(tmp_path), 2)
        assert ckpt_lib.latest_step(str(tmp_path)) == 2
        # no tmp debris
        assert not [n for n in os.listdir(tmp_path) if n.startswith(".tmp")]

    def test_async_checkpointer(self, tmp_path):
        tree = self._tree()
        saver = ckpt_lib.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3):
            saver.save_async(tree, s)
        saver.wait()
        assert ckpt_lib.latest_step(str(tmp_path)) == 3
        steps = sorted(n for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert len(steps) == 2   # gc kept 2

    def test_reshard_roundtrip(self, tmp_path):
        """Elastic restart: save, restore onto a (1,1) mesh sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_host_mesh
        tree = self._tree()
        ckpt_lib.save(tree, str(tmp_path), 1)
        flat, _ = ckpt_lib.restore(str(tmp_path))
        back = ckpt_lib.unflatten_like(flat, tree)
        mesh = make_host_mesh()
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        placed = ckpt_lib.reshard(back, sh)
        np.testing.assert_array_equal(
            np.asarray(placed["params"]["w"]),
            np.asarray(tree["params"]["w"]))


class TestFaultTolerance:
    def test_preemption_guard(self):
        g = PreemptionGuard(signals=())
        assert not g.should_stop
        g.trigger()
        assert g.should_stop

    def test_straggler_detector(self):
        d = StragglerDetector(window=20, threshold=2.0)
        for i in range(10):
            assert not d.record(i, 1.0)
        assert d.record(10, 5.0)          # 5x median
        assert d.flagged[0][0] == 10

    def test_run_supervised_restarts(self, tmp_path):
        """A step function that crashes twice still completes, resuming
        from checkpoints (the cluster-controller restart model)."""
        crashes = {"n": 0}
        store = {}

        def make_state():
            return {"x": 0}

        def step_fn(state, step):
            if step == 7 and crashes["n"] < 2:
                crashes["n"] += 1
                raise RuntimeError("simulated node failure")
            return {"x": state["x"] + 1}

        def save_fn(state, step):
            store["ckpt"] = (dict(state), step)

        def restore_fn():
            return store.get("ckpt")

        state, restarts = run_supervised(
            make_state, step_fn, save_fn, restore_fn, 20, ckpt_every=5)
        assert restarts == 2
        assert state["x"] == 20          # every step executed exactly once


class TestCompression:
    def test_ef_compress_unbiased(self):
        from repro.distributed.compression import ef_compress
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(512), jnp.float32) * 1e-3
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(50):
            c, err = ef_compress(g, err)
            total = total + c.astype(jnp.float32)
        # accumulated compressed updates track accumulated true updates
        np.testing.assert_allclose(np.asarray(total), np.asarray(g) * 50,
                                   rtol=2e-2, atol=1e-5)

    def test_compressed_psum_single_device(self):
        from repro.distributed.compression import compressed_psum
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        g = {"w": jnp.ones((8, 8)) * 0.25}
        e = {"w": jnp.zeros((8, 8))}
        m, ne = compressed_psum(g, e, mesh, axis="data")
        np.testing.assert_allclose(np.asarray(m["w"]), 0.25, atol=1e-3)


class TestTrainLoop:
    def test_loss_decreases_and_resumes(self, tmp_path):
        from repro.launch.train import train
        cfg = get_config("gpt2-small").reduced()
        opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=2, total_steps=150)
        logs = []
        params, hist = train(cfg, steps=150, batch=4, seq=32,
                             ckpt_dir=str(tmp_path), ckpt_every=50,
                             opt_cfg=opt_cfg, log_every=5,
                             guard=PreemptionGuard(signals=()),
                             log=logs.append)
        # Per-step batches are noisy (the induction task's per-batch loss
        # varies more than 30 steps of progress), so compare early/late
        # window means rather than two single samples.
        early = sum(l for _, l in hist[:4]) / 4
        late = sum(l for _, l in hist[-4:]) / 4
        assert late < early, f"loss did not decrease: {early} -> {late}"
        # resume from checkpoint: starts at step 150 == no-op, returns
        params2, hist2 = train(cfg, steps=150, batch=4, seq=32,
                               ckpt_dir=str(tmp_path), ckpt_every=50,
                               opt_cfg=opt_cfg,
                               guard=PreemptionGuard(signals=()),
                               log=logs.append)
        assert any("resumed from step 150" in l for l in logs)

    def test_preemption_drain(self, tmp_path):
        from repro.launch.train import train
        cfg = get_config("gpt2-small").reduced()
        guard = PreemptionGuard(signals=())
        calls = {"n": 0}
        orig = guard.trigger

        def log(msg):
            calls["n"] += 1
            if calls["n"] == 2:     # trigger mid-run
                guard.trigger()

        params, hist = train(cfg, steps=50, batch=2, seq=16,
                             ckpt_dir=str(tmp_path), ckpt_every=100,
                             opt_cfg=optim.OptConfig(total_steps=50),
                             log_every=1, guard=guard, log=log)
        # drained early with a checkpoint on disk
        assert ckpt_lib.latest_step(str(tmp_path)) is not None
