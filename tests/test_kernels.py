"""Per-kernel allclose tests vs. the pure-jnp oracles (interpret=True).

Sweeps shapes and dtypes per the deliverable requirements. All Pallas
kernels target TPU; on this CPU container they execute through the Pallas
interpreter, which runs the same kernel body.
"""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.vexp import vexp as vexp_op, vexp_ref
from repro.kernels.softmax import softmax as softmax_op, softmax_ref
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)


class TestVexpKernel:
    @pytest.mark.parametrize("shape", [(8,), (130,), (256, 128), (3, 5, 67),
                                       (1024, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, shape, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 5).astype(dtype)
        out = vexp_op(x, interpret=True)
        ref = vexp_ref(x)
        assert out.dtype == dtype and out.shape == shape
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=1e-6, atol=0)

    def test_extremes(self):
        x = jnp.asarray([-1e4, -100.0, 0.0, 100.0], jnp.float32)
        out = np.asarray(vexp_op(x, interpret=True))
        assert out[0] == 0.0 and out[1] == 0.0
        assert out[2] == 1.0 and out[3] == np.inf


class TestSoftmaxKernel:
    @pytest.mark.parametrize("shape,axis", [
        ((32, 128), -1), ((8, 300), -1), ((4, 16, 384), -1),
        ((16, 64), 0), ((2, 8, 128, 100), -1),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, shape, axis, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(1), shape) * 4).astype(dtype)
        out = softmax_op(x, axis=axis, interpret=True)
        ref = softmax_ref(x.astype(jnp.float32), axis=axis).astype(dtype)
        assert out.shape == shape and out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)

    def test_rows_sum_to_one(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 200)) * 8
        out = np.asarray(softmax_op(x, interpret=True))
        np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-3)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,sq,sk,h,hkv,d", [
        (1, 128, 128, 2, 2, 64),      # MHA, aligned
        (2, 128, 256, 4, 2, 64),      # GQA 2:1, cross lengths
        (1, 200, 200, 4, 1, 80),      # MQA, unaligned seq + head dim
        (1, 256, 256, 8, 2, 128),     # GQA 4:1
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_allclose_vs_ref(self, b, sq, sk, h, hkv, d, causal):
        if sq != sk and causal:
            pytest.skip("causal with sq != sk is exercised via q_offset paths")
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(keys[0], (b, sq, h, d), jnp.float32)
        k = jax.random.normal(keys[1], (b, sk, hkv, d), jnp.float32)
        v = jax.random.normal(keys[2], (b, sk, hkv, d), jnp.float32)
        out = flash_attention(q, k, v, causal, None, None, 64, 64, True)
        ref = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)

    def test_bf16(self):
        keys = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(keys[0], (1, 128, 4, 64), jnp.bfloat16)
        k = jax.random.normal(keys[1], (1, 128, 2, 64), jnp.bfloat16)
        v = jax.random.normal(keys[2], (1, 128, 2, 64), jnp.bfloat16)
        out = flash_attention(q, k, v, True, None, None, 64, 64, True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-2)

    def test_sliding_window(self):
        keys = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(keys[0], (1, 256, 2, 64), jnp.float32)
        k = jax.random.normal(keys[1], (1, 256, 2, 64), jnp.float32)
        v = jax.random.normal(keys[2], (1, 256, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, True, 64, None, 64, 64, True)
        ref = flash_attention_ref(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)

    def test_grad_finite(self):
        keys = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(keys[0], (1, 128, 2, 64), jnp.float32)
        k = jax.random.normal(keys[1], (1, 128, 2, 64), jnp.float32)
        v = jax.random.normal(keys[2], (1, 128, 2, 64), jnp.float32)

        def loss(q, k, v):
            return flash_attention(q, k, v, True, None, None, 64, 64,
                                   True).sum()

        gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for g in (gq, gk, gv):
            assert np.isfinite(np.asarray(g)).all()
