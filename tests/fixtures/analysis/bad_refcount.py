"""Planted violations for the refcount-pairing rule."""

import numpy as np


class LeakyPool:
    def __init__(self, n):
        self.refs = np.zeros(n, np.int32)
        self.free = list(range(n))

    def incref(self, g):
        self.refs[g] += 1

    def decref(self, g):
        self.refs[g] -= 1
        if self.refs[g] == 0:
            self.free.append(g)

    def cow_leak(self, g):
        # ERROR: raw refcount mutation outside the primitives — the page
        # never returns to the free list when this hits zero (the PR-6
        # cow() bug, replanted)
        self.refs[g] -= 1
        return self.free.pop()

    def attach_leak(self, gids):
        held = []
        for g in gids:
            # ERROR: unguarded incref loop — a raise mid-loop strands
            # every reference already taken
            self.incref(g)
            held.append(g)
        return held

    def attach_guarded(self, gids):
        held = []
        try:
            for g in gids:
                self.incref(g)      # OK: release reachable on exception
                held.append(g)
        except BaseException:
            for g in held:
                self.decref(g)
            raise
        return held
