"""Jaxpr-audit fixture: a decode-like step whose carry DRIFTS — the
exact PR-5 bug class (a cast inside the step silently changes the carry
dtype, the output no longer matches the donated input buffer, donation
is dropped and decode-state memory doubles)."""

import jax.numpy as jnp


def drifting_step(params, tok, state, pos, live):
    h = state["h"] + params["w"] * tok
    # the planted bug: carry comes back bf16 while the pool is f32
    h = h.astype(jnp.bfloat16)
    return tok + 1, {"h": h, "conv": state["conv"]}, pos + live


def shape_drifting_step(params, tok, state, pos, live):
    # second drift class: the carry grows along an axis
    h = jnp.concatenate([state["h"], state["h"][:, :1]], axis=1)
    return tok + 1, {"h": h, "conv": state["conv"]}, pos + live
