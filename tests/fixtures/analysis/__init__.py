# Planted-violation fixtures for repro.analysis (one module per rule,
# plus a clean control). These are ANALYZED, mostly never imported —
# keep each violation obvious and single-purpose.
