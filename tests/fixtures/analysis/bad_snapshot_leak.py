"""Planted violation for the refcount-pairing rule's speculative-
snapshot pass: ``spec_snapshot`` takes the burst's only rollback token
and the draft steps then advance the donated pool positions in place,
but no try around the burst reaches a rollback/recovery call — an
injected dispatch fault (or any raise between snapshot and verify)
strands the pool mid-draft with no way back (unguarded-spec-snapshot)."""


class BadSpecEngine:
    def decode_spec_once(self):
        snap = self.state.spec_snapshot()
        cur = self.last
        for _ in range(self.spec_k):
            # BUG: a raise here (injected decode.step_error, a
            # cancellation surfacing mid-burst) leaves the positions
            # advanced by the drafts already run — nothing restores snap.
            cur = self.state.draft_step(cur, self.live_dev)
        self.pending = (snap, cur)

    def logging_is_not_a_guard(self):
        snap = self.state.spec_snapshot()
        try:
            self.state.draft_step(self.last, self.live_dev)
        except Exception:
            # BUG: the handler observes the fault but discharges nothing
            # — the rollback token dies here with the pool mid-draft.
            self.log.append(("spec fault", snap))
            raise
