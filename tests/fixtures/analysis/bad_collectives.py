"""Jaxpr-audit fixture: a sharded step that spends TWO collectives where
the serving budget allows one (the split-stats shape PR-4 replaced with
the packed single-all_gather merge).

Works on a 1-device mesh: shard_map still lowers real stablehlo
collective ops, so the audit counts them without multi-device state.
"""

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def build_two_collective_step(mesh, axis="x"):
    def step(x):
        s = jax.lax.psum(x, axis)     # collective 1
        m = jax.lax.pmax(x, axis)     # collective 2
        return s + m

    return jax.jit(shard_map(step, mesh=mesh,
                             in_specs=P(axis), out_specs=P()))


def build_one_collective_step(mesh, axis="x"):
    def step(x):
        return jax.lax.psum(x, axis)  # exactly one collective

    return jax.jit(shard_map(step, mesh=mesh,
                             in_specs=P(axis), out_specs=P()))
