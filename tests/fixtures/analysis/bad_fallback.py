"""Planted violations for the silent-fallback rule (a kernel entry point
that quietly routes configurations back to the reference reduction)."""


def core_decode(q, k, v, cache_len):
    return q  # stand-in for the reference reduction


def decode_attention(q, k, v, cache_len, *, policy=None):
    return q  # stand-in for the fused kernel


def decode_attention_policy(q, k, v, cache_len, *, layout="bshd",
                            window=None, policy=None):
    # ERROR: configuration-gated fallback (branches on layout)
    if layout != "bshd":
        # ERROR: reference reduction reachable from the kernel entry
        return core_decode(q, k, v, cache_len)
    # ERROR: second gate, on window
    if window is not None:
        return core_decode(q, k, v, cache_len)
    return decode_attention(q, k, v, cache_len, policy=policy)
