"""Planted violations for the engine-family-branch rule (a miniature
serve.py that does exactly what the engine contract forbids)."""


class MiniEngine:
    def __init__(self, cfg, state):
        self.cfg, self.state = cfg, state

    def admit(self, req):
        # ERROR: family branch in the engine — belongs behind the
        # DecodeState protocol
        if self.cfg.family == "ssm":
            return self.state.admit_recurrent(req)
        return self.state.admit_kv(req)

    def step(self):
        if self.state.is_paged:
            # ERROR: not-implemented escape hatch in the engine
            raise NotImplementedError("paged decode unsupported")
        return self.state.step()
