"""Planted violations for the jit-retrace-hazard rule."""

import functools

import jax
import jax.numpy as jnp


@jax.jit
def jitted_mutable_default(x, scales=[1.0, 2.0]):
    # ERROR: mutable default on a jitted function
    return x * scales[0]


def assigned_later(x, table={}):
    # ERROR once _assigned is jitted below (jit-by-assignment)
    return x + table.get("bias", 0.0)


_assigned = jax.jit(assigned_later)


@functools.lru_cache(maxsize=None)
def cached_norm(v):
    # WARN: lru_cache over a parameter that flows into an array op —
    # array inputs are unhashable (crash) or pinned alive (leak)
    return jnp.sqrt(jnp.sum(v * v))


@functools.lru_cache(maxsize=None)
def cached_program(n_layers, dtype_name):
    # OK: memoized on hashable config only; arrays never enter the key
    return jnp.zeros((n_layers,), jnp.dtype(dtype_name))
