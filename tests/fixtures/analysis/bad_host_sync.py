"""Planted violations for the host-sync-in-hot-path rule."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import hot_path


@hot_path
def decode_tick(state, tok):
    # ERROR: per-step device->host scalarization
    stop = state.done.item()
    # ERROR: blocking materialization of a device array
    host = np.asarray(state.last)
    # ERROR: explicit transfer
    mirror = jax.device_get(state.pos)
    # ERROR: device sync
    jax.block_until_ready(tok)
    # WARN: int() on a non-constant (device scalar here)
    n = int(state.steps)
    return stop, host, mirror, n


@hot_path
def outer(state):
    def inner(x):
        # nested defs inherit hotness: still an ERROR
        return x.tolist()
    return inner(state)


def cold_path(state):
    # unmarked: the same calls are fine here (scheduling-event code
    # registers itself explicitly; this function never did)
    return np.asarray(state.last), int(state.steps)


@hot_path
def literal_ok(rows):
    # np.asarray on a literal comprehension builds a HOST array — allowed
    return np.asarray([r * 2 for r in range(4)]), jnp.zeros(3)
