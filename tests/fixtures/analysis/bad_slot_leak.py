"""Planted violation for the refcount-pairing rule's slot-reservation
pass: ``begin_chunk`` reserves a slot's pages/prefix refs inside an
admission loop, but no try in the loop releases the reservation on the
exception path — the ``popleft()`` (or any raise between reserve and
publish) strands the slot's pages forever (unguarded-slot-reserve)."""


class BadEngine:
    def admit_chunked(self):
        free = [j for j in range(len(self.reqs)) if self.reqs[j] is None]
        while free and self.queue:
            r = self.queue[0]
            j = free[0]
            cur = self.state.begin_chunk(j, r.prompt, len(r.prompt))
            # BUG: a raise here (popleft on a concurrently drained queue,
            # an allocator fault, a cancellation) leaks the reservation —
            # nothing aborts the chunk cursor.
            self.prefilling[j] = (self.queue.popleft(), cur)
            free.pop(0)
