"""Clean control fixture: hot-path + pool + jit idioms the analyzer must
stay quiet on, and a stable-carry step for the jaxpr-audit tests."""

import functools

import jax
import jax.numpy as jnp

from repro.analysis.registry import hot_path


@hot_path
def decode_tick(state, tok, live):
    # device-only: no syncs, no transfers, positions advance on device
    h = state["h"] * 0.5 + tok
    return {"h": h, "pos": state["pos"] + live}, jnp.argmax(h, -1)


def stable_step(params, tok, state, pos, live):
    # carry (state, pos) keeps dtypes/shapes: donation-compatible
    h = (state["h"] + params["w"] * tok).astype(state["h"].dtype)
    conv = state["conv"]
    return tok + 1, {"h": h, "conv": conv}, pos + live


class TidyPool:
    def __init__(self, n):
        self.refs = [0] * n

    def incref(self, g):
        self.refs[g] += 1

    def decref(self, g):
        self.refs[g] -= 1

    def attach(self, gids):
        held = []
        try:
            for g in gids:
                self.incref(g)
                held.append(g)
        except BaseException:
            for g in held:
                self.decref(g)
            raise
        return held


@functools.lru_cache(maxsize=None)
def program_for(width, dtype_name):
    # hashable-config memoization: allowed
    return jax.jit(lambda x: x * jnp.ones((width,), jnp.dtype(dtype_name)))
