"""Chunked, decode-overlapped prefill (PR-8).

The headline contract: serving with ``ExecPolicy.prefill_chunk > 0``
(prompts streamed into their slots in fixed-size chunks, one bounded
chunk per engine tick, interleaved with decode) must produce EXACTLY the
greedy tokens of monolithic one-wave prefill — for every decoding family
(transformer / ssm / hybrid), every exp backend (exact / vexp /
vexp_hw), and both pool kinds (contiguous slot rows and the paged block
pool), including chunks straddling a page boundary, prompts shorter than
one chunk, and chunk admission into slots freed mid-decode.

The recurrent family is held to a stronger bar: chunked prefill is
BITWISE identical in its final (h, conv) state, not just argmax-equal —
chunk boundaries are pinned to ``cfg.ssm_chunk`` so the fp summation
order of the SSD chunk math is admission-invariant. (Hybrid is
token-identical but not bitwise: the RG-LRU associative-combine tree
depends on scan length, which is why the engine pins the chunk width
instead of bucketing it.)
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.launch.serve import Server, Request
from repro.runtime import resolve_policy

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")
FAMILY_ARCH = {"transformer": "gpt2-small", "ssm": "mamba2-1.3b",
               "hybrid": "recurrentgemma-9b"}
# hybrid's reduced sliding window is 16: its serve pool is the window,
# so hybrid prompts stay <= 16 (the same bound monolithic admission
# enforces) while the linear families exercise longer prompts.
FAMILY_LENS = {"transformer": (21, 5, 33, 12), "ssm": (21, 5, 33, 12),
               "hybrid": (13, 5, 16, 9)}

_cfg_cache, _params_cache = {}, {}


def _cfg(family):
    if family not in _cfg_cache:
        _cfg_cache[family] = get_config(FAMILY_ARCH[family]).reduced()
    return _cfg_cache[family]


def _params(family):
    if family not in _params_cache:
        _params_cache[family] = api.init_params(_cfg(family),
                                                jax.random.PRNGKey(0))
    return _params_cache[family]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (n,), dtype=np.int32) for n in lens]


def _serve(family, prompts, *, chunk, exp="vexp", paged=False,
           block_page=None, max_new=6, max_batch=2, max_news=None):
    cfg = _cfg(family)
    pol = resolve_policy(cfg, env={}, exp_backend=exp, prefill_chunk=chunk)
    srv = Server(cfg, _params(family), max_batch=max_batch,
                 max_seq=cfg.sliding_window or 64, policy=pol,
                 paged=paged, block_page=block_page)
    reqs = [Request(i, p.copy(), (max_news or {}).get(i, max_new))
            for i, p in enumerate(prompts)]
    srv.run(reqs)
    return {r.rid: tuple(r.out) for r in reqs}, srv


def _group(srv):
    return srv._groups["default"]


# -------------------------------------------------- chunked == monolithic

class TestChunkedEqualsMonolithic:
    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    @pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
    def test_contiguous(self, family, exp):
        """family x exp backend over contiguous slot pools: more
        requests than slots, so completion frees slots and later
        requests are chunk-admitted mid-decode — and every emitted
        token must match the monolithic wave path."""
        prompts = _prompts(_cfg(family), FAMILY_LENS[family])
        mono, msrv = _serve(family, prompts, chunk=0, exp=exp)
        chk, csrv = _serve(family, prompts, chunk=6, exp=exp)
        assert chk == mono
        g = _group(csrv)
        assert g.chunk_c >= 6 and len(g.chunk_s) > 0
        assert not g.admit_s                 # no monolithic wave ran
        assert _group(msrv).admit_s          # ... and the baseline did

    @pytest.mark.parametrize("exp", EXP_BACKENDS)
    @pytest.mark.parametrize("family", ("transformer", "hybrid"))
    def test_paged_chunk_straddles_page_boundary(self, family, exp):
        """Paged pools with page=8 and chunk width 6: the second chunk
        of every long prompt spans tokens [6, 12) — straddling the first
        page boundary — so one chunk's KV scatter must split across two
        physical pages. Tokens must still match monolithic paged
        serving exactly."""
        prompts = _prompts(_cfg(family), FAMILY_LENS[family])
        mono, _ = _serve(family, prompts, chunk=0, exp=exp, paged=True,
                         block_page=8)
        chk, csrv = _serve(family, prompts, chunk=6, exp=exp, paged=True,
                           block_page=8)
        assert chk == mono
        g = _group(csrv)
        assert g.chunk_c == 6 and len(g.chunk_s) > 0
        # drained: only the prefix cache's own references remain resident
        # (hybrid rings are not content-addressable — no cache, zero held)
        pool = csrv.stats()["default"]["pool"]
        assert pool["pages_used"] == pool.get("prefix", {}).get("pages", 0)

    def test_chunked_batched_matches_monolithic_solo(self):
        """The full identity chain in one place: chunk-admitted batched
        serving == monolithic SOLO serving per request (the strictest
        form — batching and chunking together must change nothing)."""
        prompts = _prompts(_cfg("transformer"), (21, 5, 33))
        chk, _ = _serve("transformer", prompts, chunk=4)
        for i, p in enumerate(prompts):
            solo, _ = _serve("transformer", [p], chunk=0)
            assert chk[i] == solo[0], i

    def test_prompt_shorter_than_one_chunk(self):
        """A prompt shorter than the chunk width completes in its first
        chunk (clens < chunk_c): one chunk dispatch, identical tokens."""
        prompts = _prompts(_cfg("transformer"), (5, 3))
        mono, _ = _serve("transformer", prompts, chunk=0)
        chk, csrv = _serve("transformer", prompts, chunk=64)
        assert chk == mono
        # both admitted the same tick -> exactly one chunk dispatched
        assert len(_group(csrv).chunk_s) == 1

    def test_chunk_width_one(self):
        """Degenerate width-1 chunks (one token per tick) stress the
        cursor/offset bookkeeping hardest; tokens must not change."""
        prompts = _prompts(_cfg("transformer"), (7, 3))
        mono, _ = _serve("transformer", prompts, chunk=0)
        chk, _ = _serve("transformer", prompts, chunk=1)
        assert chk == mono

    def test_ssm_chunk_width_rounds_to_native_block(self):
        """The recurrent family rounds the requested chunk budget up to
        a multiple of cfg.ssm_chunk — chunk boundaries pinned to the SSD
        block keep the fp summation order admission-invariant."""
        cfg = _cfg("ssm")
        _, srv = _serve("ssm", _prompts(cfg, (5,)), chunk=3)
        g = _group(srv)
        q = cfg.ssm_chunk
        assert g.chunk_c % q == 0 and g.chunk_c >= 3


# ------------------------------------------- mid-decode chunk admission

class TestMidDecodeAdmission:
    def test_freed_slots_readmit_chunked(self):
        """More requests than slots with staggered max_new: slots free
        mid-serve and the queue chunk-admits into them while the other
        slot keeps decoding. Every request's tokens must match the
        monolithic engine, and admission order must stay FIFO."""
        prompts = _prompts(_cfg("transformer"), (21, 5, 33, 12, 9))
        news = {0: 3, 1: 8, 2: 5, 3: 2, 4: 6}
        mono, _ = _serve("transformer", prompts, chunk=0, max_news=news)
        chk, csrv = _serve("transformer", prompts, chunk=6, max_news=news)
        assert chk == mono
        assert csrv.admit_log == [0, 1, 2, 3, 4]

    def test_paged_freed_pages_recycle_through_chunked_admission(self):
        """Paged pool sized for ~2 slots: chunk admission must block on
        pages (never crash), recycle pages freed by finished requests,
        and still serve every request with monolithic-identical
        tokens."""
        cfg = _cfg("transformer")
        prompts = _prompts(cfg, (21, 5, 33, 12))
        pol0 = resolve_policy(cfg, env={}, prefill_chunk=0)
        polc = resolve_policy(cfg, env={}, prefill_chunk=6)
        out = {}
        for name, pol in (("mono", pol0), ("chunk", polc)):
            srv = Server(cfg, _params("transformer"), max_batch=2,
                         max_seq=64, policy=pol, paged=True, block_page=8,
                         block_budget=2 * 8 + 1)
            reqs = [Request(i, p.copy(), 4) for i, p in enumerate(prompts)]
            srv.run(reqs)
            out[name] = {r.rid: tuple(r.out) for r in reqs}
            pool = srv.stats()["default"]["pool"]
            assert pool["pages_used"] == pool.get("prefix",
                                                  {}).get("pages", 0)
        assert out["chunk"] == out["mono"]

    def test_decode_overlaps_long_prefill(self):
        """The two-queue point: with one long and one short prompt in
        flight, the short request finishes its ENTIRE service (prefill +
        all decode steps) while the long prompt is still prefilling —
        decode steps ran interleaved between the long prompt's chunks,
        which the monolithic wave scheduler cannot do."""
        cfg = _cfg("transformer")
        rng = np.random.default_rng(1)
        long_p = rng.integers(0, cfg.vocab, (33,), dtype=np.int32)
        short_p = rng.integers(0, cfg.vocab, (4,), dtype=np.int32)
        pol = resolve_policy(cfg, env={}, prefill_chunk=2)
        srv = Server(cfg, _params("transformer"), max_batch=2, max_seq=64,
                     policy=pol)
        reqs = [Request(0, long_p, 4), Request(1, short_p, 3)]
        srv.run(reqs)
        # short served end to end before the long prompt's first token
        assert reqs[1].t_done < reqs[0].t_first
        g = _group(srv)
        # and the long prompt really streamed: ceil(33/2) chunk ticks
        assert len(g.chunk_s) >= 17


# ------------------------------------------------ protocol-level identity

class TestChunkProgramIdentity:
    def test_ssm_state_bitwise_identical(self):
        """Chunked ssm prefill == one-shot ragged prefill BITWISE in the
        final (h, conv) state, per row, with chunk boundaries on
        cfg.ssm_chunk — and argmax-identical in the completion logits."""
        cfg, params = _cfg("ssm"), _params("ssm")
        b, s = 3, 64
        plens = np.array([17, 5, 33], np.int32)
        rng = np.random.default_rng(2)
        toks = np.zeros((b, s), np.int32)
        for i, n in enumerate(plens):
            toks[i, :n] = rng.integers(0, cfg.vocab, (n,))
        logits_m, state_m = api.prefill(
            params, cfg, {"tokens": jnp.asarray(toks),
                          "prompt_len": jnp.asarray(plens)})
        c = -(-16 // cfg.ssm_chunk) * cfg.ssm_chunk
        cache = api.init_cache(cfg, b, s)
        off = np.zeros(b, np.int32)
        final = [None] * b
        while (off < plens).any():
            clens = np.clip(plens - off, 0, c).astype(np.int32)
            ck = np.zeros((b, c), np.int32)
            for i in range(b):
                ck[i, :clens[i]] = toks[i, off[i]:off[i] + clens[i]]
            logits_c, cache = api.prefill_chunk(
                params, cfg, jnp.asarray(ck), cache, jnp.asarray(off),
                jnp.asarray(clens))
            off = off + clens
            for i in range(b):
                if clens[i] and off[i] == plens[i]:
                    final[i] = np.asarray(logits_c[i])
        for la, lb in zip(jax.tree_util.tree_leaves(state_m),
                          jax.tree_util.tree_leaves(cache)):
            assert la.shape == lb.shape and la.dtype == lb.dtype
            assert bool(jnp.array_equal(la, lb))
        for i in range(b):
            assert int(np.argmax(final[i])) == int(jnp.argmax(logits_m[i]))

    def test_inert_rows_pass_through_bit_untouched(self):
        """Rows with clens == 0 (slots decoding, or empty) must come out
        of the chunk program with their state bitwise unchanged — the
        property that lets decoding slots ride along the fixed-shape
        chunk step for free."""
        cfg, params = _cfg("transformer"), _params("transformer")
        b, s, c = 2, 64, 8
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, c), np.int64)
                           .astype(np.int32))
        cache = api.init_cache(cfg, b, s)
        # populate row 0 with a real chunk first
        _, cache = api.prefill_chunk(
            params, cfg, toks, cache,
            jnp.zeros((b,), jnp.int32),
            jnp.asarray([c, 0], jnp.int32))
        before = jax.tree_util.tree_leaves(jax.tree.map(
            lambda x: np.asarray(x), cache))
        # now advance only row 1; row 0 is inert (clens == 0)
        _, cache = api.prefill_chunk(
            params, cfg, toks, cache,
            jnp.zeros((b,), jnp.int32),
            jnp.asarray([0, c], jnp.int32))
        after = jax.tree_util.tree_leaves(jax.tree.map(
            lambda x: np.asarray(x), cache))
        # transformer cache leaves stack layers first: (L, B, S, Hkv, d)
        for x, y in zip(before, after):
            assert np.array_equal(x[:, 0], y[:, 0])   # row 0 bit-untouched
            assert not np.array_equal(x[:, 1], y[:, 1])  # row 1 advanced


# ----------------------------------------------------- scheduler surface

class TestSchedulerSurface:
    def test_stats_report_chunk_telemetry(self):
        """stats() carries the two-queue scheduler's telemetry — queue
        depth, prefilling count, chunk count/dispatch time and TTFT
        percentiles — all assembled from host mirrors at scheduling
        events (no device syncs; the analyzer pins that separately)."""
        prompts = _prompts(_cfg("transformer"), (21, 5, 33))
        _, csrv = _serve("transformer", prompts, chunk=6)
        s = csrv.stats()["default"]
        assert s["prefill_chunk"] == 6
        assert s["prefill_chunks"] >= 6          # 33-token prompt alone
        assert s["chunk_s_total"] > 0.0
        assert s["queue_depth"] == 0 and s["prefilling"] == 0
        assert s["p95_ttft_s"] >= s["p50_ttft_s"] > 0.0
        _, msrv = _serve("transformer", prompts, chunk=0)
        m = msrv.stats()["default"]
        assert m["prefill_chunks"] == 0 and m["prefill_chunk"] == 0
        assert m["p50_ttft_s"] > 0.0             # same keys, wave-sampled

    def test_unchunkable_pool_falls_back_to_monolithic(self):
        """A paged pool that cannot chunk (windowed KV ring tables are
        only chunkable through the hybrid state; the pure-KV paged pool
        gates on sliding_window is None) must silently keep the
        monolithic wave path even when the policy asks for chunks —
        capability lives behind the DecodeState protocol."""
        wcfg = get_config("h2o-danube3-4b").reduced()
        assert wcfg.sliding_window
        params = api.init_params(wcfg, jax.random.PRNGKey(0))
        pol = resolve_policy(wcfg, env={}, prefill_chunk=8)
        srv = Server(wcfg, params, max_batch=2,
                     max_seq=wcfg.sliding_window, policy=pol, paged=True,
                     block_page=8)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, wcfg.vocab, (n,),
                                        dtype=np.int32), 4)
                for i, n in enumerate((5, 11))]
        srv.run(reqs)
        g = _group(srv)
        assert g.chunk_c == 0 and not g.chunk_s and g.admit_s
        assert all(len(r.out) == 4 for r in reqs)
