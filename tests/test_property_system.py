"""System-level property tests (hypothesis): invariants that must hold for
any shape/seed — checkpoint roundtrips, kernel/ref agreement, optimizer
step sanity, online-softmax algebra at scale."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.vexp import vexp as vexp_op, vexp_ref
from repro.kernels.softmax import softmax as softmax_op, softmax_ref
from repro import ckpt as ckpt_lib
from repro import optim


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 4),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_vexp_kernel_any_shape(n, rank_extra, dtype):
    shape = (n,) + (2,) * (rank_extra - 1)
    x = (jax.random.normal(jax.random.PRNGKey(n), shape) * 6).astype(dtype)
    out = vexp_op(x, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(vexp_ref(x), np.float32),
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(2, 400))
def test_softmax_kernel_any_rows(rows, cols):
    x = jax.random.normal(jax.random.PRNGKey(rows * 1000 + cols),
                          (rows, cols)) * 5
    out = softmax_op(x, interpret=True)
    ref = softmax_ref(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_checkpoint_roundtrip_any_tree(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((3, 5)), jnp.float32),
            "b": {"c": jnp.asarray(rng.standard_normal(7), jnp.bfloat16),
                  "d": jnp.int32(rng.integers(0, 100))},
            "e": [jnp.ones((2, 2))]}
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        ckpt_lib.save(tree, d, 1)
        flat, _ = ckpt_lib.restore(d)
        back = ckpt_lib.unflatten_like(flat, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@settings(max_examples=10, deadline=None)
@given(st.floats(1e-5, 1e-1), st.integers(1, 50))
def test_optimizer_step_bounded(lr, steps):
    """AdamW updates are bounded by ~lr per step (trust-region property)."""
    cfg = optim.OptConfig(lr=lr, warmup_steps=0, total_steps=max(steps, 2),
                          weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = optim.init(params, cfg)
    g = {"w": jnp.ones((4,)) * 100.0}
    for _ in range(steps):
        params, state, _ = optim.update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) <= 1.1 * lr * steps
