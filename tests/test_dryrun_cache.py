"""Dry-run artifact-cache semantics: ok records short-circuit, failed
records retry on a bounded attempt count with exponential backoff, and
``--force`` starts the count over. All through failure records from a
bogus arch — no cell is ever actually compiled here."""

import importlib
import json
import os

import pytest


@pytest.fixture(scope="module")
def dryrun():
    # importing the module sets XLA_FLAGS (host-device-count override)
    # as a side effect; restore the env immediately so no later jax
    # initialization in this process can pick up 512 fake devices.
    saved = os.environ.get("XLA_FLAGS")
    try:
        mod = importlib.import_module("repro.launch.dryrun")
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    return mod


def _run(dryrun, tmp_path, now, **kw):
    kw.setdefault("max_attempts", 3)
    kw.setdefault("backoff_s", 60.0)
    return dryrun.run_cell("no-such-arch", "no-such-shape", "single",
                           out_dir=str(tmp_path), now=now, **kw)


def test_ok_record_short_circuits(dryrun, tmp_path):
    path = tmp_path / "no-such-arch__no-such-shape__single.json"
    path.write_text(json.dumps({"ok": True, "sentinel": 7}))
    # the bogus arch would fail if anything recomputed
    rec = _run(dryrun, tmp_path, now=0.0)
    assert rec["sentinel"] == 7


def test_failed_cell_backs_off_then_gives_up(dryrun, tmp_path):
    r1 = _run(dryrun, tmp_path, now=1000.0)
    assert not r1["ok"] and r1["attempts"] == 1
    assert "no-such-arch" in r1["error"] or "KeyError" in r1["error"]

    # inside the 60s backoff window: cached failure, no new attempt
    r2 = _run(dryrun, tmp_path, now=1030.0)
    assert r2["attempts"] == 1 and r2["t_attempt"] == 1000.0

    # window elapsed: retried, attempt count and timestamp advance
    r3 = _run(dryrun, tmp_path, now=1061.0)
    assert r3["attempts"] == 2 and r3["t_attempt"] == 1061.0

    # second window doubles (120s): still cached at +59s...
    r4 = _run(dryrun, tmp_path, now=1120.0)
    assert r4["attempts"] == 2

    # ...retried once it elapses
    r5 = _run(dryrun, tmp_path, now=1290.0)
    assert r5["attempts"] == 3

    # attempts exhausted: the cell never runs again, however long we wait
    r6 = _run(dryrun, tmp_path, now=10_000_000.0)
    assert r6["attempts"] == 3 and r6["t_attempt"] == 1290.0


def test_force_restarts_the_attempt_count(dryrun, tmp_path):
    for now in (0.0, 100.0, 400.0):
        _run(dryrun, tmp_path, now=now)
    assert _run(dryrun, tmp_path, now=1e9)["attempts"] == 3
    r = _run(dryrun, tmp_path, now=1e9, force=True)
    assert r["attempts"] == 1 and not r["ok"]


def test_legacy_failure_record_is_retried(dryrun, tmp_path):
    # pre-backoff records have no attempts/t_attempt bookkeeping: they
    # count as one attempt made at epoch, so the next sweep retries them
    path = tmp_path / "no-such-arch__no-such-shape__single.json"
    path.write_text(json.dumps({"ok": False, "error": "old"}))
    r = _run(dryrun, tmp_path, now=1e6)
    assert r["attempts"] == 2 and "error" in r
