"""Tests for vexp softmax, online-stats algebra, and attention paths."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

import repro.core.softmax as S
import repro.core.attention as A
from repro.core.vexp import get_exp_fn


class TestSoftmax:
    def test_close_to_exact(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 4
        a = S.softmax(x, exp_impl="vexp")
        b = jax.nn.softmax(x, axis=-1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=0)

    def test_sums_to_one(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 257)) * 10
        s = S.softmax(x).sum(-1)
        np.testing.assert_allclose(np.asarray(s), 1.0, atol=1e-3)

    def test_masked(self):
        x = jnp.zeros((2, 8))
        mask = jnp.arange(8)[None, :] < 4
        s = S.softmax(x, where=mask)
        np.testing.assert_allclose(np.asarray(s[:, :4]), 0.25, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s[:, 4:]), 0.0)

    @pytest.mark.parametrize("exp_impl", ["exact", "vexp", "vexp_hw"])
    def test_fully_masked_row_is_zeros_not_nan(self, exp_impl):
        """Regression: a row with where=False everywhere (a padded serving
        slot) used to divide by s=0 and emit NaN; it must return zeros
        while real rows are untouched."""
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 16)) * 5
        mask = jnp.ones((4, 16), bool).at[1].set(False).at[3].set(False)
        s = S.softmax(x, where=mask, exp_impl=exp_impl)
        s = np.asarray(s.astype(jnp.float32))
        assert np.isfinite(s).all(), "fully-masked row produced NaN/inf"
        np.testing.assert_allclose(s[1], 0.0)
        np.testing.assert_allclose(s[3], 0.0)
        ref = np.asarray(S.softmax(x[::2], where=mask[::2],
                                   exp_impl=exp_impl).astype(jnp.float32))
        np.testing.assert_allclose(s[::2], ref, atol=1e-6)

    def test_log_softmax(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 3
        a = S.log_softmax(x, exp_impl="exact")
        b = jax.nn.log_softmax(x, axis=-1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 64), st.floats(0.1, 20.0))
    def test_property_invariances(self, n, scale):
        """softmax(x + c) == softmax(x); outputs in [0,1]; argmax preserved."""
        key = jax.random.PRNGKey(n)
        x = jax.random.normal(key, (n,)) * scale
        s1 = np.asarray(S.softmax(x))
        s2 = np.asarray(S.softmax(x + 123.0))
        np.testing.assert_allclose(s1, s2, atol=2e-3)
        assert (s1 >= 0).all() and (s1 <= 1.0 + 1e-6).all()
        assert int(np.argmax(s1)) == int(np.argmax(np.asarray(x)))


class TestOnlineStats:
    def test_blockwise_equals_full(self):
        """Processing a row in blocks via stats_update == full softmax
        denominator (the paper's partial softmax equivalence)."""
        exp_fn = get_exp_fn("exact")
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, 96))) * 5
        stats = S.stats_init((4,))
        for i in range(0, 96, 32):
            stats, _, _ = S.stats_update(stats, jnp.asarray(x[:, i:i + 32]),
                                         exp_fn=exp_fn)
        m_ref = x.max(-1)
        l_ref = np.exp(x - m_ref[:, None]).sum(-1)
        np.testing.assert_allclose(np.asarray(stats.m), m_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(stats.l), l_ref, rtol=1e-5)

    def test_merge_associative_commutative(self):
        exp_fn = get_exp_fn("exact")
        xs = [np.asarray(jax.random.normal(jax.random.PRNGKey(i), (3, 16))) * 4
              for i in range(3)]
        parts = []
        for x in xs:
            st0, _, _ = S.stats_update(S.stats_init((3,)), jnp.asarray(x),
                                       exp_fn=exp_fn)
            parts.append(st0)
        ab, _, _ = S.stats_merge(parts[0], parts[1], exp_fn=exp_fn)
        abc1, _, _ = S.stats_merge(ab, parts[2], exp_fn=exp_fn)
        bc, _, _ = S.stats_merge(parts[1], parts[2], exp_fn=exp_fn)
        abc2, _, _ = S.stats_merge(parts[0], bc, exp_fn=exp_fn)
        np.testing.assert_allclose(np.asarray(abc1.l), np.asarray(abc2.l),
                                   rtol=1e-6)
        ba, _, _ = S.stats_merge(parts[1], parts[0], exp_fn=exp_fn)
        np.testing.assert_allclose(np.asarray(ab.l), np.asarray(ba.l),
                                   rtol=1e-6)


def _rand_qkv(key, b, sq, sk, h, hkv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, hkv, d), dtype)
    v = jax.random.normal(k3, (b, sk, hkv, d), dtype)
    return q, k, v


class TestAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("hkv", [8, 2, 1])
    def test_flash_matches_xla(self, causal, hkv):
        q, k, v = _rand_qkv(jax.random.PRNGKey(0), 2, 64, 64, 8, hkv, 16)
        a = A.attention_xla(q, k, v, causal=causal, exp_impl="exact")
        b = A.attention_flash(q, k, v, causal=causal, exp_impl="exact",
                              block_k=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)

    def test_vexp_close_to_exact(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(1), 2, 32, 32, 4, 4, 32)
        a = A.attention_flash(q, k, v, exp_impl="exact")
        b = A.attention_flash(q, k, v, exp_impl="vexp")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)

    def test_sliding_window(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(2), 1, 48, 48, 4, 4, 16)
        a = A.attention_xla(q, k, v, causal=True, window=8, exp_impl="exact")
        b = A.attention_flash(q, k, v, causal=True, window=8,
                              exp_impl="exact", block_k=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)

    def test_q_offset_prefill_chunk(self):
        """Chunked prefill with q_offset == full forward on the same rows."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(3), 1, 32, 32, 4, 2, 16)
        full = A.attention_xla(q, k, v, causal=True, exp_impl="exact")
        tail = A.attention_xla(q[:, 16:], k, v, causal=True, q_offset=16,
                               exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, 16:]),
                                   np.asarray(tail), atol=1e-4, rtol=1e-4)

    def test_decode_matches_full(self):
        """decode_attention on a cache == last row of full causal attn."""
        b, s, h, hkv, d = 2, 24, 8, 4, 16
        q, k, v = _rand_qkv(jax.random.PRNGKey(4), b, s, s, h, hkv, d)
        full = A.attention_xla(q, k, v, causal=True, exp_impl="exact")
        # cache larger than the valid length
        smax = 32
        kc = jnp.pad(k, ((0, 0), (0, smax - s), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, smax - s), (0, 0), (0, 0)))
        dec = A.decode_attention(q[:, -1:], kc, vc, cache_len=s,
                                 exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_decode_windowed(self):
        b, s, h, hkv, d = 1, 40, 4, 1, 16
        q, k, v = _rand_qkv(jax.random.PRNGKey(5), b, s, s, h, hkv, d)
        full = A.attention_xla(q, k, v, causal=True, window=8,
                               exp_impl="exact")
        dec = A.decode_attention(q[:, -1:], k, v, cache_len=s, window=8,
                                 exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_grad_flows(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(6), 1, 16, 16, 2, 2, 8)

        def loss(q):
            return A.attention_flash(q, k, v, exp_impl="vexp").sum()

        g = jax.grad(loss)(q)
        assert np.isfinite(np.asarray(g)).all()


class TestWindowConsistency:
    """Sliding-window off-by-one pinning: every implementation must attend
    exactly ``window`` tokens *including the current position* — verified
    against an oracle that slices those keys out explicitly, at the block
    boundaries where an off-by-one would hide (window = 1, block_s - 1,
    block_s, S)."""

    BLOCK = 16
    S = 32

    @pytest.mark.parametrize("window", [1, BLOCK - 1, BLOCK, S])
    def test_all_impls_keep_exactly_window_tokens(self, window):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.decode_attention import decode_attention as fused_decode
        b, s, h, hkv, d = 2, self.S, 4, 2, 32
        bs = self.BLOCK
        q, k, v = _rand_qkv(jax.random.PRNGKey(11), b, s, s, h, hkv, d)

        # Oracle at the last position: plain softmax over exactly the
        # `window` keys [s - window, s) — one more or one fewer key moves
        # the answer.
        lo = s - window
        g = h // hkv
        qg = (q[:, -1].astype(jnp.float32)
              .reshape(b, hkv, g, d)) / np.sqrt(d)
        kw = k[:, lo:].astype(jnp.float32)
        scores = jnp.einsum("bkgd,btkd->bkgt", qg, kw)
        p = jax.nn.softmax(scores, -1)
        oracle = jnp.einsum("bkgt,btkd->bkgd", p,
                            v[:, lo:].astype(jnp.float32))
        oracle = np.asarray(oracle.reshape(b, 1, h, d))

        outs = {
            "xla": A.attention_xla(q, k, v, causal=True, window=window,
                                   exp_impl="exact")[:, -1:],
            "flash": A.attention_flash(q, k, v, causal=True, window=window,
                                       exp_impl="exact",
                                       block_k=bs)[:, -1:],
            "pallas_fa": flash_attention(q, k, v, True, window, None,
                                         bs, bs, True)[:, -1:],
            "decode": fused_decode(
                q[:, -1:], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                jnp.int32(s), window=window, block_s=bs, interpret=True),
        }
        for name, out in outs.items():
            np.testing.assert_allclose(
                np.asarray(out), oracle, atol=2e-3, rtol=2e-3,
                err_msg=f"{name} window={window} disagrees with the "
                        f"exact-{window}-token oracle")

    def test_window_excludes_token_just_outside(self):
        """Perturbing the newest *out-of-window* key must not change any
        implementation's output (it would under an off-by-one that kept
        window+1 tokens)."""
        from repro.kernels.decode_attention import decode_attention as fused_decode
        b, s, h, hkv, d, w = 1, 32, 4, 2, 32, 8
        q, k, v = _rand_qkv(jax.random.PRNGKey(12), b, s, s, h, hkv, d)
        k2 = k.at[:, s - w - 1].add(100.0)
        v2 = v.at[:, s - w - 1].add(100.0)
        for fn in (
            lambda kk, vv: A.attention_xla(q, kk, vv, causal=True, window=w,
                                           exp_impl="exact")[:, -1:],
            lambda kk, vv: A.attention_flash(q, kk, vv, causal=True,
                                             window=w, exp_impl="exact",
                                             block_k=16)[:, -1:],
            lambda kk, vv: fused_decode(
                q[:, -1:], kk.transpose(0, 2, 1, 3),
                vv.transpose(0, 2, 1, 3), jnp.int32(s), window=w,
                block_s=16, interpret=True),
        ):
            np.testing.assert_allclose(np.asarray(fn(k, v)),
                                       np.asarray(fn(k2, v2)),
                                       atol=1e-5,
                                       err_msg="out-of-window key leaked in")
