"""Tests for vexp softmax, online-stats algebra, and attention paths."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

import repro.core.softmax as S
import repro.core.attention as A
from repro.core.vexp import get_exp_fn


class TestSoftmax:
    def test_close_to_exact(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 4
        a = S.softmax(x, exp_impl="vexp")
        b = jax.nn.softmax(x, axis=-1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=0)

    def test_sums_to_one(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 257)) * 10
        s = S.softmax(x).sum(-1)
        np.testing.assert_allclose(np.asarray(s), 1.0, atol=1e-3)

    def test_masked(self):
        x = jnp.zeros((2, 8))
        mask = jnp.arange(8)[None, :] < 4
        s = S.softmax(x, where=mask)
        np.testing.assert_allclose(np.asarray(s[:, :4]), 0.25, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s[:, 4:]), 0.0)

    def test_log_softmax(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 3
        a = S.log_softmax(x, exp_impl="exact")
        b = jax.nn.log_softmax(x, axis=-1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 64), st.floats(0.1, 20.0))
    def test_property_invariances(self, n, scale):
        """softmax(x + c) == softmax(x); outputs in [0,1]; argmax preserved."""
        key = jax.random.PRNGKey(n)
        x = jax.random.normal(key, (n,)) * scale
        s1 = np.asarray(S.softmax(x))
        s2 = np.asarray(S.softmax(x + 123.0))
        np.testing.assert_allclose(s1, s2, atol=2e-3)
        assert (s1 >= 0).all() and (s1 <= 1.0 + 1e-6).all()
        assert int(np.argmax(s1)) == int(np.argmax(np.asarray(x)))


class TestOnlineStats:
    def test_blockwise_equals_full(self):
        """Processing a row in blocks via stats_update == full softmax
        denominator (the paper's partial softmax equivalence)."""
        exp_fn = get_exp_fn("exact")
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, 96))) * 5
        stats = S.stats_init((4,))
        for i in range(0, 96, 32):
            stats, _, _ = S.stats_update(stats, jnp.asarray(x[:, i:i + 32]),
                                         exp_fn=exp_fn)
        m_ref = x.max(-1)
        l_ref = np.exp(x - m_ref[:, None]).sum(-1)
        np.testing.assert_allclose(np.asarray(stats.m), m_ref, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(stats.l), l_ref, rtol=1e-5)

    def test_merge_associative_commutative(self):
        exp_fn = get_exp_fn("exact")
        xs = [np.asarray(jax.random.normal(jax.random.PRNGKey(i), (3, 16))) * 4
              for i in range(3)]
        parts = []
        for x in xs:
            st0, _, _ = S.stats_update(S.stats_init((3,)), jnp.asarray(x),
                                       exp_fn=exp_fn)
            parts.append(st0)
        ab, _, _ = S.stats_merge(parts[0], parts[1], exp_fn=exp_fn)
        abc1, _, _ = S.stats_merge(ab, parts[2], exp_fn=exp_fn)
        bc, _, _ = S.stats_merge(parts[1], parts[2], exp_fn=exp_fn)
        abc2, _, _ = S.stats_merge(parts[0], bc, exp_fn=exp_fn)
        np.testing.assert_allclose(np.asarray(abc1.l), np.asarray(abc2.l),
                                   rtol=1e-6)
        ba, _, _ = S.stats_merge(parts[1], parts[0], exp_fn=exp_fn)
        np.testing.assert_allclose(np.asarray(ab.l), np.asarray(ba.l),
                                   rtol=1e-6)


def _rand_qkv(key, b, sq, sk, h, hkv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, hkv, d), dtype)
    v = jax.random.normal(k3, (b, sk, hkv, d), dtype)
    return q, k, v


class TestAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("hkv", [8, 2, 1])
    def test_flash_matches_xla(self, causal, hkv):
        q, k, v = _rand_qkv(jax.random.PRNGKey(0), 2, 64, 64, 8, hkv, 16)
        a = A.attention_xla(q, k, v, causal=causal, exp_impl="exact")
        b = A.attention_flash(q, k, v, causal=causal, exp_impl="exact",
                              block_k=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)

    def test_vexp_close_to_exact(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(1), 2, 32, 32, 4, 4, 32)
        a = A.attention_flash(q, k, v, exp_impl="exact")
        b = A.attention_flash(q, k, v, exp_impl="vexp")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)

    def test_sliding_window(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(2), 1, 48, 48, 4, 4, 16)
        a = A.attention_xla(q, k, v, causal=True, window=8, exp_impl="exact")
        b = A.attention_flash(q, k, v, causal=True, window=8,
                              exp_impl="exact", block_k=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)

    def test_q_offset_prefill_chunk(self):
        """Chunked prefill with q_offset == full forward on the same rows."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(3), 1, 32, 32, 4, 2, 16)
        full = A.attention_xla(q, k, v, causal=True, exp_impl="exact")
        tail = A.attention_xla(q[:, 16:], k, v, causal=True, q_offset=16,
                               exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, 16:]),
                                   np.asarray(tail), atol=1e-4, rtol=1e-4)

    def test_decode_matches_full(self):
        """decode_attention on a cache == last row of full causal attn."""
        b, s, h, hkv, d = 2, 24, 8, 4, 16
        q, k, v = _rand_qkv(jax.random.PRNGKey(4), b, s, s, h, hkv, d)
        full = A.attention_xla(q, k, v, causal=True, exp_impl="exact")
        # cache larger than the valid length
        smax = 32
        kc = jnp.pad(k, ((0, 0), (0, smax - s), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, smax - s), (0, 0), (0, 0)))
        dec = A.decode_attention(q[:, -1:], kc, vc, cache_len=s,
                                 exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_decode_windowed(self):
        b, s, h, hkv, d = 1, 40, 4, 1, 16
        q, k, v = _rand_qkv(jax.random.PRNGKey(5), b, s, s, h, hkv, d)
        full = A.attention_xla(q, k, v, causal=True, window=8,
                               exp_impl="exact")
        dec = A.decode_attention(q[:, -1:], k, v, cache_len=s, window=8,
                                 exp_impl="exact")
        np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                                   atol=1e-4, rtol=1e-4)

    def test_grad_flows(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(6), 1, 16, 16, 2, 2, 8)

        def loss(q):
            return A.attention_flash(q, k, v, exp_impl="vexp").sum()

        g = jax.grad(loss)(q)
        assert np.isfinite(np.asarray(g)).all()
