"""Sequence-parallel flash decode: sharded == unsharded, for real.

The tentpole contract (ISSUE 3): a ``shard_map`` decode over a KV cache
sharded along its sequence axis — either layout, ragged per-row (B,)
cache lengths, with or without a sliding window — produces the same
tokens as the unsharded fused ``decode_attention`` under every exp
backend, because the per-shard partial (m, l, acc) statistics merge
through the exact (associative + commutative) algebra of
``core.softmax.stats_merge``.

Sub-process tests force 8 host-platform devices (XLA_FLAGS must be set
before jax initializes); in-process tests cover the wiring that needs no
mesh. A CI job additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (make spmd-test).
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_AUTOTUNE_CACHE"] = "off"
import sys
sys.path.insert(0, {src!r})
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_sharded)
from repro.kernels.dispatch import dispatch
from repro.runtime import ExecPolicy

def mesh2x4():
    kw = ({{"axis_types": (jax.sharding.AxisType.Auto,) * 2}}
          if hasattr(jax.sharding, "AxisType") else {{}})
    return jax.make_mesh((2, 4), ("data", "model"), **kw)

def qkv(b, h, hkv, d, smax, layout, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    shape = ((b, hkv, smax, d) if layout == "bhsd" else (b, smax, hkv, d))
    kc = jax.random.normal(ks[1], shape, jnp.float32)
    vc = jax.random.normal(ks[2], shape, jnp.float32)
    return q, kc, vc

def shard_cache(mesh, kc, vc, layout):
    spec = [None] * 4
    spec[2 if layout == "bhsd" else 1] = "model"
    s = NamedSharding(mesh, P(*spec))
    return jax.device_put(kc, s), jax.device_put(vc, s)
"""


def _run_sub(body: str) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _PRELUDE.format(src=os.path.abspath(src)) \
        + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardedDecode:
    @pytest.mark.parametrize("layout", ["bshd", "bhsd"])
    def test_token_identical_all_exp_backends(self, layout):
        """KV-seq-sharded decode == unsharded fused decode: allclose values
        and identical greedy tokens (argmax of projected logits), for all
        three exp backends, with ragged (B,) cache lengths including a
        length-1 row and a shard-boundary-straddling one."""
        res = _run_sub(f"""
        layout = {layout!r}
        b, h, hkv, d, smax = 3, 8, 4, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, layout)
        clen = jnp.array([1, 700, 1024], jnp.int32)
        w = jax.random.normal(jax.random.PRNGKey(7), (h * d, 256),
                              jnp.float32)
        mesh = mesh2x4()
        out = {{}}
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                             block_s=128)
            ref = decode_attention(q, kc, vc, clen, layout=layout,
                                   policy=pol)
            kcs, vcs = shard_cache(mesh, kc, vc, layout)
            with mesh:
                shr = decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, layout=layout,
                    policy=pol)
            tok_r = jnp.argmax(ref.reshape(b, -1) @ w, -1)
            tok_s = jnp.argmax(shr.reshape(b, -1) @ w, -1)
            out[exp] = {{
                "delta": float(jnp.abs(ref - shr).max()),
                "tokens_equal": bool((tok_r == tok_s).all()),
            }}
        print(json.dumps(out))
        """)
        for exp, r in res.items():
            assert r["tokens_equal"], f"{exp}: greedy tokens diverged"
            assert r["delta"] < 2e-3, f"{exp}: {r['delta']}"

    def test_windowed_sharded(self):
        """Sliding-window sharded decode: shards outside the window
        contribute the merge identity; result matches the unsharded
        windowed kernel and the O(S) reference."""
        res = _run_sub("""
        from repro.kernels.decode_attention import decode_attention_ref
        b, h, hkv, d, smax = 2, 4, 2, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, "bhsd", seed=3)
        clen = jnp.array([900, 1024], jnp.int32)
        pol = ExecPolicy(kernel_backend="pallas", block_s=128)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bhsd")
        out = {}
        for win in (64, 200):
            fused = decode_attention(q, kc, vc, clen, window=win,
                                     policy=pol)
            oracle = decode_attention_ref(q, kc, vc, clen, window=win)
            with mesh:
                shr = decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, window=win,
                    layout="bhsd", policy=pol)
            out[str(win)] = {
                "d_fused": float(jnp.abs(shr - fused).max()),
                "d_oracle": float(jnp.abs(shr - oracle).max()),
            }
        print(json.dumps(out))
        """)
        for win, r in res.items():
            assert r["d_fused"] < 2e-3, f"window {win}: {r}"
            assert r["d_oracle"] < 4e-3, f"window {win}: {r}"

    def test_dispatch_entry_and_reference_parity(self):
        """kernels.dispatch('decode_attention_sharded'): the pallas entry
        runs the shard_map partial+psum path; the reference entry lowers
        the same sharded cache through GSPMD — both match the
        single-device result."""
        res = _run_sub("""
        b, h, hkv, d, smax = 2, 8, 4, 64, 512
        q, kc, vc = qkv(b, h, hkv, d, smax, "bshd", seed=5)
        clen = jnp.array([313, 512], jnp.int32)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bshd")
        pol_p = ExecPolicy(kernel_backend="pallas", block_s=128)
        pol_r = ExecPolicy(kernel_backend="reference")
        single = decode_attention(q, kc, vc, clen, layout="bshd",
                                  policy=pol_p)
        with mesh:
            fused = dispatch("decode_attention_sharded", pol_p)(
                q, kcs, vcs, clen, mesh=mesh, layout="bshd", policy=pol_p)
            ref = jax.jit(lambda *a: dispatch(
                "decode_attention_sharded", pol_r)(
                    *a, mesh=mesh, layout="bshd", policy=pol_r))(
                    q, kcs, vcs, clen)
        print(json.dumps({
            "d_fused": float(jnp.abs(fused - single).max()),
            "d_ref": float(jnp.abs(ref - single).max()),
        }))
        """)
        assert res["d_fused"] < 2e-3
        assert res["d_ref"] < 2e-3

    def test_ragged_shard_local_padding_masked(self):
        """Shard-local block padding sits at absolute positions that are
        valid on other shards — it must never leak into the scores (a
        too-small block_s forces per-shard padding)."""
        res = _run_sub("""
        b, h, hkv, d, smax = 2, 4, 4, 64, 344   # 86 per shard: pads to 128
        q, kc, vc = qkv(b, h, hkv, d, smax, "bhsd", seed=11)
        clen = jnp.array([344, 129], jnp.int32)
        pol = ExecPolicy(kernel_backend="pallas", block_s=64)
        mesh = mesh2x4()
        single = decode_attention(q, kc, vc, clen, policy=pol)
        spec = NamedSharding(mesh, P(None, None, "model", None))
        kcs, vcs = jax.device_put(kc, spec), jax.device_put(vc, spec)
        with mesh:
            shr = decode_attention_sharded(q, kcs, vcs, clen, mesh=mesh,
                                           layout="bhsd", policy=pol)
        print(json.dumps({"delta": float(jnp.abs(shr - single).max())}))
        """)
        assert res["delta"] < 2e-3


@pytest.mark.slow
class TestPackedMerge:
    """ISSUE 4 tentpole: the packed single-collective (m, l, acc) merge."""

    @pytest.mark.parametrize("layout", ["bshd", "bhsd"])
    def test_packed_token_identity_all_exp_backends(self, layout):
        """merge_strategy="packed" == "split" == unsharded fused decode —
        allclose values and identical greedy tokens under all three exp
        backends, both layouts, ragged (B,) lengths including a length-1
        row and a shard-boundary-straddling one."""
        res = _run_sub(f"""
        layout = {layout!r}
        b, h, hkv, d, smax = 3, 8, 4, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, layout, seed=2)
        clen = jnp.array([1, 700, 1024], jnp.int32)
        w = jax.random.normal(jax.random.PRNGKey(7), (h * d, 256),
                              jnp.float32)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, layout)
        out = {{}}
        for exp in ("exact", "vexp", "vexp_hw"):
            row = {{}}
            ref = decode_attention(
                q, kc, vc, clen, layout=layout,
                policy=ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                                  block_s=128))
            tok_r = jnp.argmax(ref.reshape(b, -1) @ w, -1)
            for strat in ("packed", "split"):
                pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                                 block_s=128, merge_strategy=strat)
                with mesh:
                    shr = decode_attention_sharded(
                        q, kcs, vcs, clen, mesh=mesh, layout=layout,
                        policy=pol)
                tok_s = jnp.argmax(shr.reshape(b, -1) @ w, -1)
                row[strat] = {{
                    "delta": float(jnp.abs(ref - shr).max()),
                    "tokens_equal": bool((tok_r == tok_s).all()),
                }}
            out[exp] = row
        print(json.dumps(out))
        """)
        for exp, row in res.items():
            for strat, r in row.items():
                assert r["tokens_equal"], f"{exp}/{strat}: tokens diverged"
                assert r["delta"] < 2e-3, f"{exp}/{strat}: {r['delta']}"

    def test_packed_is_single_collective(self):
        """The whole point: the packed program lowers to exactly ONE
        collective (one stablehlo.all_gather, no all_reduce); the split
        program carries three all_reduces (pmax + 2 psum)."""
        res = _run_sub("""
        import re
        from repro.kernels.decode_attention.ops import _sharded_program
        b, h, hkv, d, smax = 3, 8, 4, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, "bshd")
        clen = jnp.array([1, 700, 1024], jnp.int32)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bshd")
        out = {}
        for strat in ("packed", "split"):
            pol = ExecPolicy(kernel_backend="pallas", block_s=128,
                             merge_strategy=strat)
            txt = _sharded_program(mesh, "model", None, None, "bshd",
                                   pol).lower(q, kcs, vcs, clen).as_text()
            out[strat] = {
                "all_gather": len(re.findall(
                    r'stablehlo\\.all_gather"', txt)),
                "all_reduce": len(re.findall(
                    r'stablehlo\\.all_reduce"', txt)),
            }
        print(json.dumps(out))
        """)
        assert res["packed"] == {"all_gather": 1, "all_reduce": 0}
        assert res["split"] == {"all_gather": 0, "all_reduce": 3}

    def test_overflow_guard_large_m_spread(self):
        """Per-shard maxima spread over hundreds of logits: the packed
        fold subtracts the global max *before* exponentiation, so huge
        spreads must neither overflow nor diverge from the unsharded
        kernel (which sweeps the same scores sequentially)."""
        res = _run_sub("""
        b, h, hkv, d, smax = 2, 4, 2, 64, 512
        q, kc, vc = qkv(b, h, hkv, d, smax, "bshd", seed=13)
        # scores ~ N(0, 60^2): per-shard m values land hundreds apart,
        # exp(m_i) alone would overflow f32 (exp(200) = inf)
        q = q * 60.0
        clen = jnp.array([313, 512], jnp.int32)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bshd")
        out = {}
        for exp in ("exact", "vexp"):
            pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                             block_s=128, merge_strategy="packed")
            ref = decode_attention(q, kc, vc, clen, layout="bshd",
                                   policy=pol)
            with mesh:
                shr = decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, layout="bshd",
                    policy=pol)
            out[exp] = {
                "finite": bool(jnp.isfinite(shr).all()),
                "delta": float(jnp.abs(ref - shr).max()),
            }
        print(json.dumps(out))
        """)
        for exp, r in res.items():
            assert r["finite"], f"{exp}: packed merge overflowed"
            assert r["delta"] < 2e-3, f"{exp}: {r['delta']}"


@pytest.mark.slow
class TestShardedServing:
    """ISSUE 4 tentpole: the slot engine's SPMD decode wiring."""

    def test_engine_token_identity_all_exp_backends(self):
        """Sharded slot-engine serving (kv_mode="seq", 8-way KV mesh) is
        token-identical to single-device serving for all three exp
        backends — mixed prompt lengths, slot reuse via a 2-slot pool on
        3 requests."""
        res = _run_sub("""
        import numpy as np
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.serve import Server, Request
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import resolve_policy
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
                   for n in (5, 11, 7)]
        def serve(mesh, kv_mode, exp):
            pol = resolve_policy(cfg, env={}, exp_backend=exp,
                                 kernel_backend="pallas")
            srv = Server(cfg, params, max_batch=2, max_seq=64, mesh=mesh,
                         policy=pol, kv_mode=kv_mode)
            reqs = [Request(i, prompts[i].copy(), 5) for i in range(3)]
            srv.run(reqs)
            return {r.rid: r.out for r in reqs}, srv
        out = {}
        for exp in ("exact", "vexp", "vexp_hw"):
            plain, _ = serve(make_host_mesh(1, 1), "auto", exp)
            shard, srv = serve(make_host_mesh(1, 8), "seq", exp)
            out[exp] = {"kv_axis": srv.kv_axis,
                        "identical": plain == shard}
        print(json.dumps(out))
        """)
        for exp, r in res.items():
            assert r["kv_axis"] == "model", f"{exp}: engine did not shard"
            assert r["identical"], f"{exp}: sharded tokens diverged"

    def test_engine_one_collective_per_layer_and_donation(self):
        """The engine's sharded decode program lowers to exactly one
        all_gather (the layers are scanned, so the loop body appears once)
        and zero all_reduces, and its donated cache + position buffers are
        actually consumed (zero cache re-allocation per step)."""
        res = _run_sub("""
        import re
        import numpy as np
        from repro.configs import get_config
        from repro.models import api
        from repro.launch.serve import Server, Request
        from repro.launch.mesh import make_host_mesh
        from repro.runtime import resolve_policy
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
        srv = Server(cfg, params, max_batch=2, max_seq=64,
                     mesh=make_host_mesh(1, 8), policy=pol, kv_mode="seq")
        rng = np.random.default_rng(0)
        r = Request(0, rng.integers(0, cfg.vocab, (5,), dtype=np.int32), 4)
        srv.submit(r)
        g = srv._groups["default"]
        g.admit()
        st = g.state
        txt = st._decode.lower(st.params_decode, g.last, st.data,
                               st.pos_dev, g.live_dev).as_text()
        cache_before, pos_before = st.data["k"], st.pos_dev
        g.decode_once()
        print(json.dumps({
            "all_gather": len(re.findall(r'stablehlo\\.all_gather"', txt)),
            "all_reduce": len(re.findall(r'stablehlo\\.all_reduce"', txt)),
            "cache_donated": cache_before.is_deleted(),
            "pos_donated": pos_before.is_deleted(),
        }))
        """)
        assert res["all_gather"] == 1 and res["all_reduce"] == 0
        assert res["cache_donated"] and res["pos_donated"]


class TestShardingWiring:
    def test_decode_kv_axis_modes(self):
        cfg = get_config("gpt2-small")
        mesh = make_host_mesh()
        assert shd.decode_kv_axis(cfg, mesh, 1, kv_mode="seq") == "model"
        assert shd.decode_kv_axis(cfg, mesh, 1024, kv_mode="batch") is None

    def test_decode_kv_axis_bhsd_head_sharded(self):
        """bhsd caches with head counts divisible by |model| shard heads,
        not sequence — no collective needed, so no seq axis reported."""
        cfg = get_config("phi3-medium-14b")
        mesh = make_host_mesh()
        assert cfg.kv_cache_layout == "bhsd" or True  # layout per config
        ax = shd.decode_kv_axis(cfg, mesh, 1, kv_mode="seq")
        layout = getattr(cfg, "kv_cache_layout", "bshd")
        if layout == "bhsd" and cfg.n_kv_heads % mesh.shape["model"] == 0:
            assert ax is None
        else:
            assert ax == "model"

    def test_no_reference_fallback_branch(self):
        """The acceptance criterion, as an AST rule: the analyzer's
        silent-fallback contract forbids any layout/window/cache_len
        gate and any reference-reduction call inside
        decode_attention_policy (and constrains core decode_attention's
        routing gate) — stronger than the old source-string grep, and
        the same rule CI runs via `make analyze`."""
        from repro.kernels.decode_attention import ops
        from repro.analysis.rules import FallbackContractRule, run_rules
        findings, n_files = run_rules([ops.__file__],
                                      rules=[FallbackContractRule()])
        assert n_files == 1
        assert findings == [], "\n".join(f.render() for f in findings)
