"""Sequence-parallel flash decode: sharded == unsharded, for real.

The tentpole contract (ISSUE 3): a ``shard_map`` decode over a KV cache
sharded along its sequence axis — either layout, ragged per-row (B,)
cache lengths, with or without a sliding window — produces the same
tokens as the unsharded fused ``decode_attention`` under every exp
backend, because the per-shard partial (m, l, acc) statistics merge
through the exact (associative + commutative) algebra of
``core.softmax.stats_merge``.

Sub-process tests force 8 host-platform devices (XLA_FLAGS must be set
before jax initializes); in-process tests cover the wiring that needs no
mesh. A CI job additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (make spmd-test).
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_AUTOTUNE_CACHE"] = "off"
import sys
sys.path.insert(0, {src!r})
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_sharded)
from repro.kernels.dispatch import dispatch
from repro.runtime import ExecPolicy

def mesh2x4():
    kw = ({{"axis_types": (jax.sharding.AxisType.Auto,) * 2}}
          if hasattr(jax.sharding, "AxisType") else {{}})
    return jax.make_mesh((2, 4), ("data", "model"), **kw)

def qkv(b, h, hkv, d, smax, layout, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    shape = ((b, hkv, smax, d) if layout == "bhsd" else (b, smax, hkv, d))
    kc = jax.random.normal(ks[1], shape, jnp.float32)
    vc = jax.random.normal(ks[2], shape, jnp.float32)
    return q, kc, vc

def shard_cache(mesh, kc, vc, layout):
    spec = [None] * 4
    spec[2 if layout == "bhsd" else 1] = "model"
    s = NamedSharding(mesh, P(*spec))
    return jax.device_put(kc, s), jax.device_put(vc, s)
"""


def _run_sub(body: str) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _PRELUDE.format(src=os.path.abspath(src)) \
        + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestShardedDecode:
    @pytest.mark.parametrize("layout", ["bshd", "bhsd"])
    def test_token_identical_all_exp_backends(self, layout):
        """KV-seq-sharded decode == unsharded fused decode: allclose values
        and identical greedy tokens (argmax of projected logits), for all
        three exp backends, with ragged (B,) cache lengths including a
        length-1 row and a shard-boundary-straddling one."""
        res = _run_sub(f"""
        layout = {layout!r}
        b, h, hkv, d, smax = 3, 8, 4, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, layout)
        clen = jnp.array([1, 700, 1024], jnp.int32)
        w = jax.random.normal(jax.random.PRNGKey(7), (h * d, 256),
                              jnp.float32)
        mesh = mesh2x4()
        out = {{}}
        for exp in ("exact", "vexp", "vexp_hw"):
            pol = ExecPolicy(exp_backend=exp, kernel_backend="pallas",
                             block_s=128)
            ref = decode_attention(q, kc, vc, clen, layout=layout,
                                   policy=pol)
            kcs, vcs = shard_cache(mesh, kc, vc, layout)
            with mesh:
                shr = decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, layout=layout,
                    policy=pol)
            tok_r = jnp.argmax(ref.reshape(b, -1) @ w, -1)
            tok_s = jnp.argmax(shr.reshape(b, -1) @ w, -1)
            out[exp] = {{
                "delta": float(jnp.abs(ref - shr).max()),
                "tokens_equal": bool((tok_r == tok_s).all()),
            }}
        print(json.dumps(out))
        """)
        for exp, r in res.items():
            assert r["tokens_equal"], f"{exp}: greedy tokens diverged"
            assert r["delta"] < 2e-3, f"{exp}: {r['delta']}"

    def test_windowed_sharded(self):
        """Sliding-window sharded decode: shards outside the window
        contribute the merge identity; result matches the unsharded
        windowed kernel and the O(S) reference."""
        res = _run_sub("""
        from repro.kernels.decode_attention import decode_attention_ref
        b, h, hkv, d, smax = 2, 4, 2, 64, 1024
        q, kc, vc = qkv(b, h, hkv, d, smax, "bhsd", seed=3)
        clen = jnp.array([900, 1024], jnp.int32)
        pol = ExecPolicy(kernel_backend="pallas", block_s=128)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bhsd")
        out = {}
        for win in (64, 200):
            fused = decode_attention(q, kc, vc, clen, window=win,
                                     policy=pol)
            oracle = decode_attention_ref(q, kc, vc, clen, window=win)
            with mesh:
                shr = decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, window=win,
                    layout="bhsd", policy=pol)
            out[str(win)] = {
                "d_fused": float(jnp.abs(shr - fused).max()),
                "d_oracle": float(jnp.abs(shr - oracle).max()),
            }
        print(json.dumps(out))
        """)
        for win, r in res.items():
            assert r["d_fused"] < 2e-3, f"window {win}: {r}"
            assert r["d_oracle"] < 4e-3, f"window {win}: {r}"

    def test_dispatch_entry_and_reference_parity(self):
        """kernels.dispatch('decode_attention_sharded'): the pallas entry
        runs the shard_map partial+psum path; the reference entry lowers
        the same sharded cache through GSPMD — both match the
        single-device result."""
        res = _run_sub("""
        b, h, hkv, d, smax = 2, 8, 4, 64, 512
        q, kc, vc = qkv(b, h, hkv, d, smax, "bshd", seed=5)
        clen = jnp.array([313, 512], jnp.int32)
        mesh = mesh2x4()
        kcs, vcs = shard_cache(mesh, kc, vc, "bshd")
        pol_p = ExecPolicy(kernel_backend="pallas", block_s=128)
        pol_r = ExecPolicy(kernel_backend="reference")
        single = decode_attention(q, kc, vc, clen, layout="bshd",
                                  policy=pol_p)
        with mesh:
            fused = dispatch("decode_attention_sharded", pol_p)(
                q, kcs, vcs, clen, mesh=mesh, layout="bshd", policy=pol_p)
            ref = jax.jit(lambda *a: dispatch(
                "decode_attention_sharded", pol_r)(
                    *a, mesh=mesh, layout="bshd", policy=pol_r))(
                    q, kcs, vcs, clen)
        print(json.dumps({
            "d_fused": float(jnp.abs(fused - single).max()),
            "d_ref": float(jnp.abs(ref - single).max()),
        }))
        """)
        assert res["d_fused"] < 2e-3
        assert res["d_ref"] < 2e-3

    def test_ragged_shard_local_padding_masked(self):
        """Shard-local block padding sits at absolute positions that are
        valid on other shards — it must never leak into the scores (a
        too-small block_s forces per-shard padding)."""
        res = _run_sub("""
        b, h, hkv, d, smax = 2, 4, 4, 64, 344   # 86 per shard: pads to 128
        q, kc, vc = qkv(b, h, hkv, d, smax, "bhsd", seed=11)
        clen = jnp.array([344, 129], jnp.int32)
        pol = ExecPolicy(kernel_backend="pallas", block_s=64)
        mesh = mesh2x4()
        single = decode_attention(q, kc, vc, clen, policy=pol)
        spec = NamedSharding(mesh, P(None, None, "model", None))
        kcs, vcs = jax.device_put(kc, spec), jax.device_put(vc, spec)
        with mesh:
            shr = decode_attention_sharded(q, kcs, vcs, clen, mesh=mesh,
                                           layout="bhsd", policy=pol)
        print(json.dumps({"delta": float(jnp.abs(shr - single).max())}))
        """)
        assert res["delta"] < 2e-3


class TestShardingWiring:
    def test_decode_kv_axis_modes(self):
        cfg = get_config("gpt2-small")
        mesh = make_host_mesh()
        assert shd.decode_kv_axis(cfg, mesh, 1, kv_mode="seq") == "model"
        assert shd.decode_kv_axis(cfg, mesh, 1024, kv_mode="batch") is None

    def test_decode_kv_axis_bhsd_head_sharded(self):
        """bhsd caches with head counts divisible by |model| shard heads,
        not sequence — no collective needed, so no seq axis reported."""
        cfg = get_config("phi3-medium-14b")
        mesh = make_host_mesh()
        assert cfg.kv_cache_layout == "bhsd" or True  # layout per config
        ax = shd.decode_kv_axis(cfg, mesh, 1, kv_mode="seq")
        layout = getattr(cfg, "kv_cache_layout", "bshd")
        if layout == "bhsd" and cfg.n_kv_heads % mesh.shape["model"] == 0:
            assert ax is None
        else:
            assert ax == "model"

    def test_no_reference_fallback_branch(self):
        """The acceptance criterion, literally: decode_attention_policy
        must not contain a layout/window fallback to the reference
        reduction."""
        import inspect
        from repro.kernels.decode_attention import ops
        src = inspect.getsource(ops.decode_attention_policy)
        assert "core_decode" not in src
        assert 'layout != "bhsd"' not in src
        assert "window is not None" not in src
