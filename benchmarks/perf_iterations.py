"""§Perf hillclimbing driver: hypothesis → change → re-lower → re-count.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  A  command-r-35b × prefill_32k   — paper-representative (FA-2 prefill),
                                     largest memory term
  B  mamba2-1.3b   × prefill_32k   — the collective-bound cell
  C  stablelm-3b   × decode_32k    — worst useful-ratio / MFU

Each iteration is a ModelConfig knob (the code change itself lives in
core/models, gated by the knob so baseline and optimized both stay
buildable). ``python -m benchmarks.perf_iterations`` recounts every
variant via the dry-run's unrolled count pass and writes
benchmarks/artifacts/perf/<cell>__<tag>.json.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts", "perf")

CELLS = {
    "A": ("command-r-35b", "prefill_32k"),
    "B": ("mamba2-1.3b", "prefill_32k"),
    "C": ("stablelm-3b", "decode_32k"),
}

# tag -> (cell, description, config overrides)
VARIANTS = {
    "A0_baseline": ("A", "paper-faithful: f32 upcasts, block_k=512", {}),
    "A1_bf16_mm": ("A", "bf16 matmul inputs + f32 accum in FA-2",
                   {"attn_mm_dtype": "bf16"}),
    "A2_block2k": ("A", "A1 + KV block 512->2048 (acc rescale traffic /4)",
                   {"attn_mm_dtype": "bf16", "attn_block_k": 2048}),
    "B0_baseline": ("B", "repeat-based SSD (pre-B1 code), f32", {}),
    "B1_grouped": ("B", "grouped SSD einsums (no per-head B/C/state "
                        "repeats)", {}),
    "B2_bf16_mm": ("B", "B1 + bf16 CB^T matmul inputs",
                   {"attn_mm_dtype": "bf16"}),
    "C0_baseline": ("C", "f32 cache upcast decode", {}),
    "C1_bf16_cache": ("C", "bf16 cache reads + f32 accum",
                      {"attn_mm_dtype": "bf16"}),
    "C2_bf16_logits": ("C", "C1 + bf16 unembed matmul inputs",
                       {"attn_mm_dtype": "bf16",
                        "logits_mm_dtype": "bf16"}),
    "C3_bhsd_cache": ("C", "C2 + head-major (B,Hkv,S,hd) cache: no "
                           "transpose, heads shard over model",
                      {"attn_mm_dtype": "bf16", "logits_mm_dtype": "bf16",
                       "kv_cache_layout": "bhsd"}),
    "B3_bf16_streams": ("B", "B2 + SSD intra-chunk score/decay/x streams "
                             "in bf16 (f32 accum)",
                        {"attn_mm_dtype": "bf16"}),
}


def attention_quadratic_split(tag_cfg, arch, shape_name):
    """Isolate the O(S^2) attention bytes by a two-point fit in S:
    bytes(S) = a*S + b*S^2 with S2 = 2*S1 =>
    b = (bytes(S2) - 2*bytes(S1)) / (2*S1^2)."""
    import dataclasses as dc
    from repro.configs import get_config, SHAPES, InputShape
    from repro.launch.dryrun import count_cell
    cfg = dc.replace(get_config(arch), **tag_cfg)
    s2 = SHAPES[shape_name]
    s1 = InputShape("half", s2.seq_len // 2, s2.global_batch, s2.kind)
    c2 = count_cell(cfg, s2, 256)
    c1 = count_cell(cfg, s1, 256)
    b2, b1 = c2["bytes_per_chip"], c1["bytes_per_chip"]
    quad_coef = (b2 - 2 * b1) / (2 * s1.seq_len ** 2)
    quad = quad_coef * s2.seq_len ** 2
    return {"bytes_total": b2, "bytes_quadratic": quad,
            "bytes_linear": b2 - quad,
            "flops_per_chip": c2["flops_per_chip"]}


def pallas_fa_bytes_per_chip(cfg, shape, block_q=1024):
    """Structural HBM traffic of the Pallas FA-2 kernel (scores/stats/acc
    VMEM-resident): Q and O once, K/V re-read once per Q block (GQA KV
    replicated across the model axis when heads don't divide)."""
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // 16, 1)                           # per dp shard
    h_loc = max(cfg.n_heads // 16, 1)                 # q heads per chip
    hkv_loc = cfg.n_kv_heads if cfg.n_kv_heads % 16 else cfg.n_kv_heads
    hd = cfg.hd
    qo = 2 * b_loc * S * h_loc * hd * 2               # Q + O, bf16
    nq = -(-S // block_q)
    kv = 2 * b_loc * S * hkv_loc * hd * 2 * nq        # K+V per q-block
    return cfg.n_layers * (qo + kv)


def run_variant(tag: str, force=False) -> dict:
    from repro.configs import get_config, SHAPES
    from repro.launch.dryrun import count_cell
    cell, desc, overrides = VARIANTS[tag]
    arch, shape_name = CELLS[cell]
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"{arch}__{shape_name}__{tag}.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    rec = {"tag": tag, "cell": cell, "arch": arch, "shape": shape_name,
           "desc": desc, "overrides": overrides}
    if tag.endswith("0_baseline"):
        # baselines = the original dry-run sweep's counted numbers (taken
        # BEFORE the optimization code landed, where the change is not
        # knob-gated — e.g. B1's grouped einsums)
        src = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun",
                           f"{arch}__{shape_name}__single.json")
        rec.update(json.load(open(src))["counted"])
    else:
        cfg = dataclasses.replace(get_config(arch), **overrides)
        rec.update(count_cell(cfg, SHAPES[shape_name], 256))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_a3(force=False):
    """Iteration A3: fuse attention into the Pallas kernel — replace the
    measured O(S^2) score traffic with the kernel's structural traffic."""
    from repro.configs import get_config, SHAPES
    import dataclasses as dc
    path = os.path.join(ART, "command-r-35b__prefill_32k__A3_pallas.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    over = {"attn_mm_dtype": "bf16", "attn_block_k": 2048}
    split = attention_quadratic_split(over, "command-r-35b", "prefill_32k")
    cfg = dc.replace(get_config("command-r-35b"), **over)
    pal = pallas_fa_bytes_per_chip(cfg, SHAPES["prefill_32k"])
    rec = {"tag": "A3_pallas", "cell": "A", "arch": "command-r-35b",
           "shape": "prefill_32k",
           "desc": "A2 + Pallas-fused FA-2 (scores stay in VMEM): "
                   "quadratic score traffic -> structural Q/O + KV-per-"
                   "q-block traffic (analytic overlay on measured split)",
           "overrides": over,
           "split": split,
           "pallas_attn_bytes_per_chip": pal,
           "flops_per_chip": split["flops_per_chip"],
           "bytes_per_chip": split["bytes_linear"] + pal}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_c4(force=False):
    """Iteration C4 (accounting overlay): on TPU the decode cache is
    donated and every dynamic-update-slice / scan-carry copy aliases in
    place; XLA's bytes-accessed cannot express aliasing, so we subtract
    the copy/DUS write+readback streams and keep one cache read + one
    token write + parameter reads — the kernel's true HBM traffic."""
    from repro.configs import get_config, SHAPES
    import dataclasses as dc
    path = os.path.join(ART, "stablelm-3b__decode_32k__C4_inplace.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    c3 = run_variant("C3_bhsd_cache")
    cfg = get_config("stablelm-3b")
    shape = SHAPES["decode_32k"]
    B, S = shape.global_batch, shape.seq_len
    cache = (B * S * cfg.n_kv_heads * cfg.hd * 2 * 2 * cfg.n_layers) / 256.
    params = cfg.n_params_matmul() * 2 / 256.          # bf16 compute copies
    token_w = (B * cfg.n_kv_heads * cfg.hd * 2 * 2 * cfg.n_layers) / 256.
    act = 20 * B * cfg.d_model * 4 * cfg.n_layers / 256.   # small residuals
    rec = {"tag": "C4_inplace", "cell": "C", "arch": "stablelm-3b",
           "shape": "decode_32k",
           "desc": "C3 + donated in-place cache updates (aliasing overlay):"
                   " one cache read + one token write + param reads",
           "measured_c3_bytes": c3["bytes_per_chip"],
           "flops_per_chip": c3["flops_per_chip"],
           "bytes_per_chip": cache + params + token_w + act,
           "breakdown": {"cache_read": cache, "params": params,
                         "token_write": token_w, "activations": act}}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_b4(force=False):
    """Iteration B4 (structural overlay): a fused Pallas SSD kernel keeps
    the intra-chunk (Q x Q) score/decay tiles and running state in VMEM
    (the same residency argument as A3). True HBM traffic per layer =
    read x once + the projected z/x/B/C/dt streams + write y — all linear
    in S. The measured per-op HLO bytes count every unfused elementwise
    output, a ~30x upper bound here."""
    import dataclasses as dc
    from repro.configs import get_config, SHAPES
    path = os.path.join(ART, "mamba2-1.3b__prefill_32k__B4_fused.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    b3 = run_variant("B3_bf16_streams")
    cfg = get_config("mamba2-1.3b")
    shape = SHAPES["prefill_32k"]
    B, S = shape.global_batch, shape.seq_len
    di, nh, ds, ng, conv_dim = __import__(
        "repro.models.ssm", fromlist=["ssm"]).ssm_dims(cfg)
    tokens = B * S / 256.0                 # per chip
    per_layer = tokens * 2 * (cfg.d_model * 2        # x in + y out
                              + (2 * di + 2 * ng * ds + nh)  # zxbcdt
                              + conv_dim * 2                 # conv in/out
                              + di)                          # gated y
    state_stream = tokens / cfg.ssm_chunk * nh * cfg.ssm_headdim * ds * 4
    bytes_chip = cfg.n_layers * (per_layer + state_stream)         + cfg.n_params_matmul() * 2 / 256.0
    rec = {"tag": "B4_fused", "cell": "B", "arch": "mamba2-1.3b",
           "shape": "prefill_32k",
           "desc": "B3 + fused Pallas SSD kernel (chunk tiles + state in "
                   "VMEM): linear streams only (structural overlay)",
           "measured_b3_bytes": b3["bytes_per_chip"],
           "flops_per_chip": b3["flops_per_chip"],
           "bytes_per_chip": bytes_chip}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print(f"{'tag':<16} {'GF/chip':>10} {'GB/chip':>10} "
          f"{'t_comp':>8} {'t_mem':>8}  desc")
    from .roofline import PEAK_FLOPS, HBM_BW
    for tag in VARIANTS:
        if only and not tag.startswith(only):
            continue
        r = run_variant(tag)
        f, b = r["flops_per_chip"], r["bytes_per_chip"]
        print(f"{tag:<16} {f/1e9:>10.1f} {b/1e9:>10.2f} "
              f"{f/PEAK_FLOPS:>8.4f} {b/HBM_BW:>8.4f}  {r['desc']}")
    if only in (None, "B"):
        r = run_b4()
        f, b = r["flops_per_chip"], r["bytes_per_chip"]
        print(f"{'B4_fused':<16} {f/1e9:>10.1f} {b/1e9:>10.2f} "
              f"{f/PEAK_FLOPS:>8.4f} {b/HBM_BW:>8.4f}  {r['desc'][:60]}")
    if only in (None, "C"):
        r = run_c4()
        f, b = r["flops_per_chip"], r["bytes_per_chip"]
        print(f"{'C4_inplace':<16} {f/1e9:>10.1f} {b/1e9:>10.2f} "
              f"{f/PEAK_FLOPS:>8.4f} {b/HBM_BW:>8.4f}  {r['desc'][:60]}")
    if only in (None, "A"):
        r = run_a3()
        f, b = r["flops_per_chip"], r["bytes_per_chip"]
        print(f"{'A3_pallas':<16} {f/1e9:>10.1f} {b/1e9:>10.2f} "
              f"{f/PEAK_FLOPS:>8.4f} {b/HBM_BW:>8.4f}  {r['desc'][:60]}")


if __name__ == "__main__":
    main()
