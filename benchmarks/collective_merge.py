"""Packed vs split partial-softmax merge microbench -> BENCH_collective_merge.json.

The sequence-parallel decode merge (ISSUE 4 tentpole) can fold the
per-shard (m, l, acc) softmax statistics two ways:

  packed   ONE all_gather of each shard's contiguous [acc | m | l] tile
           (exactly what the flash-decode kernel's packed mode emits),
           alpha-rescaled fold running shard-locally on the gathered axis;
  split    the PR-3 three-collective form: pmax (global m) + psum of the
           alpha-rescaled l + psum of the alpha-rescaled acc.

Both compute the identical associative algebra — this bench isolates the
*collective* cost by timing just the shard_map merge programs on the
serving engine's per-layer decode-statistics tile (the reduced-GPT-2 slot
pool: 4 slots x 4 KV heads x group 1 x head dim 32 — decode merges are
tiny, which is exactly why they are latency- not bandwidth-bound), swept
over shard counts {2, 4, 8} on the fake 8-device host platform
(XLA_FLAGS must land before jax initializes: run standalone or via
benchmarks.run's subprocess section).

Protocol: each timed call runs K data-dependent chained merges inside one
jitted program (amortizes dispatch; the chain keeps XLA from eliding
repeats), arms are interleaved round-robin, and the min over many rounds
is reported — collective rendezvous on the time-shared fake devices has
heavy-tailed scheduler noise that the min cuts through. The packed arm is
fed pre-packed tiles, matching the kernel's direct packed write (no
concatenate on its clock).

  PYTHONPATH=src python -m benchmarks.collective_merge
"""

from __future__ import annotations

import os

if __name__ == "__main__":                       # before any jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import json
import time

OUT_PATH = os.environ.get("BENCH_COLLECTIVE_MERGE_PATH",
                          "BENCH_collective_merge.json")

# The slot engine's per-layer merge unit on the reduced GPT-2 serving
# config: (max_batch, Hkv, G, hd) m/l stats + (…, hd) accumulator.
SHAPE = dict(b=4, hkv=4, g=1, d=32)
SHARDS = (2, 4, 8)
K_CHAIN = 16         # merges per timed call (dispatch amortization)
N_WARMUP = 4
N_ROUNDS = 41        # interleaved min-of-N (heavy-tailed barrier noise)


def _programs(mesh, nsh, b, hkv, g, d):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.softmax import (SoftmaxStats, stats_merge_collective,
                                    stats_merge_collective_packed)
    from repro.core.vexp import get_exp_fn
    from repro.distributed.compression import shard_map

    exp_fn = get_exp_fn("vexp")

    def _chain(t, merge_one):
        # K data-dependent merges: feed a zero-scaled slice of each result
        # back into the next input so XLA cannot collapse the chain.
        out = jnp.zeros(t.shape[:-1] + (d,), t.dtype)

        def step(c, _):
            t2 = t + 0.0 * jnp.pad(c, [(0, 0)] * (c.ndim - 1) + [(0, 2)])
            return merge_one(t2), None

        out, _ = jax.lax.scan(step, out, None, length=K_CHAIN)
        return out

    def packed_fn(t):
        def merge_one(tile):
            stats, acc = stats_merge_collective_packed(tile, "model",
                                                       exp_fn=exp_fn)
            return acc[..., :d] / jnp.maximum(stats.l, 1e-30)

        return _chain(t[0], merge_one)

    def split_fn(t):
        def merge_one(tile):
            m, l = tile[..., d:d + 1], tile[..., d + 1:d + 2]
            stats, acc = stats_merge_collective(
                SoftmaxStats(m=m, l=l), tile[..., :d], "model",
                exp_fn=exp_fn)
            return acc / jnp.maximum(stats.l, 1e-30)

        return _chain(t[0], merge_one)

    return {name: jax.jit(shard_map(fn, mesh=mesh,
                                    in_specs=(P("model"),), out_specs=P()))
            for name, fn in (("packed", packed_fn), ("split", split_fn))}


def run_sweep() -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    b, hkv, g, d = (SHAPE[k] for k in ("b", "hkv", "g", "d"))
    ndev = len(jax.devices())
    records = []
    for nsh in SHARDS:
        if nsh > ndev:
            continue
        mesh = jax.make_mesh((nsh,), ("model",))
        ks = jax.random.split(jax.random.PRNGKey(nsh), 3)
        # per-shard statistics with a realistic m spread (each shard saw a
        # different slice of the scores)
        m = jax.random.normal(ks[0], (nsh, b, hkv, g, 1)) * 4.0
        l = jax.random.uniform(ks[1], (nsh, b, hkv, g, 1)) * 100.0 + 1.0
        acc = jax.random.normal(ks[2], (nsh, b, hkv, g, d)) * 30.0
        packed = jax.device_put(jnp.concatenate([acc, m, l], axis=-1),
                                NamedSharding(mesh, P("model")))
        fns = _programs(mesh, nsh, b, hkv, g, d)
        # identical algebra: the two programs must agree before timing
        err = float(jnp.abs(fns["packed"](packed)
                            - fns["split"](packed)).max())
        assert err < 1e-4, f"packed/split merge diverged: {err}"
        for fn in fns.values():
            for _ in range(N_WARMUP):
                jax.block_until_ready(fn(packed))
        best = {name: float("inf") for name in fns}
        for _ in range(N_ROUNDS):
            for name, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(packed))
                best[name] = min(best[name], time.perf_counter() - t0)
        records.append({
            "n_shards": nsh,
            "packed_us": best["packed"] * 1e6 / K_CHAIN,
            "split_us": best["split"] * 1e6 / K_CHAIN,
            "speedup": best["split"] / best["packed"],
            "max_abs_delta": err,
        })
    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "n_devices": ndev,
        "shape": SHAPE,
        "k_chain": K_CHAIN,
        "unix_time": time.time(),
        "records": records,
    }


def report():
    """Benchmark rows + BENCH_collective_merge.json side effect."""
    payload = run_sweep()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for r in payload["records"]:
        nsh = r["n_shards"]
        rows.append((f"shards{nsh}/packed", r["packed_us"],
                     "single all_gather of the [acc|m|l] tile"))
        rows.append((f"shards{nsh}/split", r["split_us"],
                     f"pmax + 2xpsum; packed is {r['speedup']:.2f}x"))
    rows.append(("json", 0.0, f"written to {OUT_PATH}"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"collective_merge/{name},{val:.6g},{note}")
