"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. "us_per_call" carries the
benchmark's primary scalar (latency in us where the benchmark is a timing,
otherwise the headline metric); "derived" carries the paper target /
context.

  PYTHONPATH=src python -m benchmarks.run [--section NAME] [--with-roofline]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def _emit(section, rows):
    for name, val, note in rows:
        print(f"{section}/{name},{val:.6g},{str(note).replace(',', ';')}")


def _subprocess_report(module: str):
    """Benchmarks that need a multi-device host platform require XLA_FLAGS
    set *before* jax initializes — run them in a subprocess and relay
    their rows."""
    import os
    import subprocess

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}"],
        capture_output=True, text=True, timeout=3600, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{out.stderr[-2000:]}")
    rows = []
    for line in out.stdout.strip().splitlines():
        if not line.startswith(f"{module}/"):
            continue
        name, val, note = line.split(",", 2)
        rows.append((name.split("/", 1)[1], float(val), note))
    return rows


def _sharded_decode_report():
    return _subprocess_report("sharded_decode")


def _collective_merge_report():
    return _subprocess_report("collective_merge")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default=None)
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the roofline table (needs dryrun artifacts)")
    args = ap.parse_args()

    from . import (snitch_model, exp_accuracy, model_accuracy,
                   softmax_speed, flashattention, e2e_models,
                   policy_sweep, serving, paged_serving, speculative)

    sections = {
        "snitch_model": snitch_model.report,       # Fig.6 + Table III
        "exp_accuracy": exp_accuracy.report,       # §V-A + Table IV
        "model_accuracy": model_accuracy.report,   # Table II
        "softmax_speed": softmax_speed.report,     # Fig.6a-c
        "flashattention": flashattention.report,   # Fig.6d-f
        "e2e_models": e2e_models.report,           # Fig.1 + Fig.8
        "policy_sweep": policy_sweep.report,       # ExecPolicy backends
        "serving": serving.report,                 # continuous batching
        "paged_serving": paged_serving.report,     # paged KV + prefix cache
        "speculative": speculative.report,         # draft/verify decode
        "sharded_decode": _sharded_decode_report,  # seq-parallel decode
        "collective_merge": _collective_merge_report,  # packed vs split
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections.items():
        if args.section and name != args.section:
            continue
        try:
            _emit(name, fn())
        except Exception:
            failures += 1
            print(f"{name},nan,ERROR", file=sys.stdout)
            traceback.print_exc()

    if not args.skip_roofline and not args.section:
        try:
            from . import roofline
            rows = roofline.build_table()
            for r in rows:
                print(f"roofline/{r['arch']}__{r['shape']},"
                      f"{max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6:.6g},"
                      f"bottleneck={r['bottleneck']};MFU={r['roofline_fraction']:.3f};"
                      f"useful={r['useful_ratio']:.2f}")
        except Exception:
            traceback.print_exc()

    if failures:
        raise SystemExit(f"{failures} benchmark sections failed")


if __name__ == "__main__":
    main()
