"""FlashAttention-2 benchmark — Fig. 6d-f analogue (GPT-2 config, hd=64).

  1. Snitch cycle model: throughput / softmax-share / energy across seq
     lengths for baseline vs optimized partial softmax (Fig. 6d-f),
  2. our JAX/Pallas stack: wall-time of the flash kernel path with exact
     vs vexp exponentials (CPU, informational) and numerical agreement.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from . import snitch_model as sm
from repro.core.attention import attention_flash

SEQS = (256, 512, 1024, 2048)


def snitch_fa2():
    rows = []
    for s in SEQS:
        shape = sm.AttnShape(seq=s)
        for config in ("baseline", "sw_exp_hw_optim"):
            c = sm.fa2_cycles(shape, config)
            rows.append({"seq": s, "config": config,
                         "cycles": c["total"],
                         "softmax_share": c["softmax"] / c["total"]})
    return rows


def jax_fa2(b=1, s=512, h=12, hd=64):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = {}
    for impl in ("exact", "vexp"):
        f = jax.jit(lambda q, k, v, impl=impl: attention_flash(
            q, k, v, causal=True, exp_impl=impl, block_k=128))
        f(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            r = f(q, k, v)
        r.block_until_ready()
        out[impl] = (time.perf_counter() - t0) / 5
    a = attention_flash(q, k, v, causal=True, exp_impl="exact")
    bv = attention_flash(q, k, v, causal=True, exp_impl="vexp")
    out["max_delta"] = float(jnp.abs(a - bv).max())
    return out


def report():
    rows = []
    for s in SEQS:
        shape = sm.AttnShape(seq=s)
        rows.append((f"snitch_fa2_{s}_speedup_x", sm.fa2_speedup(shape),
                     "paper Fig.6d: up to 8.2x"))
    rows.append(("snitch_fa2_softmax_share_baseline",
                 sm.fa2_softmax_share(sm.AttnShape(2048), "baseline"),
                 "paper Fig.6e: dominant"))
    rows.append(("snitch_fa2_softmax_share_optim",
                 sm.fa2_softmax_share(sm.AttnShape(2048), "sw_exp_hw_optim"),
                 "paper Fig.6e: ~6%"))
    rows.append(("snitch_fa2_energy_x", sm.fa2_energy_ratio(),
                 "paper Fig.6f: up to 4.1x"))
    j = jax_fa2()
    rows.append(("jax_fa2_exact_ms", j["exact"] * 1e3, "CPU wall (info)"))
    rows.append(("jax_fa2_vexp_ms", j["vexp"] * 1e3, "CPU wall (info)"))
    rows.append(("jax_fa2_max_delta", j["max_delta"], "exact vs vexp"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"{name:40s} {val:12.4f}  {note}")
