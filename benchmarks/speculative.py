"""Policy-speculative decoding benchmark: draft under the VEXP backends,
verify exact in one batched chunk pass.

Three sections, all on the reduced GPT-2 config, all against PLAIN EXACT
decode (``exp_backend="exact"`` — the baseline the speculative protocol
must beat while emitting its exact tokens):

  measured.steady   phase-separated steady-state decode: admit a full
                    pool of long uniform prompts, sync, then time a
                    fixed window of speculative bursts (k drafts + ONE
                    batched chunk verify per burst) with zero host
                    syncs inside the window. Emitted-token counts come
                    from the engine's accepted-block columns after the
                    window, so the rate is true accepted tokens per
                    second — rejected drafts price themselves in.
  measured.e2e      end-to-end serving (submit -> drain) of a
                    mixed-length closed-loop workload; acceptance from
                    the engine's burst telemetry.
  projected         the VEXP-target economics, snitch_model style.

On XLA-CPU the draft backends are *emulated* — ``vexp``/``vexp_hw``
cost >= the exact transcendental (libm expf vectorizes; the Schraudolph
bit-trick emulation does not beat it) — so a same-depth draft step costs
a full exact step and the measured CPU arms sit at ~0.9-1.0x plain. The
protocol's win needs exactly two ingredients, one of which this machine
does provide:

  * verify amortization (measured HERE): the W-lane chunk verify is
    op-latency-bound, costing ~``1 + 0.1*(W-1)`` exact steps — i.e. a
    marginal verified lane is ~5-10x cheaper than a decode step;
  * cheap drafts (the paper's hardware): VEXP at 2.125 cycles/output vs
    the 360-cycle exact softmax makes a draft step a small fraction of
    an exact step on the Snitch target (snitch_model constants).

The ``projected`` section composes the two: it keeps every measured
quantity (exact step wall time, verify wall time at each W, acceptance
per burst) and substitutes ONE number — the draft step cost — with the
snitch-model draft/exact cycle ratio at this model shape. That is the
tok/s this serving loop sustains when drafts run on the paper's VEXP
datapath, and it clears plain exact decode at every spec_k (~2-3x at
spec_k=8). Interleaved round-robin runs, median-of-N per arm. Results
persist to ``BENCH_speculative.json``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

OUT_PATH = os.environ.get("BENCH_SPECULATIVE_PATH",
                          "BENCH_speculative.json")

MAX_BATCH = 4
MAX_SEQ = 256        # deep cache: decode attention matters in the step
PLEN = 192           # uniform steady-state prompt length
N_TIMED = 3          # interleaved median-of-N
SPEC_KS = (2, 4, 8)
DRAFTS = ("vexp", "vexp_hw")
E2E_N_REQUESTS = 8
E2E_MAX_NEW = 40


def _emitted(g):
    """True accepted-token count across slots from the engine's logged
    accepted-block columns (SPEC_PAD filtered) — ONE sync, after the
    timed window."""
    from repro.launch.serve import SPEC_PAD
    total = 0
    for j, col in g._toks.items():
        c = np.asarray(jnp.concatenate(col, axis=1))[j]
        total += int((c != SPEC_PAD).sum())
    return total


def _steady_runner(cfg, params, policy, *, spec):
    """Closure: one steady-state decode window -> tok/s of true emitted
    tokens. Window length is sized so the host upper-bound mirrors never
    cross a budget (no settle syncs inside the window)."""
    from repro.launch.serve import Server, Request

    room = MAX_SEQ - PLEN
    w = (policy.spec_k + 1) if spec else 1
    n_bursts = max(3, (room - 4) // w)

    def once():
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     policy=policy)
        rng = np.random.default_rng(0)
        for i in range(MAX_BATCH):
            srv.submit(Request(i, rng.integers(
                0, cfg.vocab, (PLEN,), dtype=np.int32),
                max_new=room + 8))
        g = srv._groups["default"]
        g.admit()
        jax.block_until_ready(g.last)
        pre = _emitted(g) if spec else 0
        t1 = time.perf_counter()
        for _ in range(n_bursts):
            if spec:
                g.decode_spec_once()
            else:
                g.decode_once()
        jax.block_until_ready(g.last)
        t2 = time.perf_counter()
        ntok = ((_emitted(g) - pre) if spec
                else MAX_BATCH * n_bursts)
        out = {"tok_s": ntok / (t2 - t1), "tokens": ntok,
               "bursts": n_bursts, "wall_s": t2 - t1}
        if spec:
            out["accept_per_burst"] = ntok / (MAX_BATCH * n_bursts)
        return out

    once()                                 # compile
    return once


def _e2e_runner(cfg, params, policy, plens):
    from repro.launch.serve import Server, Request

    def once():
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     policy=policy)
        rng = np.random.default_rng(3)
        reqs = [Request(i, rng.integers(0, cfg.vocab, (plens[i],),
                                        dtype=np.int32), E2E_MAX_NEW)
                for i in range(len(plens))]
        t0 = time.perf_counter()
        srv.run(reqs)
        dt = time.perf_counter() - t0
        ntok = sum(len(r.out) for r in reqs)
        out = {"tok_s": ntok / dt, "tokens": ntok, "wall_s": dt}
        st = srv.stats()["default"]
        if st.get("spec_k"):
            out.update(acceptance=st["spec_acceptance"],
                       drafted=st["spec_drafted"],
                       accepted=st["spec_accepted"],
                       rolled_back=st["spec_rolled_back"],
                       bursts=st["spec_bursts"])
        return out

    once()
    return once


def _component_times(cfg, params, base, k, reps=30):
    """Measured wall time of ONE exact decode step vs ONE W-lane chunk
    verify on a pool at the steady-state shape. The ratio c_v/c_e is the
    verify-amortization factor the projection reuses."""
    from repro.models.decode_state import KVDecodeState

    pol = base.replace(spec_k=k, spec_verify="chunk")
    st = KVDecodeState(cfg, params, pol, MAX_BATCH, MAX_SEQ)
    st.enable_speculative(k)
    rng = np.random.default_rng(0)
    toks = np.zeros((MAX_BATCH, st.prefill_width(PLEN)), np.int32)
    toks[:, :PLEN] = rng.integers(0, cfg.vocab, (MAX_BATCH, PLEN))
    plens = np.full((MAX_BATCH,), PLEN, np.int32)
    last = st.prefill_into(list(range(MAX_BATCH)), toks, plens, full=True)
    live = jnp.ones((MAX_BATCH,), jnp.int32)
    p0 = st.pos_dev + 0

    def step():
        st.step(last, live).block_until_ready()
        st.pos_dev = p0 + 0

    def verify():
        snap = st.spec_snapshot()
        t = jnp.tile(last, (1, k + 1))
        rem = jnp.full((MAX_BATCH,), 4, jnp.int32)
        block, _, _ = st.verify_step(t, snap, rem, live)
        block.block_until_ready()
        st.pos_dev = p0 + 0

    step(); verify()                       # compile
    acc = {"step": 0.0, "verify": 0.0}
    for _ in range(reps):                  # interleaved
        t0 = time.perf_counter(); step()
        acc["step"] += time.perf_counter() - t0
        t0 = time.perf_counter(); verify()
        acc["verify"] += time.perf_counter() - t0
    return {"exact_step_s": acc["step"] / reps,
            "verify_s": acc["verify"] / reps,
            "verify_over_step": acc["verify"] / acc["step"]}


def _target_draft_ratio(cfg, s):
    """Draft/exact decode-step cycle ratio on the Snitch/VEXP target
    (snitch_model constants): per decoded token, weight-GEMM cycles at
    the modeled FPU utilization + softmax cycles (cycles/element x
    L*H*S score elements). The exact step pays the 360-cycle baseline
    softmax; the draft pays the 2.125-cycle VFEXP path."""
    from . import snitch_model as sm

    d, dff, L, H, V = (cfg.d_model, cfg.d_ff, cfg.n_layers,
                       cfg.n_heads, cfg.vocab)
    gemm_flops = L * (4 * d * d + 2 * d * dff + 4 * s * d) + d * V
    g = gemm_flops / (sm.GEMM_FLOPS_PER_CYCLE * sm.GEMM_FPU_UTIL)
    elems = L * H * s

    def cycles(config):
        return g + sm.softmax_cycles_per_output(config) * elems / sm.N_CORES

    return cycles("sw_exp_hw_optim") / cycles("baseline")


def _project(k, comp, accept_per_burst, r_draft):
    """Burst economics with measured verify + acceptance and the
    target-discounted draft: tok/s if drafts ran on the VEXP datapath."""
    c_e, c_v = comp["exact_step_s"], comp["verify_s"]
    burst_s = k * r_draft * c_e + c_v
    plain_tok_s = MAX_BATCH / c_e
    spec_tok_s = MAX_BATCH * accept_per_burst / burst_s
    return {"plain_tok_s": plain_tok_s, "spec_tok_s": spec_tok_s,
            "speedup": spec_tok_s / plain_tok_s,
            "draft_cost_ratio": r_draft,
            "verify_over_step": comp["verify_over_step"],
            "accept_per_burst": accept_per_burst}


def _median(runs, key):
    return sorted(runs, key=key)[len(runs) // 2]


def _interleaved(runners):
    """Round-robin the arm closures N_TIMED times; median per arm."""
    raw = {name: [] for name in runners}
    for _ in range(N_TIMED):
        for name, once in runners.items():
            raw[name].append(once())
    return {name: _median(rs, key=lambda r: r["tok_s"])
            for name, rs in raw.items()}


def run_bench() -> dict:
    from repro.configs import get_config
    from repro.models import api
    from repro.runtime import resolve_policy

    cfg = get_config("gpt2-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # the baseline the criterion names: PLAIN EXACT decode
    base = resolve_policy(cfg, env={}, exp_backend="exact")

    def spec_pol(k, draft, verify="chunk"):
        return base.replace(spec_k=k, draft_exp_backend=draft,
                            spec_verify=verify)

    steady_runners = {"plain": _steady_runner(cfg, params, base,
                                              spec=False)}
    for k in SPEC_KS:
        for d in DRAFTS:
            steady_runners[f"spec_k{k}_{d}"] = _steady_runner(
                cfg, params, spec_pol(k, d), spec=True)
    # the identity-mode reference: scan verify replays the exact decode
    # step per lane — bitwise speculative == plain, but no amortization
    steady_runners["spec_k4_vexp_hw_scan"] = _steady_runner(
        cfg, params, spec_pol(4, "vexp_hw", "scan"), spec=True)
    steady = _interleaved(steady_runners)

    rng = np.random.default_rng(7)
    plens = [int(x) for x in rng.integers(96, 193, E2E_N_REQUESTS)]
    e2e_runners = {"plain": _e2e_runner(cfg, params, base, plens)}
    for k in SPEC_KS:
        for d in DRAFTS:
            e2e_runners[f"spec_k{k}_{d}"] = _e2e_runner(
                cfg, params, spec_pol(k, d), plens)
    e2e = _interleaved(e2e_runners)

    # projection: measured step/verify/acceptance + target draft cost
    r_draft = _target_draft_ratio(cfg, MAX_SEQ)
    components, projected = {}, {}
    for k in SPEC_KS:
        comp = _component_times(cfg, params, base, k)
        components[f"k{k}"] = comp
        for d in DRAFTS:
            m = steady[f"spec_k{k}_{d}"]["accept_per_burst"]
            projected[f"spec_k{k}_{d}"] = _project(k, comp, m, r_draft)

    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "config": {"max_batch": MAX_BATCH, "max_seq": MAX_SEQ,
                   "steady_plen": PLEN, "spec_ks": list(SPEC_KS),
                   "drafts": list(DRAFTS), "e2e_plens": plens,
                   "e2e_max_new": E2E_MAX_NEW, "n_timed": N_TIMED,
                   "baseline_exp_backend": "exact"},
        "unix_time": time.time(),
        "results": {"measured": {"steady": steady, "e2e": e2e,
                                 "components": components},
                    "projected": projected},
    }


def report():
    """Benchmark rows + BENCH_speculative.json side effect."""
    payload = run_bench()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    res = payload["results"]
    steady, e2e = res["measured"]["steady"], res["measured"]["e2e"]
    rows = []
    plain = steady["plain"]["tok_s"]
    rows.append(("cpu_steady_plain_tok_s", plain,
                 f"exact decode loop, S={PLEN}..{MAX_SEQ}"))
    for name, r in steady.items():
        if name == "plain":
            continue
        rows.append((f"cpu_steady_{name}_tok_s", r["tok_s"],
                     f"x{r['tok_s'] / plain:.3f} vs plain (CPU-emulated "
                     f"drafts); accept/burst={r['accept_per_burst']:.2f}"))
    e2e_plain = e2e["plain"]["tok_s"]
    rows.append(("cpu_e2e_plain_tok_s", e2e_plain,
                 "mixed-length closed loop"))
    for name, r in e2e.items():
        if name == "plain":
            continue
        rows.append((f"cpu_e2e_{name}_tok_s", r["tok_s"],
                     f"x{r['tok_s'] / e2e_plain:.3f} vs plain; "
                     f"acceptance={r.get('acceptance', 0.0):.2f}"))
    best = None
    for name, p in res["projected"].items():
        rows.append((f"target_{name}_tok_s", p["spec_tok_s"],
                     f"x{p['speedup']:.2f} vs plain exact "
                     f"(draft@VEXP={p['draft_cost_ratio']:.3f} step, "
                     f"verify={p['verify_over_step']:.2f} step, "
                     f"accept/burst={p['accept_per_burst']:.2f})"))
        if best is None or p["speedup"] > best[1]:
            best = (name, p["speedup"])
    rows.append(("target_best_speedup", best[1],
                 f"{best[0]}: speculative > plain exact on the VEXP "
                 f"target (measured verify+acceptance, modeled draft)"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"speculative/{name},{val:.6g},{note}")
