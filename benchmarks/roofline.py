"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) on the single-pod 256-chip mesh:

  compute    = HLO_FLOPs_per_chip / 197 TFLOP/s      (bf16 peak, TPU v5e)
  memory     = HLO_bytes_per_chip / 819 GB/s         (HBM)
  collective = wire_bytes_per_chip / 50 GB/s         (per ICI link)

HLO FLOPs/bytes come from the dry-run's *unrolled count pass* (the scanned
production program under-reports while bodies — see launch/dryrun.py
count_cell; per-chip = global/256, so sharding-induced duplication like
replicated GQA KV projections is not included). Collective wire bytes use
the analytic ring-collective model below, cross-checked against the op
inventory parsed from the compiled HLO.

MODEL_FLOPS (the "useful work" yardstick):
  train   6 * N_active * tokens   (+2*N for the remat re-forward is NOT
                                   counted as useful)
  prefill 2 * N_active * tokens
  decode  2 * N_active * batch    (+ KV-cache attention reads)
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link
CHIPS = 256
TP = 16                   # model axis
DP = 16                   # data axis

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global)."""
    n_act = cfg.n_params_matmul()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_act * B * S
        attn_mult = 3.0
    elif shape.kind == "prefill":
        base = 2.0 * n_act * B * S
        attn_mult = 1.0
    else:
        base = 2.0 * n_act * B
        attn_mult = 1.0
    # attention score/value matmuls (not in 6N)
    attn = 0.0
    if cfg.n_heads:
        ctx = min(S, cfg.sliding_window or S)
        n_attn_layers = (cfg.n_layers // cfg.attn_period
                         if cfg.family == "hybrid" else cfg.n_layers)
        hq = cfg.n_heads * cfg.hd
        if shape.kind == "decode":
            attn = 4.0 * B * ctx * hq * n_attn_layers
        else:
            attn = attn_mult * 4.0 * B * S * ctx * hq * n_attn_layers / 2
    if cfg.family == "ssm":
        # SSD: intra-chunk quadratic + state updates per layer
        q = cfg.ssm_chunk
        di, ds = cfg.d_inner, cfg.ssm_state
        if shape.kind == "decode":
            attn = 2.0 * B * di * ds * 2 * cfg.n_layers
        else:
            per_tok = 2.0 * (q * di + 2 * di * ds)
            attn = attn_mult * B * S * per_tok * cfg.n_layers
    return base + attn


def collective_bytes_per_chip(cfg, shape, rec) -> dict:
    """Analytic ring-collective wire bytes per chip per step.

    TP (model axis, Megatron pattern): 2 activation all-reduces per layer
    (attention out + FFN out) in bf16, ring cost 2*(n-1)/n * local bytes.
    DP (data axis): gradient all-reduce of all parameters in f32 (train
    only); FSDP archs instead reduce-scatter + all-gather (same wire bytes).
    Embedding/logits: one all-reduce of the (local-batch, chunk, or 1) x
    d_model activation for the vocab-parallel matmul + CE reductions.
    Sequence-parallel decode (B < DP): partial-softmax merge all-reduce of
    (B, H, hd) per attention layer over the model axis.
    """
    B, S = shape.global_batch, shape.seq_len
    dpb = max(B // DP, 1)                         # local batch
    d = cfg.d_model
    act = 2.0                                     # bf16 bytes
    ring_tp = 2.0 * (TP - 1) / TP
    ring_dp = 2.0 * (DP - 1) / DP
    L = cfg.n_layers
    n_attn = (L // cfg.attn_period if cfg.family == "hybrid" else L)

    out = {"tp": 0.0, "dp": 0.0, "embed": 0.0, "sp": 0.0, "ep": 0.0}
    tokens_local = dpb * (S if shape.kind != "decode" else 1)
    # TP activation all-reduces: 2 per transformer layer (attn out + FFN
    # out, Megatron row-parallel), 1 for parallel blocks (fused residual)
    # and for SSM layers (col-parallel in_proj needs none; only the
    # row-parallel out_proj reduces).
    ars_per_layer = 1.0 if (cfg.parallel_block
                            or cfg.family == "ssm") else 2.0
    out["tp"] = (ring_tp * ars_per_layer * L
                 * tokens_local * d * act)
    # vocab-parallel logits: all-reduce of CE partials (lse etc.) — small;
    # embedding gather all-to-all approx: tokens * d
    out["embed"] = ring_tp * tokens_local * d * act
    if cfg.n_experts:
        # EP all-to-all (dispatch + combine) of top_k routed token copies
        out["ep"] = 2.0 * cfg.top_k * tokens_local * d * act * (TP - 1) / TP
    if shape.kind == "train":
        # gradients are TP-sharded like the params: use the per-device
        # param bytes from the dry-run artifact (f32 grads match f32 params)
        ppd = (rec.get("analytic_state") or {}).get(
            "params_bytes_per_device") or cfg.n_params() * 4.0 / TP
        out["dp"] = ring_dp * ppd
    if shape.kind == "decode" and B < DP:
        # sequence-parallel flash-decode merge over the model axis
        hq = max(cfg.n_heads, 1) * cfg.hd
        out["sp"] = ring_tp * n_attn * B * hq * 4.0
    out["total"] = sum(out.values())
    return out


def load_artifacts(mesh="single") -> dict:
    out = {}
    for f in glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json")):
        rec = json.load(open(f))
        out[(rec["arch"], rec["shape"])] = rec
    return out


def roofline_row(cfg, shape, rec) -> dict:
    counted = rec.get("counted") or {}
    if "flops_per_chip" in counted:
        flops_chip = counted["flops_per_chip"]
        bytes_chip = counted["bytes_per_chip"]
        src = "hlo-counted"
    else:
        flops_chip = rec.get("flops_per_device", 0)
        bytes_chip = rec.get("bytes_accessed_per_device", 0)
        src = "hlo-scanned(undercount)"
    coll = collective_bytes_per_chip(cfg, shape, rec)
    t_c = flops_chip / PEAK_FLOPS
    t_m = bytes_chip / HBM_BW
    t_x = coll["total"] / LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    step_time = max(t_c, t_m, t_x)
    mfu = (mf / CHIPS / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        "arch": cfg.arch_id, "shape": shape.name,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": dom,
        "model_flops": mf,
        "hlo_flops_global": flops_chip * CHIPS,
        "useful_ratio": mf / (flops_chip * CHIPS) if flops_chip else 0.0,
        "roofline_fraction": mfu,
        "flops_src": src,
        "coll_breakdown": coll,
    }


def build_table(mesh="single"):
    from repro.configs import REGISTRY, SHAPES
    arts = load_artifacts(mesh)
    rows = []
    for (arch, shape_name), rec in sorted(arts.items()):
        cfg = REGISTRY[arch]
        rows.append(roofline_row(cfg, SHAPES[shape_name], rec))
    return rows


def main():
    rows = build_table()
    hdr = (f"{'arch':<18} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'collect_s':>10} {'bottleneck':<11} {'useful':>7} {'MFU':>6}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:<18} {r['shape']:<12} {r['compute_s']:>10.4f} "
              f"{r['memory_s']:>10.4f} {r['collective_s']:>10.4f} "
              f"{r['bottleneck']:<11} {r['useful_ratio']:>7.2f} "
              f"{r['roofline_fraction']:>6.3f}")


if __name__ == "__main__":
    main()
