"""Softmax kernel benchmark — Fig. 6a-c analogue.

Two complementary views:
  1. the Snitch cycle/energy model across the paper's four configurations
     and a sweep of row lengths (reproduces Fig. 6a-c),
  2. TPU-side structural comparison of our kernels: VPU-op counts per
     element for the vexp datapath vs a transcendental exp, plus measured
     CPU wall time of the jitted XLA softmax (exact vs vexp) as a
     same-machine sanity check (CPU timings are NOT TPU predictions).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from . import snitch_model as sm
from repro.core.softmax import softmax as vexp_softmax


SEQ_SWEEP = (128, 512, 2048, 8192)


def snitch_sweep():
    rows = []
    for n in SEQ_SWEEP:
        for config in sm.SOFTMAX_CONFIGS:
            lat = sm.softmax_latency_s(n * n, config)     # SxS attn scores
            en = sm.softmax_energy_pj(n * n, config) * 1e-12
            rows.append({"seq": n, "config": config,
                         "latency_s": lat, "energy_j": en})
    return rows


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def xla_wall_time(rows=256, cols=2048):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, cols))
    f_exact = jax.jit(lambda x: jax.nn.softmax(x, -1))
    f_vexp = jax.jit(lambda x: vexp_softmax(x, -1, exp_impl="vexp"))
    return {"exact_us": _time(f_exact, x) * 1e6,
            "vexp_us": _time(f_vexp, x) * 1e6}


def vpu_op_count():
    """Static op counts of one exp evaluation (from the algorithm): the
    paper's hardware collapses these into one 2-cycle instruction; on TPU
    they are ~11 cheap VPU ops vs XLA's exp expansion (~25+ ops incl. a
    polynomial ladder) — counted from the jaxpr."""
    import jax.core

    def count_ops(fn):
        jaxpr = jax.make_jaxpr(fn)(jnp.ones((8, 128), jnp.float32))
        return sum(1 for e in jaxpr.jaxpr.eqns)

    from repro.core.vexp import vexp_f32
    return {"vexp_ops": count_ops(vexp_f32),
            "exact_exp_ops": count_ops(jnp.exp)}


def report():
    rows = []
    base = [r for r in snitch_sweep() if r["config"] == "baseline"]
    opt = [r for r in snitch_sweep() if r["config"] == "sw_exp_hw_optim"]
    for b, o in zip(base, opt):
        rows.append((f"snitch_softmax_{b['seq']}_speedup_x",
                     b["latency_s"] / o["latency_s"], "paper Fig.6a: 162.7x"))
    rows.append(("snitch_softmax_energy_x", sm.softmax_energy_reduction(),
                 "paper Fig.6c: 74.3x"))
    wt = xla_wall_time()
    rows.append(("xla_softmax_exact_us", wt["exact_us"], "CPU wall (info)"))
    rows.append(("xla_softmax_vexp_us", wt["vexp_us"], "CPU wall (info)"))
    ops = vpu_op_count()
    rows.append(("vexp_jaxpr_ops", ops["vexp_ops"],
                 "vs exp " + str(ops["exact_exp_ops"])))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"{name:38s} {val:12.3f}  {note}")
