"""Accuracy benchmark — paper §V-A + Table IV.

Paper claims: mean relative error 0.14%, max 0.78% vs glibc exp; softmax
MSE 1.62e-9 (Table IV, vs other softmax accelerators); accuracy parity on
GPT-2/ViT (Table II — see model_accuracy.py for the model-level study).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import vexp as V


def exp_relative_error(n=200_000, lo=-30.0, hi=10.0, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(lo, hi, n).astype(np.float32)
    ref = np.exp(x.astype(np.float64))
    out = {}
    y32 = np.asarray(V.vexp_f32(jnp.asarray(x)), np.float64)
    rel32 = np.abs(y32 - ref) / ref
    out["vexp_f32"] = {"mean_rel": rel32.mean(), "max_rel": rel32.max()}
    xb = jnp.asarray(x, jnp.bfloat16)
    refb = np.exp(np.asarray(xb, np.float64))
    yhw = np.asarray(V.vexp_bf16_fixedpoint(xb), np.float64)
    relh = np.abs(yhw - refb) / refb
    out["vexp_hw_bf16"] = {"mean_rel": relh.mean(), "max_rel": relh.max()}
    return out


def softmax_mse(rows=512, cols=512, scale=3.0, seed=1):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, cols)) * scale).astype(np.float32)
    xb = jnp.asarray(x, jnp.bfloat16)
    xr = np.asarray(xb, np.float64)
    er = np.exp(xr - xr.max(-1, keepdims=True))
    ref = er / er.sum(-1, keepdims=True)
    out = {}
    for name, fn in [("vexp_f32", V.vexp_f32),
                     ("vexp_hw_bf16", V.vexp_bf16_fixedpoint)]:
        e = np.asarray(fn(xb - jnp.max(xb, -1, keepdims=True)), np.float64)
        sm = e / e.sum(-1, keepdims=True)
        out[name] = float(np.mean((sm - ref) ** 2))
    return out


def report():
    rows = []
    errs = exp_relative_error()
    for name, e in errs.items():
        rows.append((f"exp_{name}_mean_rel_pct", e["mean_rel"] * 100,
                     "paper: 0.14%"))
        rows.append((f"exp_{name}_max_rel_pct", e["max_rel"] * 100,
                     "paper: 0.78%"))
    for name, mse in softmax_mse().items():
        rows.append((f"softmax_mse_{name}", mse, "paper Table IV: 1.62e-9"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"{name:35s} {val:12.4e}  {note}")
