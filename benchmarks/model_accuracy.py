"""Model-level accuracy parity — the Table II analogue.

The paper shows GPT-2/ViT accuracy is unchanged when BF16 exp is replaced
by the VEXP approximation (no retraining). Pretrained weights are not
available offline, so we measure the *forward parity* that underlies that
result on a randomly-initialized GPT-2-small-family model at BF16:

  * max/mean absolute logit delta (exact exp vs vexp vs the HW model),
  * greedy-decode argmax agreement over many positions,
  * per-token loss delta,
  * softmax-distribution KL divergence.

Table II's "<0.1% accuracy change" corresponds to argmax agreement ~100%
and loss deltas far below run-to-run noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.data import SyntheticLM


def _outputs(cfg, params, batch):
    x = api.forward(params, cfg, batch)
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    loss = api.loss_fn(params, cfg, batch)
    return np.asarray(logits), float(loss)


def parity_study(b=4, s=128, seed=0):
    base = get_config("gpt2-small")
    cfg = dataclasses.replace(base.reduced(), n_layers=4, d_model=256,
                              n_heads=8, head_dim=32, d_ff=1024)
    params = api.init_params(
        dataclasses.replace(cfg, exp_impl="exact"), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLM(cfg, b, s, seed=seed).batch(0).items()}
    out = {}
    ref_logits, ref_loss = _outputs(
        dataclasses.replace(cfg, exp_impl="exact"), params, batch)
    ref_p = jax.nn.softmax(jnp.asarray(ref_logits), axis=-1)
    # vexp_hw works on f32 activations since the registry entry routes
    # through bf16 (exactly what feeding the silicon would do).
    for impl in ("vexp", "vexp_hw"):
        c = dataclasses.replace(cfg, exp_impl=impl)
        logits, loss = _outputs(c, params, batch)
        p = jax.nn.softmax(jnp.asarray(logits), axis=-1)
        kl = jnp.sum(ref_p * (jnp.log(ref_p + 1e-12)
                              - jnp.log(p + 1e-12)), -1)
        out[impl] = {
            "max_logit_delta": float(np.abs(logits - ref_logits).max()),
            "mean_logit_delta": float(np.abs(logits - ref_logits).mean()),
            "argmax_agree_pct": float(
                (logits.argmax(-1) == ref_logits.argmax(-1)).mean() * 100),
            "loss_delta": abs(loss - ref_loss),
            "loss_ref": ref_loss,
            "mean_kl": float(jnp.mean(kl)),
        }
    return out


def report():
    rows = []
    for impl, m in parity_study().items():
        rows.append((f"parity_{impl}_argmax_agree_pct",
                     m["argmax_agree_pct"], "paper Table II: <0.1% delta"))
        rows.append((f"parity_{impl}_loss_delta", m["loss_delta"],
                     f"ref loss {m['loss_ref']:.4f}"))
        rows.append((f"parity_{impl}_mean_kl", m["mean_kl"], ""))
        rows.append((f"parity_{impl}_max_logit_delta",
                     m["max_logit_delta"], ""))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"{name:35s} {val:12.5f}  {note}")
