"""Paged-KV serving benchmark (ISSUE 6 acceptance): the paged block pool
+ shared-prefix cache against contiguous per-slot serving.

Four arms on the reduced GPT-2 config:

  identity          paged serving must emit exactly the contiguous
                    engine's greedy tokens under all three exp backends
                    (the perf numbers below are meaningless if this row
                    is not all-true);
  decode_parity     steady-state decode tok/s, paged vs contiguous, same
                    phase-separated measurement as BENCH_serving (admit
                    -> sync, N full-pool decode steps -> sync). The paged
                    step adds only the block-table indirection, so the
                    ratio should sit within a few percent of 1;
  prefix_amortize   admission wall time for a long prompt served COLD
                    (full prefill) vs HOT (its prefix pages attach to the
                    cache; only the tail suffix is prefilled) — the hot
                    wave should amortize toward the suffix's share;
  oversubscription  a pool whose physical page budget is ~half the
                    summed logical footprint serves 8 prefix-sharing
                    requests concurrently: peak logical tokens / physical
                    capacity > 2 with zero cache evictions (live state is
                    never evicted — sharing alone carries the pool).

Results persist to ``BENCH_paged_serving.json``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax

OUT_PATH = os.environ.get("BENCH_PAGED_SERVING_PATH",
                          "BENCH_paged_serving.json")

N_TIMED = 5          # median-of-N (container noise is large + asymmetric)
MAX_BATCH = 4
MAX_SEQ = 128
UNIFORM_LEN = 32
STEADY_STEPS = 40
PAGE = 4             # deep chains on the reduced config's short prompts
PARITY_PAGE = 16     # decode parity at a serving-realistic page size


def _median(xs, key=None):
    xs = sorted(xs, key=key)
    return xs[len(xs) // 2]


def _mk_server(cfg, params, *, paged, policy=None, max_batch=MAX_BATCH,
               max_seq=MAX_SEQ, **kw):
    from repro.launch.serve import Server
    return Server(cfg, params, max_batch=max_batch, max_seq=max_seq,
                  policy=policy, paged=paged, **kw)


def _identity_arm(cfg, params):
    from repro.launch.serve import Request
    from repro.runtime import resolve_policy
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab, (16,), dtype=np.int32)
    prompts = []
    for n in (5, 20, 24, 30, 11, 28):
        p = rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
        if n >= 20:
            p[:16] = prefix           # prefix-sharing rows in the mix
        prompts.append(p)

    out = {}
    for exp in ("exact", "vexp", "vexp_hw"):
        pol = resolve_policy(cfg, env={}, exp_backend=exp)
        res = {}
        for paged in (False, True):
            srv = _mk_server(cfg, params, paged=paged, policy=pol,
                             max_batch=2, max_seq=64,
                             block_page=PAGE if paged else None)
            reqs = [Request(i, p.copy(), 5) for i, p in enumerate(prompts)]
            srv.run(reqs)
            res[paged] = {r.rid: r.out for r in reqs}
        out[exp] = res[False] == res[True]
    return out


def _steady_decode(cfg, params, *, paged, n_timed=N_TIMED):
    """Steady-state decode tok/s with a full pool and no admissions or
    finishes inside the timed window (mirrors BENCH_serving)."""
    from repro.launch.serve import Request

    def once():
        srv = _mk_server(cfg, params, paged=paged,
                         block_page=PAGE if paged else None)
        rng = np.random.default_rng(0)
        for i in range(MAX_BATCH):
            srv.submit(Request(i, rng.integers(
                0, cfg.vocab, (UNIFORM_LEN,), dtype=np.int32),
                max_new=STEADY_STEPS + 8))
        g = srv._groups["default"]
        g.admit()
        jax.block_until_ready(g.last)
        t1 = time.perf_counter()
        for _ in range(STEADY_STEPS):
            g.decode_once()
        jax.block_until_ready(g.last)
        return MAX_BATCH * STEADY_STEPS / (time.perf_counter() - t1)

    once()                            # compile
    return _median([once() for _ in range(n_timed)])


def _decode_parity_arm(cfg, params):
    # interleave the two runners so container noise hits both alike
    from repro.launch.serve import Request

    def runner(paged, page):
        def once():
            srv = _mk_server(cfg, params, paged=paged,
                             block_page=page if paged else None)
            rng = np.random.default_rng(0)
            for i in range(MAX_BATCH):
                srv.submit(Request(i, rng.integers(
                    0, cfg.vocab, (UNIFORM_LEN,), dtype=np.int32),
                    max_new=STEADY_STEPS + 8))
            g = srv._groups["default"]
            g.admit()
            jax.block_until_ready(g.last)
            t1 = time.perf_counter()
            for _ in range(STEADY_STEPS):
                g.decode_once()
            jax.block_until_ready(g.last)
            return MAX_BATCH * STEADY_STEPS / (time.perf_counter() - t1)
        once()
        return once

    def parity(page):
        paged_once, contig_once = runner(True, page), runner(False, page)
        pr, cr = [], []
        for _ in range(N_TIMED):
            pr.append(paged_once())
            cr.append(contig_once())
        # best-of-N on both sides: container stalls are one-sided and
        # large relative to a burst, so medians still carry them
        paged_tok_s, contig_tok_s = max(pr), max(cr)
        return {"paged_decode_tok_s": paged_tok_s,
                "contiguous_decode_tok_s": contig_tok_s,
                "ratio": paged_tok_s / contig_tok_s}

    # Headline parity is at the shipped default page size; the deep-table
    # page measures the XLA fallback's per-page gather cost (the pallas
    # path drives the table DMA in-kernel and does not pay it).
    from repro.runtime import resolve_policy
    default_page = resolve_policy(cfg, env={}).block_page
    out = parity(default_page)
    out["page"] = default_page
    deep = parity(PARITY_PAGE)
    deep["page"] = PARITY_PAGE
    out["deep_tables"] = deep
    return out


def _prefix_amortize_arm(cfg, params):
    """Cold vs hot admission wall time for the same long prompt family:
    hot admissions attach the cached prefix pages and prefill only the
    suffix (a much smaller length bucket)."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(3)
    # Deep prompt: 116 of 120 tokens shared -> cold prefills the full
    # 128 bucket, hot attaches 29 pages and prefills a 4-token suffix.
    # Short prompts would hide the amortization behind the fixed costs
    # (host hashing, the prefix-KV gather, program dispatch).
    prefix = rng.integers(0, cfg.vocab, (116,), dtype=np.int32)

    def prompt():
        p = rng.integers(0, cfg.vocab, (120,), dtype=np.int32)
        p[:116] = prefix
        return p

    def once():
        srv = _mk_server(cfg, params, paged=True, max_batch=1,
                         block_page=PAGE)
        g = srv._groups["default"]
        # time the prefill programs themselves alongside the wall
        # admission: at reduced scale the fixed admission costs (host
        # hashing, allocator walks, dispatch) are a large floor under
        # the wall ratio; the program ratio is the amortization itself.
        prog_s = []

        def timed(fn):
            def run(*a, **k):
                t0 = time.perf_counter()
                r = fn(*a, **k)
                jax.block_until_ready(r)
                prog_s.append(time.perf_counter() - t0)
                return r
            return run

        st = g.state
        st._prefill = timed(st._prefill)
        st._hist_prefill = timed(st._hist_prefill)
        # cold: seeds the cache (full 128-bucket prefill)
        srv.submit(Request(0, prompt(), 2))
        g.admit()
        while g.busy:
            g.decode_once()
            g.admit()
        cold = g.admit_s[0]
        # hot: same prefix, fresh suffix -> attach + tiny suffix prefill
        srv.submit(Request(1, prompt(), 2))
        g.admit()
        while g.busy:
            g.decode_once()
            g.admit()
        hot = g.admit_s[1]
        stats = srv.stats()["default"]["pool"]["prefix"]
        return {"cold_admit_s": cold, "hot_admit_s": hot,
                "hot_over_cold": hot / cold,
                "cold_prefill_s": prog_s[0], "hot_prefill_s": prog_s[1],
                "prefill_hot_over_cold": prog_s[1] / prog_s[0],
                "hit_tokens": stats["hit_tokens"]}

    once()                            # compile both buckets
    return _median([once() for _ in range(N_TIMED)],
                   key=lambda r: r["hot_over_cold"])


def _oversubscription_arm(cfg, params):
    """8 requests sharing a 44-token prefix through a pool whose budget
    covers ~half their summed logical footprint. A primer request seeds
    the cache, then all 8 run concurrently on shared physical pages."""
    from repro.launch.serve import Request
    rng = np.random.default_rng(4)
    prefix = rng.integers(0, cfg.vocab, (44,), dtype=np.int32)

    def prompt():
        p = rng.integers(0, cfg.vocab, (47,), dtype=np.int32)
        p[:44] = prefix
        return p

    cache_s = 64                      # ns = 16 pages/slot at PAGE=4
    n_shared = 11                     # full prefix pages: (47-1)//4
    budget = 1 + n_shared + 8 * (16 - n_shared)    # scratch+shared+fresh
    srv = _mk_server(cfg, params, paged=True, max_batch=8, max_seq=cache_s,
                     block_page=PAGE, block_budget=budget)
    srv.run([Request(0, prompt(), 1)])             # primer: publish chain
    srv.run([Request(1 + i, prompt(), 8) for i in range(8)])
    pool = srv.stats()["default"]["pool"]
    capacity_tokens = pool["pages_allocatable"] * pool["page"]
    return {
        "pages_budget": budget,
        "physical_capacity_tokens": capacity_tokens,
        "peak_logical_tokens": pool["peak_logical_tokens"],
        "oversubscription": pool["peak_logical_tokens"] / capacity_tokens,
        "prefix_evictions": pool["prefix"]["evictions"],
        "prefix_hits": pool["prefix"]["hits"],
    }


def run_bench() -> dict:
    from repro.configs import get_config
    from repro.models import api

    cfg = get_config("gpt2-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    results = {
        "identity": _identity_arm(cfg, params),
        "decode_parity": _decode_parity_arm(cfg, params),
        "prefix_amortize": _prefix_amortize_arm(cfg, params),
        "oversubscription": _oversubscription_arm(cfg, params),
    }
    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "config": {"page": PAGE, "max_batch": MAX_BATCH,
                   "max_seq": MAX_SEQ, "uniform_len": UNIFORM_LEN,
                   "steady_steps": STEADY_STEPS},
        "unix_time": time.time(),
        "results": results,
    }


def report():
    """Benchmark rows + BENCH_paged_serving.json side effect."""
    payload = run_bench()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    res = payload["results"]
    rows = []
    ident = res["identity"]
    rows.append(("token_identity", float(all(ident.values())),
                 ";".join(f"{k}={v}" for k, v in ident.items())))
    dp = res["decode_parity"]
    deep = dp["deep_tables"]
    rows.append(("paged_decode_tok_s", dp["paged_decode_tok_s"],
                 f"contiguous={dp['contiguous_decode_tok_s']:.1f};"
                 f"ratio={dp['ratio']:.3f} at page={dp['page']} "
                 f"(>=0.95 target); deep tables page={deep['page']} "
                 f"ratio={deep['ratio']:.3f} (XLA fallback pays the "
                 f"per-page gather the pallas table-DMA path does not)"))
    pa = res["prefix_amortize"]
    rows.append(("hot_admit_over_cold", pa["hot_over_cold"],
                 f"cold={pa['cold_admit_s'] * 1e3:.1f}ms;"
                 f"hot={pa['hot_admit_s'] * 1e3:.1f}ms;"
                 f"prefill_program_ratio={pa['prefill_hot_over_cold']:.3f};"
                 f"hit_tokens={pa['hit_tokens']}"))
    ov = res["oversubscription"]
    rows.append(("oversubscription", ov["oversubscription"],
                 f"peak_logical={ov['peak_logical_tokens']}tok over "
                 f"{ov['physical_capacity_tokens']}tok physical; "
                 f"evictions={ov['prefix_evictions']} (>=2x, 0 expected)"))
    rows.append(("json", 0.0, f"written to {OUT_PATH}"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"paged_serving/{name},{val},{note}")
