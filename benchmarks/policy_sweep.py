"""Policy sweep: exact vs vexp vs vexp_hw across kernel backends.

The apples-to-apples comparison the ExecPolicy layer unlocks: the same
fused-softmax and flash-attention workloads, executed under each exp
backend and kernel backend, with per-policy latency and accuracy vs. the
exact baseline. Results are printed as benchmark rows and also persisted
to ``BENCH_policy.json`` so the perf trajectory accumulates across PRs.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.runtime import ExecPolicy
from repro.kernels.dispatch import dispatch

# Modest CPU-interpreter-friendly shapes; TPU runs simply go faster.
SOFTMAX_SHAPE = (256, 512)
FA_SHAPE = dict(b=1, s=128, h=4, hkv=2, d=64)

OUT_PATH = os.environ.get("BENCH_POLICY_PATH", "BENCH_policy.json")


def _time(fn, n_warmup=2, n_timed=5) -> float:
    for _ in range(n_warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(n_timed):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run_sweep() -> dict:
    x = jax.random.normal(jax.random.PRNGKey(0), SOFTMAX_SHAPE) * 4
    f = FA_SHAPE
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (f["b"], f["s"], f["h"], f["d"]))
    k = jax.random.normal(ks[1], (f["b"], f["s"], f["hkv"], f["d"]))
    v = jax.random.normal(ks[2], (f["b"], f["s"], f["hkv"], f["d"]))

    sm_exact = jax.nn.softmax(x, -1)
    fa_exact = None
    records = []
    # accum_dtype only exists on the pallas backend (rejected elsewhere):
    # the extra pallas/bfloat16 rows quantify the accuracy/latency delta of
    # carrying (m, l, acc) scratch in bf16 instead of f32.
    combos = [(exp, kb, "float32") for exp in ("exact", "vexp", "vexp_hw")
              for kb in ("pallas", "reference", "xla")]
    combos += [(exp, "pallas", "bfloat16")
               for exp in ("exact", "vexp", "vexp_hw")]
    for exp, kb, accum in combos:
        pol = ExecPolicy(exp_backend=exp, kernel_backend=kb,
                         block_q=64, block_k=64, accum_dtype=accum)
        sm_fn = dispatch("softmax", pol)
        fa_fn = dispatch("flash_attention", pol)
        sm_out = sm_fn(x, policy=pol)
        fa_out = fa_fn(q, k, v, causal=True, policy=pol)
        if fa_exact is None and exp == "exact":
            fa_exact = fa_out
        records.append({
            "exp_backend": exp,
            "kernel_backend": kb,
            "accum_dtype": accum,
            "softmax_us": _time(lambda: sm_fn(x, policy=pol)) * 1e6,
            "flash_attention_us":
                _time(lambda: fa_fn(q, k, v, causal=True,
                                    policy=pol)) * 1e6,
            "softmax_max_abs_err":
                float(jnp.max(jnp.abs(sm_out - sm_exact))),
            "flash_attention_max_abs_err":
                float(jnp.max(jnp.abs(fa_out - fa_exact)))
                if fa_exact is not None else float("nan"),
        })
    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "softmax_shape": list(SOFTMAX_SHAPE),
        "flash_attention_shape": FA_SHAPE,
        "unix_time": time.time(),
        "records": records,
    }


def report():
    """Benchmark rows + BENCH_policy.json side effect."""
    payload = run_sweep()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for r in payload["records"]:
        name = f"{r['exp_backend']}__{r['kernel_backend']}"
        if r.get("accum_dtype", "float32") != "float32":
            name += f"__{r['accum_dtype']}"
        rows.append((f"softmax/{name}", r["softmax_us"],
                     f"max_abs_err={r['softmax_max_abs_err']:.2e}"))
        rows.append((f"flash_attention/{name}", r["flash_attention_us"],
                     f"max_abs_err={r['flash_attention_max_abs_err']:.2e}"))
    rows.append(("json", 0.0, f"written to {OUT_PATH}"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"policy_sweep/{name},{val:.6g},{note}")
