"""Snitch-cluster cycle/energy cost model — reproduces the paper's measured
results (Fig. 6, Table III) from its reported microarchitectural constants.

The paper's latency/energy numbers are silicon properties of the GF12
Snitch cluster; this container has no RISC-V RTL simulator, so we rebuild
the paper's own accounting:

  * baseline softmax: 56 instr/output, 360 cycles/output, with the
    exponential at 319 cycles/call (math.h piecewise polynomial + LUT);
  * optimized softmax: 1.5 instr/output, 2.125 cycles/output
    (FREP+SSR+SIMD, VFEXP = 4 bf16 lanes / 2 cycles, reciprocal-multiply);
  * energy: Table III — EXP 3433 pJ/op baseline vs 6.39 pJ/op extended;
    GEMM 3.96 vs 4.04 pJ/op; EXP kernel average power rises 2.4x.

Every derived quantity (162.7x softmax speedup, 74.3x energy, 8.2x
FlashAttention-2 throughput, 5.8x GPT-2 end-to-end, ...) is *computed* from
these constants, not hard-coded, and checked against the paper's claims in
tests/test_benchmarks.py.
"""

from __future__ import annotations

from dataclasses import dataclass

# ----------------------------------------------------------- paper constants

FREQ_HZ = 1.0e9                  # cluster runs at 1 GHz (§V-C)
N_CORES = 8

# cycles per output element of a softmax row (paper §IV-C, Fig. 4)
BASELINE_EXP_CYCLES = 319        # math.h-style exp, per BF16 item
BASELINE_CYCLES_PER_OUT = 360    # full baseline softmax
BASELINE_INSTR_PER_OUT = 56
# software-optimized (FREP/SSR/SIMD) but software exp: MAX+NORM vanish,
# exp dominates -> paper reports only 1.1x overall gain
SW_OPTIM_CYCLES_PER_OUT = BASELINE_CYCLES_PER_OUT / 1.1
# software Schraudolph (no EXP instruction): hardware beats it by 19.6x
SW_SCHRAUDOLPH_CYCLES_PER_OUT = 2.125 * 19.6
# fully optimized: FREP+SSR+SIMD+VFEXP
HW_OPTIM_CYCLES_PER_OUT = 2.125
HW_OPTIM_INSTR_PER_OUT = 1.5

# energy per op (Table III, pJ)
E_GEMM_BASE = 3.96
E_GEMM_EXT = 4.04
E_EXP_BASE = 3433.0
E_EXP_HW = 6.39
# softmax energy scales ~ with cycles x power; EXP kernel power rises 2.4x
P_EXP_RATIO = 2.4

SOFTMAX_CONFIGS = ("baseline", "sw_optim", "sw_exp_sw_optim",
                   "sw_exp_hw_optim")


def softmax_cycles_per_output(config: str) -> float:
    return {
        "baseline": BASELINE_CYCLES_PER_OUT,
        "sw_optim": SW_OPTIM_CYCLES_PER_OUT,
        "sw_exp_sw_optim": SW_SCHRAUDOLPH_CYCLES_PER_OUT,
        "sw_exp_hw_optim": HW_OPTIM_CYCLES_PER_OUT,
    }[config]


def softmax_latency_s(n_elements: int, config: str,
                      cores: int = N_CORES) -> float:
    """Softmax over n_elements total (rows parallelized across cores)."""
    return softmax_cycles_per_output(config) * n_elements / cores / FREQ_HZ


def softmax_energy_pj(n_elements: int, config: str) -> float:
    """Per-element softmax energy. The baseline element cost is dominated
    by the 319-cycle exp at baseline power; the optimized kernel burns
    2.4x power over 2.125 cycles."""
    base_power = E_EXP_BASE / BASELINE_EXP_CYCLES        # pJ/cycle-ish
    cycles = softmax_cycles_per_output(config)
    power = base_power * (P_EXP_RATIO if config == "sw_exp_hw_optim" else 1.0)
    return cycles * power * n_elements


def softmax_speedup() -> float:
    return BASELINE_CYCLES_PER_OUT / HW_OPTIM_CYCLES_PER_OUT


def softmax_energy_reduction() -> float:
    return softmax_energy_pj(1, "baseline") / softmax_energy_pj(
        1, "sw_exp_hw_optim")


# -------------------------------------------------- FlashAttention-2 model

@dataclass(frozen=True)
class AttnShape:
    seq: int
    head_dim: int = 64               # GPT-2 configuration (§V-C)


GEMM_FPU_UTIL = 0.85                # [5]'s optimized GEMM on Snitch
GEMM_FLOPS_PER_CYCLE = N_CORES * 8  # 8 cores x 4-lane bf16 FMA (2 flop/lane)


def fa2_cycles(shape: AttnShape, softmax_config: str) -> dict:
    """FlashAttention-2 forward for one head: two S x S x hd GEMMs plus the
    partial softmax over S^2 scores (max/exp/norm per element)."""
    s, hd = shape.seq, shape.head_dim
    gemm_flops = 2 * 2 * s * s * hd
    gemm_cycles = gemm_flops / (GEMM_FLOPS_PER_CYCLE * GEMM_FPU_UTIL)
    sm_cycles = softmax_cycles_per_output(softmax_config) * s * s / N_CORES
    return {"gemm": gemm_cycles, "softmax": sm_cycles,
            "total": gemm_cycles + sm_cycles}


def fa2_speedup(shape: AttnShape = AttnShape(2048)) -> float:
    base = fa2_cycles(shape, "baseline")["total"]
    opt = fa2_cycles(shape, "sw_exp_hw_optim")["total"]
    return base / opt


def fa2_softmax_share(shape: AttnShape, softmax_config: str) -> float:
    c = fa2_cycles(shape, softmax_config)
    return c["softmax"] / c["total"]


def fa2_energy_ratio(shape: AttnShape = AttnShape(2048)) -> float:
    """Energy improvement of optimized FA-2 vs baseline."""
    s, hd = shape.seq, shape.head_dim
    gemm_ops = 2 * 2 * s * s * hd
    e_base = gemm_ops * E_GEMM_BASE + softmax_energy_pj(s * s, "baseline")
    e_opt = gemm_ops * E_GEMM_EXT + softmax_energy_pj(s * s,
                                                      "sw_exp_hw_optim")
    return e_base / e_opt


# ------------------------------------------------------ end-to-end models

@dataclass(frozen=True)
class E2EModel:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    seq: int


E2E_MODELS = {
    "gpt2-small": E2EModel("gpt2-small", 12, 768, 12, 3072, 2048),
    "gpt3-xl": E2EModel("gpt3-xl", 24, 2048, 24, 8192, 2048),
    "vit-base": E2EModel("vit-base", 12, 768, 12, 3072, 197),
    "vit-huge": E2EModel("vit-huge", 32, 1280, 16, 5120, 197),
}


def e2e_cycles(m: E2EModel, softmax_config: str) -> dict:
    """Non-autoregressive inference cycles on the 16-cluster Occamy system
    (one head per cluster, following [5] / §V-D): GEMMs at the optimized
    utilization, softmax per attention row."""
    s, d, L, f = m.seq, m.d_model, m.n_layers, m.d_ff
    # per-layer GEMM flops: qkv+out projections + ffn + attention matmuls
    proj = 2 * s * d * (4 * d + 2 * f)
    attn = 2 * 2 * s * s * d
    gemm_flops = L * (proj + attn)
    n_clusters = 16
    gemm_cycles = gemm_flops / (GEMM_FLOPS_PER_CYCLE * GEMM_FPU_UTIL
                                * n_clusters)
    sm_elements = L * m.n_heads * s * s / min(m.n_heads, n_clusters)
    sm_cycles = softmax_cycles_per_output(softmax_config) * sm_elements \
        / N_CORES
    other = 0.08 * gemm_cycles          # norms, residuals, gelu (small)
    return {"gemm": gemm_cycles, "softmax": sm_cycles, "other": other,
            "total": gemm_cycles + sm_cycles + other}


def e2e_speedup(name: str) -> float:
    m = E2E_MODELS[name]
    return (e2e_cycles(m, "baseline")["total"]
            / e2e_cycles(m, "sw_exp_hw_optim")["total"])


def e2e_energy_ratio(name: str) -> float:
    m = E2E_MODELS[name]
    s, d, L, f = m.seq, m.d_model, m.n_layers, m.d_ff
    gemm_ops = L * (2 * s * d * (4 * d + 2 * f) + 4 * s * s * d)
    sm_el = L * m.n_heads * s * s
    e_base = gemm_ops * E_GEMM_BASE + softmax_energy_pj(sm_el, "baseline")
    e_opt = gemm_ops * E_GEMM_EXT + softmax_energy_pj(sm_el,
                                                      "sw_exp_hw_optim")
    return e_base / e_opt


def report() -> list[tuple]:
    rows = []
    rows.append(("softmax_speedup_x", softmax_speedup(), "paper: 162.7x"))
    rows.append(("softmax_energy_reduction_x", softmax_energy_reduction(),
                 "paper: 74.3x"))
    rows.append(("exp_energy_pj_base", E_EXP_BASE, "paper Table III"))
    rows.append(("exp_energy_pj_hw", E_EXP_HW, "paper Table III"))
    rows.append(("fa2_speedup_x", fa2_speedup(), "paper: up to 8.2x"))
    rows.append(("fa2_energy_x", fa2_energy_ratio(), "paper: up to 4.1x"))
    rows.append(("fa2_softmax_share_opt",
                 fa2_softmax_share(AttnShape(2048), "sw_exp_hw_optim"),
                 "paper: ~6%"))
    for name, target in [("gpt2-small", 5.8), ("gpt3-xl", 2.9),
                         ("vit-base", 1.9), ("vit-huge", 1.4)]:
        rows.append((f"e2e_speedup_{name}_x", e2e_speedup(name),
                     f"paper: {target}x"))
        rows.append((f"e2e_energy_{name}_x", e2e_energy_ratio(name), ""))
    return rows
