"""End-to-end model benchmark — Fig. 1 + Fig. 8 analogue.

Snitch/Occamy 16-cluster cycle model for GPT-2, GPT-3-XL, ViT-Base and
ViT-Huge (non-autoregressive, seq 2048 / 197): runtime + energy, baseline
vs softmax-optimized, including the runtime-share breakdown of Fig. 1
(softmax share before/after GEMM optimization).
"""

from __future__ import annotations

from . import snitch_model as sm


def fig1_shares(name="gpt3-xl"):
    """Softmax share of runtime with unoptimized vs optimized GEMMs
    (Fig. 1: ~30% before GEMM acceleration, ~70% after, at seq 2048)."""
    m = sm.E2E_MODELS[name]
    c = sm.e2e_cycles(m, "baseline")
    share_opt_gemm = c["softmax"] / c["total"]
    # unoptimized GEMM: ~8x slower (no FREP/SSR/SIMD, per [5])
    slow = {"gemm": c["gemm"] * 8, "softmax": c["softmax"],
            "other": c["other"] * 8}
    share_unopt_gemm = slow["softmax"] / sum(slow.values())
    return {"softmax_share_unopt_gemm": share_unopt_gemm,
            "softmax_share_opt_gemm": share_opt_gemm}


def report():
    rows = []
    paper = {"gpt2-small": (5.8, 3.6), "gpt3-xl": (2.9, 1.7),
             "vit-base": (1.9, 1.4), "vit-huge": (1.4, 1.2)}
    for name, (lat_t, en_t) in paper.items():
        rows.append((f"e2e_{name}_latency_x", sm.e2e_speedup(name),
                     f"paper Fig.8: {lat_t}x"))
        rows.append((f"e2e_{name}_energy_x", sm.e2e_energy_ratio(name),
                     f"paper Fig.8: {en_t}x"))
    sh = fig1_shares()
    rows.append(("fig1_softmax_share_unopt_gemm",
                 sh["softmax_share_unopt_gemm"], "paper Fig.1: ~0.3"))
    rows.append(("fig1_softmax_share_opt_gemm",
                 sh["softmax_share_opt_gemm"], "paper Fig.1: ~0.7"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"{name:40s} {val:10.3f}  {note}")
