"""Emit the EXPERIMENTS.md markdown tables from the dry-run/perf artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables [dryrun|roofline|perf]
"""

from __future__ import annotations

import glob
import json
import os
import sys

from .roofline import (build_table, load_artifacts, PEAK_FLOPS, HBM_BW,
                       LINK_BW)

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def fmt_bytes(b):
    if b is None or b < 0:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    rows = []
    for mesh in ("single", "multi"):
        for (arch, shape), rec in sorted(load_artifacts(mesh).items()):
            st = rec.get("analytic_state", {})
            coll = rec.get("collectives", {})
            ctypes = "+".join(
                f"{k}:{coll[k + '_count']}" for k in
                ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute") if k in coll)
            mem = rec.get("memory_analysis", {})
            rows.append(
                f"| {arch} | {shape} | {mesh} | "
                f"{'OK' if rec['ok'] else 'FAIL'} | "
                f"{rec.get('compile_s', '-')}s | "
                f"{fmt_bytes(st.get('total_state_bytes_per_device'))} | "
                f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
                f"{ctypes} |")
    print("| arch | shape | mesh | status | compile | state/dev | "
          "temp/dev | collectives (count) |")
    print("|---|---|---|---|---|---|---|---|")
    print("\n".join(rows))


def roofline_table():
    rows = build_table()
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "bottleneck | MODEL_FLOPS/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
              f"{r['roofline_fraction']:.3f} |")


def perf_table():
    print("| tag | GFLOP/chip | GB/chip | t_compute | t_memory | change |")
    print("|---|---|---|---|---|---|")
    for f in sorted(glob.glob(os.path.join(ART, "perf", "*.json"))):
        r = json.load(open(f))
        fl, by = r["flops_per_chip"], r["bytes_per_chip"]
        print(f"| {r['tag']} | {fl/1e9:.1f} | {by/1e9:.2f} | "
              f"{fl/PEAK_FLOPS:.4f}s | {by/HBM_BW:.4f}s | {r['desc']} |")


def speculative_table(path="BENCH_speculative.json"):
    """Speculative-decode summary from benchmarks/speculative.py."""
    if not os.path.exists(path):
        print(f"(no {path}; run `python -m benchmarks.speculative`)")
        return
    r = json.load(open(path))
    res = r["results"]
    steady = res["measured"]["steady"]
    plain = steady["plain"]["tok_s"]
    print("| arm | CPU tok/s | vs plain | accept/burst | "
          "VEXP-target tok/s | target speedup |")
    print("|---|---|---|---|---|---|")
    print(f"| plain exact | {plain:.0f} | 1.00x | — | — | — |")
    for name, row in steady.items():
        if name == "plain":
            continue
        proj = res["projected"].get(name)
        t_tok = f"{proj['spec_tok_s']:.0f}" if proj else "—"
        t_spd = f"{proj['speedup']:.2f}x" if proj else "—"
        print(f"| {name} | {row['tok_s']:.0f} | "
              f"{row['tok_s'] / plain:.2f}x | "
              f"{row['accept_per_burst']:.2f} | {t_tok} | {t_spd} |")


def skips_table():
    from repro.configs import REGISTRY, SHAPES
    print("| arch | shape | status |")
    print("|---|---|---|")
    for arch, cfg in sorted(REGISTRY.items()):
        if arch == "gpt2-small":
            continue
        for s in SHAPES:
            if s in cfg.shapes:
                print(f"| {arch} | {s} | run |")
            else:
                print(f"| {arch} | {s} | SKIP: {cfg.skip_notes[s]} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("### Dry-run matrix\n")
        dryrun_table()
    if which in ("roofline", "all"):
        print("\n### Roofline\n")
        roofline_table()
    if which in ("perf", "all"):
        print("\n### Perf iterations\n")
        perf_table()
    if which in ("speculative", "all"):
        print("\n### Speculative decoding\n")
        speculative_table()
    if which in ("skips", "all"):
        print("\n### Shape applicability\n")
        skips_table()
