"""Serving-engine benchmark: slot-level continuous batching under load.

Three workloads on the reduced GPT-2 config (the paper's serving model),
compared against a fixed-shape chunk driver with the old scheduler's
semantics (batch-wide prefill + one scalar decode position — the shape the
engine replaced):

  uniform       all prompts the same length — the scheduler generality
                must not regress the throughput the old driver got here;
  mixed_len     ragged prompt lengths — the case the old driver answered
                incorrectly; measured for tok/s + per-step tail latency;
  mixed_policy  half the requests under ``exact`` (eval traffic), half
                under ``vexp`` (bulk) in one server.

Phase-separated measurement: the blended per-workload tok/s above mixes
prefill and decode, which hides decode regressions behind prefill wins —
the ``steady_state`` section therefore times the two phases at explicit
device syncs (admit -> sync, then N decode steps -> sync) and reports
**steady-state decode tok/s** on its own. The ``sharded`` section runs
the same phase measurement through the SPMD serve loop (KV cache
sequence-sharded over 8 fake host devices, fused partial-statistics
decode with the packed single-collective merge) in a subprocess —
XLA_FLAGS must land before jax initializes.

The ``recurrent`` section serves the ssm (mamba2) and hybrid
(recurrentgemma) reduced configs through the same slot engine — the
family-agnostic DecodeState pool — on a mixed-length workload.

The ``open_loop`` section drives the engine with a Poisson arrival
process (requests arrive at ``--rate`` req/s regardless of service
progress — closed-loop workloads can never show queueing delay) and
compares the monolithic-wave scheduler against chunked prefill
(``ExecPolicy.prefill_chunk``) at the same arrivals: per-engine-tick
wall time (each tick synced, so a tick that runs a whole prefill wave
pays for it honestly), per-request TTFT and completion p50/p95. The
chunked arm's per-tick p95 must beat the monolithic arm's — one bounded
chunk per tick is the whole point. Run just this section with
``python -m benchmarks.serving --load-mode open [--rate R]``.

The ``chaos`` section serves the identical workload twice — fault-free
and threaded with a seeded ``repro.ft.FaultInjector`` at the default
chaos rates (``REPRO_FAULT_SEED`` seeds it) — and reports goodput
(tokens from cleanly-finished requests per second), tail latency and
fault/quarantine counts for both, plus their ratio. Every chaos run
ends on ``Server.assert_idle_clean``, so the benchmark doubles as a
zero-leak check under storm conditions. Run just this section with
``python -m benchmarks.serving --chaos``.

Rows carry tokens/s as the primary scalar; per-request p50/p95 completion
latency (submit -> tokens materialized, measured at the finish-time
device sync) rides in the note. Results persist to ``BENCH_serving.json``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

OUT_PATH = os.environ.get("BENCH_SERVING_PATH", "BENCH_serving.json")

N_REQUESTS = 16
MAX_NEW = 16
MAX_BATCH = 4
MAX_SEQ = 128
UNIFORM_LEN = 32
N_TIMED = 5          # median-of-N (container noise is large + asymmetric)
STEADY_STEPS = 12    # decode steps per steady-state phase measurement
OPEN_RATE = 16.0     # Poisson arrival rate (req/s) for the open-loop arm
OPEN_CHUNK = 16      # prefill chunk tokens for the chunked open-loop arm
OPEN_TIMED = 3       # open-loop runs are wall-clock long; fewer medians


def _requests(cfg, lens, groups=None):
    from repro.launch.serve import Request
    rng = np.random.default_rng(0)
    names = groups or ["default"]
    return [Request(i, rng.integers(0, cfg.vocab, (lens[i],),
                                    dtype=np.int32), MAX_NEW,
                    group=names[i % len(names)])
            for i in range(len(lens))]


def _engine_runner(cfg, params, lens, *, policy=None, policy_groups=None):
    """Warm up (compiles) and return a closure serving the workload once."""
    from repro.launch.serve import Server

    def once():
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     policy=policy, policy_groups=policy_groups)
        reqs = _requests(cfg, lens,
                         sorted(policy_groups) if policy_groups else None)
        t0 = time.perf_counter()
        srv.run(reqs)
        dt = time.perf_counter() - t0
        ntok = sum(len(r.out) for r in reqs)
        # request-level tail latency: submit -> tokens materialized, each
        # measured at a real device sync (per-step dispatch times are
        # async and would under-report).
        lat = sorted(x for g in srv._groups.values() for x in g.req_lat)
        return {
            "tok_s": ntok / dt,
            "tokens": ntok,
            "wall_s": dt,
            "p50_req_ms": 1e3 * (lat[len(lat) // 2] if lat else 0.0),
            "p95_req_ms": 1e3 * (lat[min(int(len(lat) * 0.95),
                                         len(lat) - 1)] if lat else 0.0),
        }

    once()                      # warmup: compile prefill buckets + decode
    return once


def _median(runs, key=None):
    runs = sorted(runs, key=key)
    return runs[len(runs) // 2]


def _run_engine(cfg, params, lens, **kw):
    once = _engine_runner(cfg, params, lens, **kw)
    return _median([once() for _ in range(N_TIMED)],
                   key=lambda r: r["tok_s"])


def _steady_state(cfg, params, *, policy=None, mesh=None, kv_mode="auto",
                  n_steps=STEADY_STEPS, n_timed=3):
    """Phase-separated engine measurement: prefill wall (admit -> sync)
    and steady-state decode tok/s (N full-pool decode steps between
    syncs, no admissions or finishes inside the window)."""
    from repro.launch.serve import Server, Request

    def once():
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     mesh=mesh, policy=policy, kv_mode=kv_mode)
        rng = np.random.default_rng(0)
        for i in range(MAX_BATCH):
            srv.submit(Request(i, rng.integers(
                0, cfg.vocab, (UNIFORM_LEN,), dtype=np.int32),
                max_new=n_steps + 8))       # no slot finishes mid-window
        g = srv._groups["default"]
        t0 = time.perf_counter()
        g.admit()
        jax.block_until_ready(g.last)
        t1 = time.perf_counter()
        for _ in range(n_steps):
            g.decode_once()
        jax.block_until_ready(g.last)
        t2 = time.perf_counter()
        return {"prefill_s": t1 - t0,
                "decode_tok_s": MAX_BATCH * n_steps / (t2 - t1),
                "prefill_tok_s": MAX_BATCH * UNIFORM_LEN / (t1 - t0),
                "kv_axis": srv.kv_axis}

    once()                                  # compile
    return _median([once() for _ in range(n_timed)],
                   key=lambda r: r["decode_tok_s"])


def _sharded_arm():
    """SPMD serve-loop phase measurement: runs in a subprocess with 8
    forced host devices (see __main__), comparing the sequence-sharded
    fused decode path against the single-device engine in-process."""
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import api
    from repro.runtime import resolve_policy

    cfg = get_config("gpt2-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    pol = resolve_policy(cfg, env={}, kernel_backend="pallas")
    nsh = len(jax.devices())
    sharded = _steady_state(cfg, params, policy=pol,
                            mesh=make_host_mesh(1, nsh), kv_mode="seq")
    single = _steady_state(cfg, params, policy=pol,
                           mesh=make_host_mesh(1, 1))
    return {"n_shards": nsh, "merge_strategy": pol.merge_strategy,
            "sharded": sharded, "single_device": single}


def _recurrent_arm():
    """Recurrent families through the same slot engine: mixed-length
    continuous batching over the family-agnostic DecodeState pool (ssm =
    mamba2 per-layer (h, conv) snapshots; hybrid = recurrentgemma mixed
    recurrent/attention periods). Prompt lengths stay inside the hybrid
    reduced config's sliding window (its ragged admission width)."""
    from repro.configs import get_config
    from repro.models import api
    from repro.runtime import resolve_policy

    rng = np.random.default_rng(2)
    lens = [int(x) for x in rng.integers(4, 13, N_REQUESTS)]
    out = {}
    for fam, arch in (("ssm", "mamba2-1.3b"),
                      ("hybrid", "recurrentgemma-9b")):
        cfg = get_config(arch).reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        pol = resolve_policy(cfg, env={})
        res = _run_engine(cfg, params, lens, policy=pol)
        res["arch"] = arch
        out[fam] = res
    return out


def _fixed_chunk_runner(cfg, params, lens, *, policy=None):
    """The old driver's schedule (uniform lengths only): whole-batch
    prefill, then scalar-position decode for the batch-wide max_new.
    Warms up and returns a tok/s closure."""
    from repro.models import api
    pol = policy
    prefill = jax.jit(lambda p, t: api.prefill(p, cfg, {"tokens": t},
                                               policy=pol))
    decode = jax.jit(lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos,
                                                          policy=pol))
    rng = np.random.default_rng(0)
    plen = lens[0]
    assert all(n == plen for n in lens), "fixed-chunk baseline is uniform"
    prompts = rng.integers(0, cfg.vocab, (len(lens), plen)).astype(np.int32)

    def once():
        t0 = time.perf_counter()
        ntok = 0
        for i in range(0, len(lens), MAX_BATCH):
            toks = jnp.asarray(prompts[i:i + MAX_BATCH])
            b = toks.shape[0]
            logits, cache = prefill(params, toks)
            ck = jnp.zeros((cfg.n_layers, b, MAX_SEQ, cfg.n_kv_heads,
                            cfg.hd), jnp.bfloat16)
            ck = ck.at[:, :, :plen].set(cache["k"])
            cv = jnp.zeros_like(ck).at[:, :, :plen].set(cache["v"])
            cache = {"k": ck, "v": cv}
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            ntok += b
            for step in range(MAX_NEW - 1):
                logits, cache = decode(params, tok, cache,
                                       jnp.int32(plen + step))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                ntok += b
        jax.block_until_ready(tok)
        return ntok / (time.perf_counter() - t0)

    once()
    return once


def _open_loop_runner(cfg, params, lens, arrivals, *, policy):
    """Open-loop load: requests arrive on the fixed ``arrivals`` clock
    (seconds from start) no matter how far behind the engine is — the
    arrival process both arms share, so queueing delay is comparable.

    Per-tick latency is measured at a device sync after every
    ``Server.step()``: the engine's own dispatch times are async and
    would hide a monolithic prefill wave inside a later sync. A tick
    that admits a whole prompt pays its full prefill here; a chunked
    tick pays one bounded chunk. Warms up (compiles every prefill
    bucket / the chunk program) and returns a closure."""
    from repro.launch.serve import Server, Request

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (n,), dtype=np.int32)
               for n in lens]

    def once():
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     policy=policy)
        reqs = [Request(i, prompts[i], MAX_NEW) for i in range(len(lens))]
        groups = list(srv._groups.values())
        step_s: list = []
        i = 0
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            while i < len(reqs) and arrivals[i] <= now:
                srv.submit(reqs[i])
                i += 1
            if not any(g.busy for g in groups):
                if i >= len(reqs):
                    break
                # idle before the next arrival: sleep it off rather than
                # spin (empty ticks would dilute the percentiles).
                time.sleep(max(0.0, arrivals[i]
                               - (time.perf_counter() - t0)))
                continue
            ts = time.perf_counter()
            srv.step()
            jax.block_until_ready([g.last for g in groups])
            step_s.append(time.perf_counter() - ts)
        wall = time.perf_counter() - t0
        ntok = sum(len(r.out) for r in reqs)
        ttft = sorted(x for g in groups for x in g.ttft)
        lat = sorted(x for g in groups for x in g.req_lat)
        step_s.sort()

        def pct(xs, q):
            return 1e3 * xs[min(int(len(xs) * q), len(xs) - 1)] \
                if xs else 0.0

        return {
            "tok_s": ntok / wall,
            "wall_s": wall,
            "ticks": len(step_s),
            "p50_step_ms": pct(step_s, 0.50),
            "p95_step_ms": pct(step_s, 0.95),
            "p50_ttft_ms": pct(ttft, 0.50),
            "p95_ttft_ms": pct(ttft, 0.95),
            "p50_req_ms": pct(lat, 0.50),
            "p95_req_ms": pct(lat, 0.95),
        }

    once()                      # warmup: compile buckets / chunk program
    return once


def _open_loop_arm(cfg, params, *, policy, rate=OPEN_RATE,
                   chunk=OPEN_CHUNK, n_timed=OPEN_TIMED):
    """Chunked-vs-monolithic under identical Poisson arrivals. Prompt
    lengths reach deep into the cache (long prefills are what make a
    monolithic admission tick expensive); runs interleave so container
    noise hits both arms alike; median by per-tick p95."""
    import dataclasses

    rng = np.random.default_rng(5)
    lens = [int(x) for x in rng.integers(8, 97, N_REQUESTS)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, N_REQUESTS))
    pol_chunk = dataclasses.replace(policy, prefill_chunk=chunk)
    mono_once = _open_loop_runner(cfg, params, lens, arrivals,
                                  policy=policy)
    chunk_once = _open_loop_runner(cfg, params, lens, arrivals,
                                   policy=pol_chunk)
    mono_runs, chunk_runs = [], []
    for _ in range(n_timed):
        mono_runs.append(mono_once())
        chunk_runs.append(chunk_once())
    key = lambda r: r["p95_step_ms"]          # noqa: E731
    return {
        "rate_req_s": rate,
        "chunk_tokens": chunk,
        "lens": lens,
        "monolithic": _median(mono_runs, key=key),
        "chunked": _median(chunk_runs, key=key),
    }


def _chaos_arm(cfg, params, *, n_timed=OPEN_TIMED):
    """Goodput under injected faults vs fault-free on the IDENTICAL
    workload: same prompts, same engine, one arm threaded with a seeded
    FaultInjector at the default chaos rates (REPRO_FAULT_SEED seeds
    it). Goodput counts only tokens from cleanly-finished requests —
    quarantined/shed work is overhead, not progress — so the ratio row
    is the price of the faults plus the recovery machinery. Every run
    ends on ``assert_idle_clean``: the benchmark doubles as a leak
    check under storm conditions."""
    from repro.ft import FAULT_SEED_ENV, FaultInjector, default_chaos_rates
    from repro.launch.serve import Server

    seed = int(os.environ.get(FAULT_SEED_ENV, "0") or "0")
    rng = np.random.default_rng(7)
    lens = [int(x) for x in rng.integers(8, 49, N_REQUESTS)]

    def once(inj_seed):
        inj = (FaultInjector(seed=inj_seed, rates=default_chaos_rates())
               if inj_seed is not None else None)
        srv = Server(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                     injector=inj, degrade_groups=("default",))
        reqs = _requests(cfg, lens)
        t0 = time.perf_counter()
        srv.run(reqs)
        wall = time.perf_counter() - t0
        clean = [r for r in reqs
                 if r.finish_reason in ("max_new", "length_cap")]
        good = sum(len(r.out) for r in clean)
        lat = sorted(x for g in srv._groups.values() for x in g.req_lat)
        st = srv.stats()["default"]
        out = {
            "goodput_tok_s": good / wall,
            "good_tokens": good,
            "clean_requests": len(clean),
            "n_requests": len(reqs),
            "wall_s": wall,
            "p95_req_ms": 1e3 * (lat[min(int(len(lat) * 0.95),
                                         len(lat) - 1)] if lat else 0.0),
            "quarantined": st["quarantined"],
            "step_faults": st["step_faults"],
            "requeued": st["requeued"],
            "shed": st["shed"],
            "admit_retries": st["admit_retries"],
        }
        if inj is not None:
            out["faults_fired"] = srv.fault_stats()["injector"]["fired"]
        srv.assert_idle_clean()        # zero leaked pages/slots, or raise
        return out

    once(None)                         # warmup: compiles both paths
    key = lambda r: r["goodput_tok_s"]          # noqa: E731
    fault_free = _median([once(None) for _ in range(n_timed)], key=key)
    # nearby seeds sample different fault mixes; median by goodput
    chaos = _median([once(seed + i) for i in range(n_timed)], key=key)
    return {
        "seed": seed,
        "rates": default_chaos_rates(),
        "fault_free": fault_free,
        "chaos": chaos,
        "goodput_ratio": chaos["goodput_tok_s"]
        / max(fault_free["goodput_tok_s"], 1e-9),
    }


def run_bench() -> dict:
    from repro.configs import get_config
    from repro.models import api
    from repro.runtime import resolve_policy

    cfg = get_config("gpt2-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    pol = resolve_policy(cfg, env={})
    rng = np.random.default_rng(1)
    mixed = [int(x) for x in rng.integers(8, 49, N_REQUESTS)]

    # the headline comparison (slot engine vs the old fixed-shape driver
    # on the uniform workload) interleaves the two runners so container
    # noise hits both alike; median-of-N on each side.
    engine_once = _engine_runner(cfg, params, [UNIFORM_LEN] * N_REQUESTS,
                                 policy=pol)
    fixed_once = _fixed_chunk_runner(cfg, params,
                                     [UNIFORM_LEN] * N_REQUESTS, policy=pol)
    eng_runs, fixed_runs = [], []
    for _ in range(N_TIMED):
        eng_runs.append(engine_once())
        fixed_runs.append(fixed_once())
    uniform = _median(eng_runs, key=lambda r: r["tok_s"])
    fixed_tok_s = _median(fixed_runs)

    results = {
        "uniform": uniform,
        "mixed_len": _run_engine(cfg, params, mixed, policy=pol),
        "mixed_policy": _run_engine(
            cfg, params, mixed,
            policy_groups={
                "eval": resolve_policy(cfg, env={}, exp_backend="exact"),
                "bulk": resolve_policy(cfg, env={}, exp_backend="vexp"),
            }),
        "fixed_chunk_baseline": {"tok_s": fixed_tok_s},
        "steady_state": _steady_state(cfg, params, policy=pol),
        "recurrent": _recurrent_arm(),
        "open_loop": _open_loop_arm(cfg, params, policy=pol),
        "chaos": _chaos_arm(cfg, params),
    }
    # sharded serving needs a multi-device host platform: XLA_FLAGS must
    # precede jax init, so the arm runs in a subprocess (best-effort — a
    # failure is recorded, not fatal to the rest of the benchmark).
    try:
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving", "--sharded-json"],
            capture_output=True, text=True, timeout=3600, env=env)
        if out.returncode != 0:
            raise RuntimeError(out.stderr[-1500:])
        results["sharded"] = json.loads(
            out.stdout.strip().splitlines()[-1])
    except Exception as e:                      # noqa: BLE001
        results["sharded"] = {"error": str(e)[:2000]}
    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "config": {"n_requests": N_REQUESTS, "max_new": MAX_NEW,
                   "max_batch": MAX_BATCH, "max_seq": MAX_SEQ,
                   "uniform_len": UNIFORM_LEN, "mixed_lens": mixed},
        "unix_time": time.time(),
        "results": results,
    }


def report():
    """Benchmark rows + BENCH_serving.json side effect."""
    payload = run_bench()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    res = payload["results"]
    rows = []
    for name in ("uniform", "mixed_len", "mixed_policy"):
        r = res[name]
        rows.append((f"{name}_tok_s", r["tok_s"],
                     f"req_p50={r['p50_req_ms']:.1f}ms;"
                     f"req_p95={r['p95_req_ms']:.1f}ms"))
    base = res["fixed_chunk_baseline"]["tok_s"]
    rows.append(("fixed_chunk_baseline_tok_s", base,
                 "old fixed-shape driver schedule (uniform lengths)"))
    rows.append(("uniform_vs_fixed_chunk",
                 res["uniform"]["tok_s"] / base,
                 "slot engine / old driver throughput (>= 1 expected)"))
    ss = res["steady_state"]
    rows.append(("steady_decode_tok_s", ss["decode_tok_s"],
                 f"decode-only; prefill={ss['prefill_s'] * 1e3:.1f}ms "
                 f"({ss['prefill_tok_s']:.1f} tok/s) measured separately"))
    ol = res.get("open_loop", {})
    if ol:
        for arm in ("monolithic", "chunked"):
            r = ol[arm]
            what = (f"chunk={ol['chunk_tokens']}tok"
                    if arm == "chunked" else "whole-prompt waves")
            rows.append((f"open_{arm}_step_p95_ms", r["p95_step_ms"],
                         f"Poisson {ol['rate_req_s']:g}req/s, {what}; "
                         f"ttft_p50/p95={r['p50_ttft_ms']:.0f}/"
                         f"{r['p95_ttft_ms']:.0f}ms; "
                         f"req_p95={r['p95_req_ms']:.0f}ms; "
                         f"{r['tok_s']:.1f}tok/s"))
        rows.append(("open_step_p95_ratio",
                     ol["monolithic"]["p95_step_ms"]
                     / max(ol["chunked"]["p95_step_ms"], 1e-9),
                     "monolithic / chunked per-tick p95 (> 1 expected: "
                     "the chunk budget bounds every tick)"))
    for fam, r in res.get("recurrent", {}).items():
        rows.append((f"recurrent_{fam}_tok_s", r["tok_s"],
                     f"{r['arch']} mixed-length slot engine; "
                     f"req_p50={r['p50_req_ms']:.1f}ms;"
                     f"req_p95={r['p95_req_ms']:.1f}ms"))
    ch = res.get("chaos", {})
    if ch:
        c = ch["chaos"]
        rows.append(("chaos_goodput_tok_s", c["goodput_tok_s"],
                     f"seed={ch['seed']}; clean={c['clean_requests']}/"
                     f"{c['n_requests']} requests; fired="
                     f"{c.get('faults_fired', {})}; "
                     f"quarantined={c['quarantined']} shed={c['shed']} "
                     f"step_faults={c['step_faults']}; "
                     f"req_p95={c['p95_req_ms']:.1f}ms"))
        rows.append(("chaos_goodput_ratio", ch["goodput_ratio"],
                     f"chaos / fault-free goodput (fault-free="
                     f"{ch['fault_free']['goodput_tok_s']:.1f}tok/s, "
                     f"req_p95={ch['fault_free']['p95_req_ms']:.1f}ms)"))
    sh = res.get("sharded", {})
    if "error" not in sh and sh:
        rows.append(("sharded_decode_tok_s",
                     sh["sharded"]["decode_tok_s"],
                     f"{sh['n_shards']}-way seq-sharded SPMD serve loop "
                     f"(merge={sh['merge_strategy']}); single-device "
                     f"decode={sh['single_device']['decode_tok_s']:.1f} "
                     f"tok/s in the same subprocess"))
    else:
        rows.append(("sharded_decode_tok_s", 0.0,
                     f"unavailable: {sh.get('error', 'not run')[:120]}"))
    rows.append(("json", 0.0, f"written to {OUT_PATH}"))
    return rows


def _open_loop_main(argv):
    """``--load-mode open [--rate R] [--chunk C]``: run just the
    open-loop Poisson comparison and print its rows (no JSON write —
    the full ``report()`` refreshes BENCH_serving.json)."""
    from repro.configs import get_config
    from repro.models import api
    from repro.runtime import resolve_policy

    def _flag(name, default, cast):
        return cast(argv[argv.index(name) + 1]) \
            if name in argv else default

    rate = _flag("--rate", OPEN_RATE, float)
    chunk = _flag("--chunk", OPEN_CHUNK, int)
    cfg = get_config("gpt2-small").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ol = _open_loop_arm(cfg, params, policy=resolve_policy(cfg, env={}),
                        rate=rate, chunk=chunk)
    for arm in ("monolithic", "chunked"):
        r = ol[arm]
        print(f"open_loop/{arm}: step p50/p95="
              f"{r['p50_step_ms']:.1f}/{r['p95_step_ms']:.1f}ms  "
              f"ttft p50/p95={r['p50_ttft_ms']:.0f}/"
              f"{r['p95_ttft_ms']:.0f}ms  "
              f"req p50/p95={r['p50_req_ms']:.0f}/"
              f"{r['p95_req_ms']:.0f}ms  {r['tok_s']:.1f}tok/s "
              f"({r['ticks']} ticks)")
    print(f"open_loop/step_p95_ratio,"
          f"{ol['monolithic']['p95_step_ms'] / max(ol['chunked']['p95_step_ms'], 1e-9):.3g},"
          f"rate={rate:g}req/s chunk={chunk}tok")


if __name__ == "__main__":
    if "--sharded-json" in sys.argv:
        # subprocess mode (parent sets XLA_FLAGS before we ever import
        # jax): print one JSON line with the sharded phase measurement.
        print(json.dumps(_sharded_arm()))
        sys.exit(0)
    if "--chaos" in sys.argv:
        # run just the chaos arm and print its rows (no JSON write —
        # the full report() refreshes BENCH_serving.json)
        from repro.configs import get_config
        from repro.models import api
        cfg = get_config("gpt2-small").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        ch = _chaos_arm(cfg, params)
        for arm in ("fault_free", "chaos"):
            r = ch[arm]
            print(f"serving/chaos_{arm},{r['goodput_tok_s']:.6g},"
                  f"clean={r['clean_requests']}/{r['n_requests']} "
                  f"req_p95={r['p95_req_ms']:.1f}ms "
                  f"fired={r.get('faults_fired', {})}")
        print(f"serving/chaos_goodput_ratio,{ch['goodput_ratio']:.6g},"
              f"seed={ch['seed']}")
        sys.exit(0)
    if "--load-mode" in sys.argv:
        mode = sys.argv[sys.argv.index("--load-mode") + 1]
        if mode != "open":
            sys.exit(f"unknown --load-mode {mode!r} (only 'open')")
        _open_loop_main(sys.argv)
        sys.exit(0)
    for name, val, note in report():
        print(f"serving/{name},{val:.6g},{note}")
