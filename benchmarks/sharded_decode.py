"""Sequence-parallel flash-decode benchmark -> BENCH_sharded_decode.json.

Measures the tentpole of ISSUE 3 on the serving engine's own decode shape:
a ragged continuous-batching slot pool (one long-context slot at S, seven
at S/8 — per-row (B,) cache lengths) over a bf16 "bshd" cache (the
serving default the old code silently kicked to the reference reduction),
swept over cache length × KV shard count on a host-platform mesh (8 fake
devices; XLA_FLAGS must land before jax initializes, so run standalone or
via benchmarks.run's subprocess section):

  reference        what ``decode_attention_policy`` executed before this
                   PR: the silent fallback to the single-device O(S)
                   materialized reference reduction (the serving engine
                   never sharded, so SPMD configs ran exactly this)
  fused_shardedN   the new path — shard_map partial-(m, l, acc) Pallas
                   sweep + psum stats merge over N KV shards
  reference_gspmdN the reference reduction over the same sharded cache,
                   lowered by GSPMD (per-shard partials + all-reduce)
  fused_single     the unsharded fused kernel (baseline)

On this CPU container the Pallas kernels execute in *interpret* mode,
which pays a per-block copy the compiled TPU kernel does not — the fused
rows carry that handicap and still beat the reference fallback at
S >= 4k; on TPU the gap widens (one HBM pass, MXU dots, no materialized
scores).

  PYTHONPATH=src python -m benchmarks.sharded_decode
"""

from __future__ import annotations

import os

if __name__ == "__main__":                       # before any jax import
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import functools
import json
import time

import numpy as np

OUT_PATH = os.environ.get("BENCH_SHARDED_DECODE_PATH",
                          "BENCH_sharded_decode.json")

# Serving slot-pool shape: 8 ragged slots, Falcon/PaLM-style MQA (wide
# query group over one KV head), bf16 bshd cache.
SHAPE = dict(b=8, h=64, hkv=1, d=128)
CACHE_LENS = (1024, 4096, 8192, 16384)
SHARDS = (4, 8)


def _time_interleaved(fns: dict, n_warmup=1, n_timed=7) -> dict:
    """Time several arms in interleaved rounds (min per arm): background
    load on the shared-CPU host platform then penalizes every arm alike
    instead of whichever ran last (the serving benchmark's protocol)."""
    import jax
    for fn in fns.values():
        for _ in range(n_warmup):
            jax.block_until_ready(fn())
    best = {k: float("inf") for k in fns}
    for _ in range(n_timed):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def run_sweep() -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_sharded)
    from repro.kernels.dispatch import dispatch
    from repro.runtime import ExecPolicy

    pol_ref = ExecPolicy(kernel_backend="reference")
    b, h, hkv, d = (SHAPE[k] for k in ("b", "h", "hkv", "d"))
    ndev = len(jax.devices())
    ref_fn = jax.jit(lambda q, k, v, c: dispatch(
        "decode_attention", pol_ref)(q, k, v, c, layout="bshd",
                                     policy=pol_ref))
    gspmd_fn = jax.jit(lambda q, k, v, c: dispatch(
        "decode_attention_sharded", pol_ref)(q, k, v, c, layout="bshd",
                                             policy=pol_ref))
    records = []
    for smax in CACHE_LENS:
        ks = jax.random.split(jax.random.PRNGKey(smax), 3)
        q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
        kc = jax.random.normal(ks[1], (b, smax, hkv, d), jnp.bfloat16)
        vc = jax.random.normal(ks[2], (b, smax, hkv, d), jnp.bfloat16)
        # ragged slot pool: one long-context request, the rest short
        lens = np.full(b, max(1, smax // 8))
        lens[0] = smax
        clen = jnp.asarray(lens, jnp.int32)

        rec = {"cache_len": smax, "layout": "bshd",
               "slot_lens": lens.tolist()}
        pol1 = ExecPolicy(kernel_backend="pallas",
                          block_s=max(512, smax // 8))
        arms = {
            "reference_us": lambda: ref_fn(q, kc, vc, clen),
            "fused_single_us": lambda: decode_attention(
                q, kc, vc, clen, layout="bshd", policy=pol1),
        }
        sharded_ctx = []
        for nsh in SHARDS:
            if nsh > ndev or smax % nsh:
                continue
            pol = ExecPolicy(kernel_backend="pallas", block_s=smax // nsh)
            # (1, nsh): a data axis > 1 would *replicate* the decode on
            # the host platform's time-shared fake devices and double the
            # measured CPU work for nothing.
            mesh = jax.make_mesh((1, nsh), ("data", "model"))
            spec = NamedSharding(mesh, P(None, "model", None, None))
            kcs, vcs = jax.device_put(kc, spec), jax.device_put(vc, spec)
            sharded_ctx.append(mesh)       # keep meshes alive over timing
            arms[f"fused_sharded{nsh}_us"] = functools.partial(
                lambda kcs, vcs, pol, mesh: decode_attention_sharded(
                    q, kcs, vcs, clen, mesh=mesh, layout="bshd",
                    policy=pol), kcs, vcs, pol, mesh)
            arms[f"reference_gspmd{nsh}_us"] = functools.partial(
                lambda kcs, vcs: gspmd_fn(q, kcs, vcs, clen), kcs, vcs)
        for name, secs in _time_interleaved(arms).items():
            rec[name] = secs * 1e6
        records.append(rec)
    dev = jax.devices()[0]
    return {
        "device": f"{dev.platform}:{getattr(dev, 'device_kind', '')}",
        "backend": jax.default_backend(),
        "n_devices": ndev,
        "shape": SHAPE,
        "unix_time": time.time(),
        "records": records,
    }


def report():
    """Benchmark rows + BENCH_sharded_decode.json side effect."""
    payload = run_sweep()
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for r in payload["records"]:
        s = r["cache_len"]
        rows.append((f"S{s}/reference", r["reference_us"],
                     "old fallback: single-device O(S) reduction"))
        rows.append((f"S{s}/fused_single", r["fused_single_us"],
                     "fused kernel; 1 device"))
        for nsh in SHARDS:
            fk = f"fused_sharded{nsh}_us"
            if fk not in r:
                continue
            speed = r["reference_us"] / r[fk]
            rows.append((f"S{s}/fused_sharded{nsh}", r[fk],
                         f"{speed:.2f}x vs reference fallback"))
            rows.append((f"S{s}/reference_gspmd{nsh}",
                         r[f"reference_gspmd{nsh}_us"],
                         "GSPMD-sharded reference reduction"))
    rows.append(("json", 0.0, f"written to {OUT_PATH}"))
    return rows


if __name__ == "__main__":
    for name, val, note in report():
        print(f"sharded_decode/{name},{val:.6g},{note}")
