# Repo-level tooling. CI runs `make ci` (CPU: Pallas kernels execute in
# interpret mode automatically).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: ci test analyze analysis-test bench sweep serve-smoke \
	serve-smoke-recurrent serve-smoke-paged serve-smoke-chunked \
	serve-smoke-chaos serve-smoke-spec spmd-test spmd-serve-smoke \
	spmd-serve-smoke-paged spmd-serve-smoke-chunked

ci:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

# Hot-path contract lint (repro.analysis Layer 1): AST rules over
# src/repro diffed against the justified baseline. Stdlib-only — needs
# no JAX, so CI runs it as its own fast job. Fails on any NEW finding.
analyze:
	$(PY) -m repro.analysis src/repro

# Both analyzer layers' own tests (AST rules on the planted fixtures +
# jaxpr/lowering audits of the real decode programs).
analysis-test:
	$(PY) -m pytest -q -m analysis

# SPMD decode tests on 8 fake host devices: the sequence-parallel
# (shard_map partial-softmax merge) decode paths and the multi-pod
# sharding rules, exercised with real collectives.
spmd-test:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m pytest -q tests/test_sharded_decode.py \
	    tests/test_distributed.py

bench:
	$(PY) -m benchmarks.run --skip-roofline

sweep:
	$(PY) -m benchmarks.policy_sweep

# Tiny mixed-length, mixed-policy workload through the slot-level
# continuous-batching engine (reduced gpt2; CPU interpret mode).
serve-smoke:
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 \
	    --policy-groups "eval=exact,bulk=vexp"

# Recurrent families (ssm + hybrid) through the same slot engine: the
# family-agnostic DecodeState pool serves mamba2's (h, conv) snapshots
# and recurrentgemma's mixed recurrent/attention periods with ragged
# mixed-length admission.
serve-smoke-recurrent:
	$(PY) -m repro.launch.serve --arch mamba2-1.3b --reduced \
	    --requests 4 --prompt-len 12 --mixed-lengths --max-new 6 \
	    --max-batch 2 --max-seq 64
	$(PY) -m repro.launch.serve --arch recurrentgemma-9b --reduced \
	    --requests 4 --prompt-len 12 --mixed-lengths --max-new 6 \
	    --max-batch 2 --max-seq 64

# Paged KV pool + copy-on-write shared-prefix cache through the same
# engine: block-table indirection, refcounted pages, hot shared-prefix
# admission (8-token page so the 24-token prefix actually shares).
serve-smoke-paged:
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 32 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --paged --block-page 8 \
	    --shared-prefix 24 --policy-groups "eval=exact,bulk=vexp"

# Chunked prefill interleaved with decode: long mixed-length prompts
# stream through the two-queue chunk scheduler in bounded 8-token
# chunks (one chunk + one decode step per tick) while earlier
# admissions keep decoding. Covers contiguous and paged pools.
serve-smoke-chunked:
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 40 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --prefill-chunk 8
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 40 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --prefill-chunk 8 \
	    --paged --block-page 8 --shared-prefix 16

# Chaos smoke: the same workloads with a seeded FaultInjector firing
# every catalog point (REPRO_FAULT_SEED replays a run exactly), request
# deadlines, cancellations and degradable groups enabled. Each run ends
# on Server.assert_idle_clean — zero leaked pages/slots after the storm
# or the process exits nonzero. Covers contiguous, paged, paged+chunked
# and sequence-sharded pools.
serve-smoke-chaos:
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 8 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --chaos --fault-seed 3 \
	    --cancel-frac 0.25 --deadline 30 --degrade-groups default
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 8 --prompt-len 32 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --paged --block-page 8 \
	    --shared-prefix 24 --chaos --fault-seed 5 --cancel-frac 0.25 \
	    --deadline 30
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 8 --prompt-len 40 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --prefill-chunk 8 --paged \
	    --block-page 8 --shared-prefix 16 --chaos --fault-seed 7 \
	    --cancel-frac 0.25 --deadline 30 --degrade-groups default
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 8 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --kv-mode seq --chaos --fault-seed 9 \
	    --cancel-frac 0.25 --deadline 30 --degrade-groups default

# Speculative smoke: k-step vexp_hw draft bursts + one batched exact
# verify through the slot engine, on the contiguous and paged pools and
# once inside a chaos storm (rollback + fault recovery composing). Each
# run ends on Server.assert_idle_clean — speculative rollback leaks
# nothing or the process exits nonzero.
serve-smoke-spec:
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --spec-k 4 --draft-backend vexp_hw
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 32 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --paged --block-page 8 \
	    --shared-prefix 24 --spec-k 4 --spec-verify chunk \
	    --policy-groups "eval=exact,bulk=vexp" --spec-groups eval
	$(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 8 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --spec-k 4 --chaos --fault-seed 11 \
	    --cancel-frac 0.25 --deadline 30

# The same slot engine end-to-end through the SPMD serve loop: KV cache
# sequence-sharded over 8 fake host devices, decode through the fused
# partial-statistics path with the packed single-collective merge.
spmd-serve-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --kv-mode seq

# Sharded paged serving: page pools sharded over the seq axis, tables
# holding partition-local ids, one packed collective per layer.
spmd-serve-smoke-paged:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 24 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --kv-mode seq --kernel-backend pallas \
	    --paged --block-page 8 --shared-prefix 16

# Sharded chunked prefill: the batch-sharded chunk program writes cache
# rows already carrying the pool sharding (no post-prefill re-placement
# device_put — the jaxpr output-sharding audit pins this).
spmd-serve-smoke-chunked:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m repro.launch.serve --arch gpt2-small --reduced \
	    --requests 6 --prompt-len 40 --mixed-lengths --max-new 8 \
	    --max-batch 2 --max-seq 64 --kv-mode seq --prefill-chunk 8
