# Repo-level tooling. CI runs `make ci` (CPU: Pallas kernels execute in
# interpret mode automatically).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: ci test bench sweep

ci:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

bench:
	$(PY) -m benchmarks.run --skip-roofline

sweep:
	$(PY) -m benchmarks.policy_sweep
