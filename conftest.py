"""Repo-level pytest config.

Ensures `src/` is importable without an editable install and falls back to
the bundled hypothesis shim (tests/_compat) when the real library is absent
— this container has no network and nothing may be pip-installed.
"""

import os
import sys

# Hermetic autotune: unit tests must not read/write the user-level on-disk
# block-size cache (persistence tests opt back in with explicit tmp paths).
os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "off")

_ROOT = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(_ROOT, "tests", "_compat"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (deselect with "
        "-m 'not slow' for a quick pass)")
    config.addinivalue_line(
        "markers", "analysis: repro.analysis contract checks (AST lint "
        "layer + jaxpr program audits; select with -m analysis)")
