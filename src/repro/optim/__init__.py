from .adamw import OptConfig, init, update, schedule, global_norm
