"""AdamW with global-norm clipping and warmup+cosine schedule (from scratch;
no optax in this environment). Pure-pytree states, pjit-transparent."""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0
    # distributed-optimization trick: keep Adam moments in bf16 (halves
    # optimizer HBM) with stochastic-free simple rounding; master weights
    # stay f32.
    moment_dtype: str = "float32"


def schedule(cfg: OptConfig, step):
    """Linear warmup then cosine decay to min_lr_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vn = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mn / c1
        vhat = vn / c2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on >=2D tensors only (not norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), mn.astype(mdt), vn.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    stats = {"grad_norm": gnorm, "lr": lr, "clip_scale": scale}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
