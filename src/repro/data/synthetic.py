"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step, shape) — so a job restarted
from a checkpoint at step k replays exactly the same stream with no state
file (the fault-tolerance property the trainer relies on). Host-side numpy
generation (cheap), shapes mirror ``models.api.input_specs`` exactly.

For the "train a real ~100M model" example we also provide a tiny
byte-level corpus generator with learnable structure (counting / copying
patterns) so loss visibly decreases.
"""

from __future__ import annotations

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


class SyntheticLM:
    """Uniform-random token batches matching a (cfg, shape) cell."""

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0):
        self.cfg, self.b, self.s, self.seed = cfg, batch, seq, seed

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = _rng(self.seed, step)
        s_txt = (self.s - cfg.n_vision_tokens if cfg.family == "vlm"
                 else self.s)
        out = {
            "tokens": rng.integers(0, cfg.vocab, (self.b, s_txt),
                                   dtype=np.int32),
            "labels": rng.integers(0, cfg.vocab, (self.b, s_txt),
                                   dtype=np.int32),
        }
        if cfg.family == "vlm":
            out["extra"] = rng.standard_normal(
                (self.b, cfg.n_vision_tokens, cfg.vision_embed_dim),
                dtype=np.float32)
        if cfg.family == "audio":
            out["extra"] = rng.standard_normal(
                (self.b, self.s, cfg.frame_input_dim), dtype=np.float32)
            out["labels"] = rng.integers(0, cfg.vocab, (self.b, self.s),
                                         dtype=np.int32)
        return out


class StructuredLM:
    """Learnable synthetic LM stream: each sequence is a repeated random
    motif with noise — a model that learns copying/induction drops loss
    well below the unigram entropy. Deterministic per (seed, step)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 motif_len: int = 16, noise: float = 0.05):
        self.v, self.b, self.s, self.seed = vocab, batch, seq, seed
        self.m, self.noise = motif_len, noise

    def batch(self, step: int) -> dict:
        rng = _rng(self.seed, step)
        motifs = rng.integers(0, self.v, (self.b, self.m))
        reps = -(-(self.s + 1) // self.m)
        seqs = np.tile(motifs, (1, reps))[:, :self.s + 1]
        flip = rng.random(seqs.shape) < self.noise
        seqs = np.where(flip, rng.integers(0, self.v, seqs.shape), seqs)
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}
