from .synthetic import SyntheticLM, StructuredLM
