"""Sharding rules: map every parameter/cache/batch leaf to a PartitionSpec.

Axes convention (launch/mesh.py):
  single pod:  ("data", "model") = (16, 16)
  multi pod:   ("pod", "data", "model") = (2, 16, 16)

"pod" behaves as an outer data-parallel axis; ``dp_axes(mesh)`` returns the
tuple of data axes present so specs written here work on both meshes.

Rules (TP = tensor parallel over "model"):
  * embeddings: vocab over model (row-parallel lookup);
  * attention: column-parallel wq / row-parallel wo; KV projections are
    replicated when n_kv_heads < |model| (GQA duplication — cheaper than
    splitting heads mid-dimension), sharded otherwise;
  * MLP: column-parallel in, row-parallel out (Megatron pattern — one
    all-reduce per block);
  * MoE: expert-parallel (experts over model) when E % |model| == 0, else
    TP-inside-expert (hidden over model);
  * SSM / RG-LRU: inner/recurrent width over model (all per-channel
    recurrences stay local);
  * FSDP (ZeRO-3 style) for large archs: remaining dim over "data";
    optimizer moments inherit parameter specs automatically.

Decode caches: KV sequence dim over model ("sequence-parallel flash
decode", powered by the paper's partial-softmax merge) when the batch is
too small to fill the data axes — selected per cell by ``cache_specs``.
``decode_kv_axis`` reports which mesh axis (if any) that left the cache's
S dim sharded over; callers hand it to ``decode_attention_sharded``
(kernels.dispatch), which sweeps each shard in partial-(m, l, acc) mode
and merges with the psum form of ``core.softmax.stats_merge`` — the fused
Pallas path now covers SPMD decode instead of falling back to the O(S)
reference reduction.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_specs(cfg, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching init_params(cfg)'s structure."""
    tp = model_axis_size(mesh)
    # shard KV projections only on clean head boundaries (GQA duplication
    # otherwise — replicating tiny KV heads beats mid-head splits)
    kv_shardable = bool(cfg.n_kv_heads) and cfg.n_kv_heads % tp == 0
    moe_ep = cfg.n_experts and cfg.n_experts % tp == 0

    def rule(path: str, leaf) -> P:
        nd = leaf.ndim
        # stacked layer arrays carry 1-2 leading layer axes; rules address
        # the trailing (true parameter) dims.
        def lead(n_param_dims):
            return (None,) * (nd - n_param_dims)

        if re.search(r"(^|/)(embed)$", path):
            return P("model", None)
        if re.search(r"pos_embed$", path):
            return P(None, None)
        if re.search(r"unembed$", path):
            return P(None, "model")
        if re.search(r"(wq|wg|wu|wx|wy|w_input_gate|w_rec_gate|in_proj|"
                     r"vis_proj)$", path):
            return P(*lead(2), None, "model")
        if re.search(r"(wo|wd|w_out|out_proj)$", path):
            return P(*lead(2), "model", None)
        if re.search(r"(wk|wv)$", path):
            return (P(*lead(2), None, "model") if kv_shardable
                    else P(*lead(2), None, None))
        if re.search(r"experts/(wg|wu)$", path):
            return (P(*lead(3), "model", None, None) if moe_ep
                    else P(*lead(3), None, None, "model"))
        if re.search(r"experts/wd$", path):
            return (P(*lead(3), "model", None, None) if moe_ep
                    else P(*lead(3), None, "model", None))
        if re.search(r"router$", path):
            return P(*lead(2), None, None)
        if re.search(r"conv_w$", path):
            return P(*lead(2), None, "model")
        if re.search(r"(conv_b|lam)$", path):
            return P(*lead(1), "model")
        return P(*((None,) * nd))       # norms, biases, scalars

    # ZeRO-3 shards over *all* data-parallel axes: on the multi-pod mesh
    # ("pod", "data", "model") the parameter dim splits over pod×data, so
    # per-device parameter memory matches what dp_axes implies (hardcoding
    # "data" left the pod axis replicated — 2× the memory it should be).
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def fsdp_augment(spec: P, leaf) -> P:
        if not fsdp or leaf.ndim < 2:
            return spec
        s = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (ax, dim) in enumerate(zip(s, leaf.shape)):
            if ax is None and dim % dp_size == 0 and dim >= 1024:
                s[i] = dp[0] if len(dp) == 1 else dp
                break
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        jax.tree.map(lambda x: x, _template(cfg)))
    specs = []
    for path, leaf in flat:
        sp = rule(_path_str(path), leaf)
        specs.append(fsdp_augment(sp, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _template(cfg):
    """Shape template via eval_shape (no allocation)."""
    from repro.models import api
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def opt_specs(cfg, mesh, pspecs):
    """Optimizer state specs: moments inherit parameter specs."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def cache_specs(cfg, mesh, batch: int, *, kv_mode: str = "auto"):
    """Decode-cache PartitionSpecs.

    kv_mode: "batch" shards cache on batch; "seq" shards the KV sequence
    dim over model (sequence-parallel decode via partial-softmax merge);
    "auto" picks seq when the per-dp-shard batch is < 1 (long-context,
    global_batch=1) or the arch is windowed with huge contexts.
    """
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if kv_mode == "auto":
        kv_mode = "seq" if batch < dp_size else "batch"
    bspec = dp if batch >= dp_size else None

    if cfg.family == "ssm":
        return {"h": P(None, bspec, "model", None, None),
                "conv": P(None, bspec, None, "model")}
    if cfg.family == "hybrid":
        seq = "model" if kv_mode == "seq" else None
        out = {"periods": {
            "rec_h": P(None, None, bspec, "model"),
            "rec_conv": P(None, None, bspec, None, "model"),
            "k": P(None, bspec, seq, None, None),
            "v": P(None, bspec, seq, None, None)}}
        period = cfg.attn_period
        if cfg.n_layers % period:
            out["tail"] = {"h": P(None, bspec, "model"),
                           "conv": P(None, bspec, None, "model")}
        return out
    seq = "model" if kv_mode == "seq" else None
    if getattr(cfg, "kv_cache_layout", "bshd") == "bhsd":
        # head-major cache: shard heads over model when they divide evenly
        # (decode attention then needs no collective at all); fall back to
        # sequence sharding otherwise.
        tp = model_axis_size(mesh)
        if cfg.n_kv_heads % tp == 0:
            return {"k": P(None, bspec, "model", None, None),
                    "v": P(None, bspec, "model", None, None)}
        return {"k": P(None, bspec, None, seq, None),
                "v": P(None, bspec, None, seq, None)}
    return {"k": P(None, bspec, seq, None, None),
            "v": P(None, bspec, seq, None, None)}


def decode_kv_axis(cfg, mesh, batch: int, *, kv_mode: str = "auto"):
    """The mesh axis the decode cache's *sequence* dim is sharded over
    under ``cache_specs`` (None when the cache is not sequence-sharded).

    This is the glue between the cache placement chosen here and the
    sequence-parallel decode entry (``kernels.dispatch``'s
    ``decode_attention_sharded``): when it returns an axis name, decode
    should run the per-shard partial-(m, l, acc) kernel and merge through
    the psum form of ``core.softmax.stats_merge`` on that axis; when it
    returns None the unsharded fused kernel applies as-is.
    """
    if cfg.family in ("ssm",):
        return None
    specs = cache_specs(cfg, mesh, batch, kv_mode=kv_mode)
    if cfg.family == "hybrid":
        spec = specs["periods"]["k"]
    else:
        spec = specs["k"]
    from repro.models.transformer import cache_seq_axis
    layout = getattr(cfg, "kv_cache_layout", "bshd")
    s_ax = cache_seq_axis(layout, stacked=True)
    entry = spec[s_ax] if s_ax < len(spec) else None
    return entry


def serve_cache_sharding(cfg, mesh, seq_axis):
    """NamedSharding pytree for the slot engine's *stacked* KV-cache pool
    with the sequence dim sharded over ``seq_axis`` (every other dim
    replicated — the engine's pool batch stays local). This is the
    placement the engine's shard_map decode program keeps its carry in,
    so the pool is sharded once at allocation and never resharded on the
    hot path."""
    from repro.models.transformer import cache_seq_axis
    layout = getattr(cfg, "kv_cache_layout", "bshd")
    spec = [None] * 5
    spec[cache_seq_axis(layout, stacked=True)] = seq_axis
    sh = NamedSharding(mesh, P(*spec))
    return {"k": sh, "v": sh}


def batch_specs(cfg, mesh, kind: str):
    """Input-batch PartitionSpecs per shape kind."""
    b = batch_spec(mesh)
    if kind in ("train", "prefill"):
        specs = {"tokens": P(*b), "labels": P(*b)}
        if cfg.family in ("vlm", "audio"):
            specs["extra"] = P(*b, None, None)
        if kind == "prefill":
            specs.pop("labels")
            if cfg.family == "audio":
                specs.pop("tokens")
        return specs
    raise ValueError(kind)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
