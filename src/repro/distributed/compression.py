"""Gradient compression with error feedback (distributed-optimization trick).

``compressed_psum`` all-reduces gradients in bfloat16 instead of float32 —
halving DP collective bytes — while an error-feedback buffer accumulates the
quantization residual locally so the *average* update stays unbiased over
steps (Karimireddy et al.-style EF). Implemented with shard_map + lax.psum
so it drops into a DDP-style trainer; under plain pjit the same idea is
expressed by casting grads before the pjit boundary (see train loop's
``grad_allreduce_dtype`` knob, which XLA lowers to bf16 all-reduces).
"""

from __future__ import annotations

import functools

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                      # jax >= 0.6 re-exports at top level
    from jax import shard_map as _shard_map
except ImportError:                       # 0.4.x experimental location
    from jax.experimental.shard_map import shard_map as _shard_map

# Replication checking was renamed check_rep -> check_vma across versions;
# pass whichever keyword this jax accepts.
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check=False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def ef_compress(grad, err):
    """Quantize grad+err to bf16; return (compressed, new_err)."""
    g = grad.astype(jnp.float32) + err
    c = g.astype(jnp.bfloat16)
    return c, g - c.astype(jnp.float32)


def compressed_psum(grads, errs, mesh: Mesh, axis: str = "data"):
    """All-reduce a grad pytree in bf16 with error feedback.

    grads: pytree of f32 (device-local, e.g. per-DP-shard); errs: matching
    error buffers. Returns (mean_grads_f32, new_errs).
    """
    def one(g, e):
        def body(g, e):
            c, ne = ef_compress(g, e)
            s = jax.lax.psum(c.astype(jnp.float32), axis)
            return s / mesh.shape[axis], ne

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()))(g, e)

    out = jax.tree.map(one, grads, errs)
    means = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    nerrs = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return means, nerrs
