from . import sharding, compression
from .sharding import (param_specs, opt_specs, cache_specs, batch_specs,
                       batch_spec, dp_axes, named)
