"""Pure-jnp oracle for the vexp kernel."""

import jax.numpy as jnp

from repro.core.vexp import vexp_f32


def vexp_ref(x):
    """Oracle: the same algorithm, un-tiled (XLA executes it directly)."""
    return vexp_f32(x)


def exp_exact_ref(x):
    """The transcendental baseline, for accuracy comparisons."""
    return jnp.exp(x)
