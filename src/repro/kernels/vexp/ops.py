"""jit'd public wrapper for the vexp Pallas kernel: arbitrary shapes/dtypes."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import vexp_2d, DEFAULT_BLOCK


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def vexp(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """VEXP exponential via the Pallas kernel, any shape, float dtypes.

    Pads/reshapes to a lane-aligned 2D layout, runs the tiled kernel, and
    restores the original shape. ``interpret=None`` auto-selects interpreter
    mode on CPU hosts (this container) and compiled mode on TPU.
    """
    if interpret is None:
        interpret = _is_cpu()
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Choose a 2D factorization with a 512-wide lane dim.
    lanes = 512 if n >= 512 else 128
    rows = -(-n // lanes)
    bm = min(DEFAULT_BLOCK[0], rows)
    rows_pad = -(-rows // bm) * bm
    padded = jnp.pad(flat, (0, rows_pad * lanes - n),
                     constant_values=jnp.asarray(0, x.dtype))
    out = vexp_2d(padded.reshape(rows_pad, lanes),
                  block=(bm, min(DEFAULT_BLOCK[1], lanes)),
                  interpret=interpret)
    return out.reshape(-1)[:n].reshape(orig_shape)
