"""jit'd public wrapper for the vexp Pallas kernel: arbitrary shapes/dtypes.

Policy-aware: pass an ``ExecPolicy`` to select the exp backend, block rows
and interpret mode in one object (a static jit argument, so each policy
compiles and caches separately). The legacy ``interpret=`` form still works.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.policy import ExecPolicy
from .kernel import vexp_2d, DEFAULT_BLOCK


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret", "policy"))
def _vexp_impl(x: jax.Array, interpret, policy) -> jax.Array:
    exp_impl = policy.exp_backend if policy is not None else "vexp"
    block_rows = (policy.block_rows if policy is not None
                  else DEFAULT_BLOCK[0])
    if interpret is None:
        interpret = (policy.interpret_resolved() if policy is not None
                     else _is_cpu())
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Choose a 2D factorization with a 512-wide lane dim.
    lanes = 512 if n >= 512 else 128
    rows = -(-n // lanes)
    bm = min(block_rows, rows)
    rows_pad = -(-rows // bm) * bm
    padded = jnp.pad(flat, (0, rows_pad * lanes - n),
                     constant_values=jnp.asarray(0, x.dtype))
    out = vexp_2d(padded.reshape(rows_pad, lanes),
                  block=(bm, min(DEFAULT_BLOCK[1], lanes)),
                  interpret=interpret, exp_impl=exp_impl)
    return out.reshape(-1)[:n].reshape(orig_shape)


def vexp(x: jax.Array, *, interpret: bool | None = None,
         policy: Optional[ExecPolicy] = None) -> jax.Array:
    """Exponential via the Pallas kernel, any shape, float dtypes.

    Pads/reshapes to a lane-aligned 2D layout, runs the tiled kernel, and
    restores the original shape. ``interpret=None`` auto-selects interpreter
    mode on CPU hosts (this container) and compiled mode on TPU. A policy
    supplies exp backend, row block and interpret mode; ``policy.autotune``
    picks the row block by timing candidates once per shape bucket.
    """
    if policy is not None and policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "vexp", policy, lambda p: _vexp_impl(x, interpret, p), x)
    return _vexp_impl(x, interpret, policy)
