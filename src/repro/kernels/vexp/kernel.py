"""Pallas TPU kernel for the VEXP elementwise exponential.

This is the TPU counterpart of the paper's VFEXP instruction: where Snitch
packs 4×BF16 lanes into a 64-bit FPU register and retires one SIMD exp per
two cycles, the TPU VPU processes (8, 128) vregs of the same bit-twiddled
Schraudolph+P(x) datapath. The kernel body is the *same* jnp program as the
core implementation (mul / floor / select / int add / shift / bitcast — no
transcendental), tiled through VMEM with an explicit BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vexp import get_exp_fn

# Block shape: sublane×lane aligned; 512 rows × 512 lanes = 1 MiB f32,
# comfortably inside the ~16 MiB/core VMEM with double buffering.
DEFAULT_BLOCK = (256, 512)


def _vexp_kernel(x_ref, o_ref, *, exp_impl: str):
    exp_fn = get_exp_fn(exp_impl)
    o_ref[...] = exp_fn(x_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "exp_impl"))
def vexp_2d(x: jax.Array, *, block=DEFAULT_BLOCK,
            interpret: bool = False, exp_impl: str = "vexp") -> jax.Array:
    """exp over a 2D array via the selected backend; shape must be divisible
    by ``block`` (ops.py handles padding/reshaping for arbitrary shapes)."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_vexp_kernel, exp_impl=exp_impl),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)
