from .ops import vexp
from .ref import vexp_ref, exp_exact_ref
