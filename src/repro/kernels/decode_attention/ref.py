"""Pure-jnp oracle for the decode-attention kernel."""

from repro.core.attention import decode_attention


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, window=None,
                         sm_scale=None, layout="bhsd", exp_impl="vexp"):
    """Oracle with identical math: (B,1,H,d) q over a KV cache in either
    layout, optionally windowed — the O(S) reference reduction."""
    return decode_attention(q, k_cache, v_cache, cache_len, window=window,
                            exp_impl=exp_impl, sm_scale=sm_scale,
                            mm_dtype="f32", layout=layout)
