"""Pure-jnp oracle for the decode-attention kernel."""

from repro.core.attention import decode_attention


def decode_attention_ref(q, k_cache, v_cache, cache_len, *, sm_scale=None):
    """Oracle with identical math: (B,1,H,d) q over a bhsd cache."""
    return decode_attention(q, k_cache, v_cache, cache_len,
                            exp_impl="vexp", sm_scale=sm_scale,
                            mm_dtype="f32", layout="bhsd")
