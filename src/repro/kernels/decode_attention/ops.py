"""jit'd public wrapper: (B, 1, H, d) queries over a (B, Hkv, S, d) cache.

Policy-aware: ``decode_attention`` takes an ``ExecPolicy`` static argument
selecting exp backend, KV block size and interpret mode;
``decode_attention_policy`` is the kernels.dispatch entry and applies
block-size autotuning when requested.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.policy import ExecPolicy
from .kernel import decode_attention_bhsd


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_s",
                                             "interpret", "policy"))
def decode_attention(q, k_cache, v_cache, cache_len, *, sm_scale=None,
                     block_s=512, interpret=None,
                     policy: Optional[ExecPolicy] = None):
    """Fused flash-decode. q: (B, 1, H, d); caches: (B, Hkv, S, d) (bhsd);
    cache_len: scalar int32 or per-row (B,) int32 of valid positions (the
    serving engine's per-slot lengths). Returns (B, 1, H, d)."""
    exp_impl = "vexp"
    if policy is not None:
        exp_impl = policy.exp_backend
        block_s = policy.block_s
        if interpret is None:
            interpret = policy.interpret_resolved()
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, _, h, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    d_pad = -(-d // 128) * 128
    s_pad = -(-smax // min(block_s, smax)) * min(block_s, smax)

    def pad(x, s_axis_target, d_axis_target):
        pads = [(0, 0)] * 4
        pads[2] = (0, s_axis_target - x.shape[2])
        pads[3] = (0, d_axis_target - x.shape[3])
        return jnp.pad(x, pads)

    qp = jnp.pad(qg, [(0, 0), (0, 0), (0, 0), (0, d_pad - d)])
    kp = pad(k_cache, s_pad, d_pad)
    vp = pad(v_cache, s_pad, d_pad)
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (b,))
    out = decode_attention_bhsd(qp, kp, vp, clen, sm_scale=scale,
                                block_s=block_s, interpret=interpret,
                                exp_impl=exp_impl)
    return out[..., :d].reshape(b, 1, h, d)


def decode_attention_policy(q, k_cache, v_cache, cache_len, *, window=None,
                            sm_scale=None, layout="bhsd",
                            policy: ExecPolicy):
    """kernels.dispatch entry. The Pallas kernel requires the head-major
    ("bhsd") cache and no sliding window; other configurations fall back to
    the reference decode with the policy's exp backend."""
    if layout != "bhsd" or window is not None:
        from repro.core.attention import decode_attention as core_decode
        return core_decode(q, k_cache, v_cache, cache_len, window=window,
                           sm_scale=sm_scale, exp_impl=policy.exp_backend,
                           layout=layout)
    if policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "decode_attention", policy,
            lambda p: decode_attention(q, k_cache, v_cache, cache_len,
                                       sm_scale=sm_scale, policy=p),
            q, k_cache)
    return decode_attention(q, k_cache, v_cache, cache_len,
                            sm_scale=sm_scale, policy=policy)
