"""jit'd public wrappers: (B, 1, H, d) queries over a KV cache.

Policy-aware: ``decode_attention`` takes an ``ExecPolicy`` static argument
selecting exp backend, KV block size, accumulation dtype and interpret
mode; ``decode_attention_policy`` is the kernels.dispatch entry and applies
block-size autotuning when requested. Both cover every configuration the
serving engine produces — head-major ("bhsd") *and* sequence-major
("bshd") caches, scalar or per-slot (B,) ``cache_len``, and sliding
windows — with no reference fallback.

``decode_attention_sharded`` is the sequence-parallel entry: a KV cache
sharded along its sequence axis over a mesh axis is swept shard-locally in
partial-statistics mode (each shard masks against its own slice of the
*global* ``cache_len`` via ``seq_offset``), and the per-shard statistics
merge under ``shard_map`` per ``policy.merge_strategy`` — "packed" (one
all_gather of a contiguous [acc | m | l] tile, a single collective) or
"split" (pmax + two psums) — the paper's §IV-C partial-softmax algebra as
an SPMD collective. ``decode_attention_partial_merged`` exposes the
shard-local sweep + merge for callers that run their own ``shard_map``
(the serving engine's sharded decode step).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.policy import ExecPolicy
from .kernel import (decode_attention_kernel, decode_attention_kernel_partial,
                     decode_attention_kernel_packed, decode_attention_bhsd,
                     decode_attention_kernel_paged,
                     decode_attention_kernel_paged_partial,
                     decode_attention_kernel_paged_packed)

__all__ = ["decode_attention", "decode_attention_partial",
           "decode_attention_partial_packed",
           "decode_attention_partial_merged",
           "decode_attention_sharded", "decode_attention_policy",
           "decode_attention_bhsd", "decode_attention_paged",
           "decode_attention_paged_partial_merged",
           "decode_attention_paged_policy", "paged_gather"]


def _seq_axis(layout: str) -> int:
    return 2 if layout == "bhsd" else 1


def _prepare(q, k_cache, v_cache, cache_len, block_s, layout):
    """Group queries, lane-pad d, block-pad S, broadcast cache_len."""
    b, _, h, d = q.shape
    hkv = k_cache.shape[1] if layout == "bhsd" else k_cache.shape[2]
    s_ax = _seq_axis(layout)
    smax = k_cache.shape[s_ax]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    d_pad = -(-d // 128) * 128
    s_pad = -(-smax // min(block_s, smax)) * min(block_s, smax)

    def pad(x):
        pads = [(0, 0)] * 4
        pads[s_ax] = (0, s_pad - x.shape[s_ax])
        pads[3] = (0, d_pad - x.shape[3])
        return jnp.pad(x, pads)

    qp = jnp.pad(qg, [(0, 0), (0, 0), (0, 0), (0, d_pad - d)])
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (b,))
    return qp, pad(k_cache), pad(v_cache), clen, smax


def _policy_kernel_args(policy: Optional[ExecPolicy], block_s, interpret):
    exp_impl, accum = "vexp", "float32"
    if policy is not None:
        exp_impl = policy.exp_backend
        block_s = policy.block_s
        accum = policy.accum_dtype
        if interpret is None:
            interpret = policy.interpret_resolved()
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return exp_impl, accum, block_s, interpret


@functools.partial(jax.jit, static_argnames=("window", "sm_scale", "layout",
                                             "block_s", "interpret",
                                             "policy"))
def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     sm_scale=None, layout="bhsd", block_s=512,
                     interpret=None, policy: Optional[ExecPolicy] = None):
    """Fused flash-decode. q: (B, 1, H, d); caches: (B, Hkv, S, d) ("bhsd")
    or (B, S, Hkv, d) ("bshd"); cache_len: scalar int32 or per-row (B,)
    int32 of valid positions (the serving engine's per-slot lengths);
    ``window``: static sliding window (attend exactly the last ``window``
    positions of each row's valid range). Returns (B, 1, H, d)."""
    exp_impl, accum, block_s, interpret = _policy_kernel_args(
        policy, block_s, interpret)
    b, _, h, d = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qp, kp, vp, clen, smax = _prepare(q, k_cache, v_cache, cache_len,
                                      block_s, layout)
    out = decode_attention_kernel(
        qp, kp, vp, clen, jnp.zeros((1,), jnp.int32), sm_scale=scale,
        s_valid=smax, block_s=block_s, interpret=interpret,
        exp_impl=exp_impl, window=window, layout=layout, accum_dtype=accum)
    return out[..., :d].reshape(b, 1, h, d)


@functools.partial(jax.jit, static_argnames=("window", "sm_scale", "layout",
                                             "block_s", "interpret",
                                             "policy"))
def decode_attention_partial(q, k_cache, v_cache, cache_len, seq_offset, *,
                             window=None, sm_scale=None, layout="bhsd",
                             block_s=512, interpret=None,
                             policy: Optional[ExecPolicy] = None):
    """Per-shard partial statistics for sequence-parallel decode.

    ``seq_offset`` (traced scalar int32) is the absolute cache position of
    this K/V slice's first row; ``cache_len`` stays *global*. Returns
    (m, l, acc): (B, Hkv, G, 1) ×2 and (B, Hkv, G, d), all f32 — merge
    with ``core.softmax.stats_merge_collective`` and normalize by
    ``acc / max(l, tiny)``.
    """
    exp_impl, accum, block_s, interpret = _policy_kernel_args(
        policy, block_s, interpret)
    d = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qp, kp, vp, clen, smax = _prepare(q, k_cache, v_cache, cache_len,
                                      block_s, layout)
    off = jnp.asarray(seq_offset, jnp.int32).reshape(1)
    m, l, acc = decode_attention_kernel_partial(
        qp, kp, vp, clen, off, sm_scale=scale, s_valid=smax,
        block_s=block_s, interpret=interpret, exp_impl=exp_impl,
        window=window, layout=layout, accum_dtype=accum)
    return m, l, acc[..., :d]


@functools.partial(jax.jit, static_argnames=("window", "sm_scale", "layout",
                                             "block_s", "interpret",
                                             "policy"))
def decode_attention_partial_packed(q, k_cache, v_cache, cache_len,
                                    seq_offset, *, window=None, sm_scale=None,
                                    layout="bhsd", block_s=512,
                                    interpret=None,
                                    policy: Optional[ExecPolicy] = None):
    """Per-shard partial statistics as ONE contiguous packed tile.

    Same sweep as ``decode_attention_partial`` but the kernel writes the
    shard's raw statistics directly into a single f32 buffer of shape
    (B, Hkv, G, d_pad + 2) laid out ``[acc | m | l]`` — the unit the
    single-collective merge all_gathers whole. ``d_pad`` is the
    lane-padded head dim; merge first, then slice the accumulator back to
    the true ``d`` (the padded lanes are zeros and fold to zeros).
    """
    exp_impl, accum, block_s, interpret = _policy_kernel_args(
        policy, block_s, interpret)
    d = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qp, kp, vp, clen, smax = _prepare(q, k_cache, v_cache, cache_len,
                                      block_s, layout)
    off = jnp.asarray(seq_offset, jnp.int32).reshape(1)
    return decode_attention_kernel_packed(
        qp, kp, vp, clen, off, sm_scale=scale, s_valid=smax,
        block_s=block_s, interpret=interpret, exp_impl=exp_impl,
        window=window, layout=layout, accum_dtype=accum)


def decode_attention_partial_merged(q, k_cache, v_cache, cache_len,
                                    seq_offset, *, seq_axis, window=None,
                                    sm_scale=None, layout="bhsd",
                                    policy: ExecPolicy):
    """Shard-local partial sweep + collective merge (call INSIDE shard_map).

    ``k_cache``/``v_cache`` are the *local* sequence slice; ``seq_offset``
    is the absolute position of its first row and ``cache_len`` stays
    global. The merge strategy comes from ``policy.merge_strategy``:

      "packed"  the kernel emits one contiguous [acc | m | l] tile and a
                single ``all_gather`` over ``seq_axis`` moves it — one
                collective per merge;
      "split"   the PR-3 form: pmax (global m) + two psums of the
                alpha-rescaled (l, acc) — three collectives.

    Both fold the exact same associative algebra; only the collective
    count (and fp summation order) differs. This is the one merge site
    shared by ``decode_attention_sharded`` and the serving engine's
    sharded ``decode_step``. Returns the normalized (B, 1, H, d) output.
    """
    from repro.core.softmax import (SoftmaxStats, stats_merge_collective,
                                    stats_merge_collective_packed)
    b, _, h, d = q.shape
    exp_fn = policy.exp_fn()
    if policy.merge_strategy == "packed":
        packed = decode_attention_partial_packed(
            q, k_cache, v_cache, cache_len, seq_offset, window=window,
            sm_scale=sm_scale, layout=layout, policy=policy)
        stats, acc = stats_merge_collective_packed(packed, seq_axis,
                                                   exp_fn=exp_fn)
        acc = acc[..., :d]
    else:
        m, l, acc = decode_attention_partial(
            q, k_cache, v_cache, cache_len, seq_offset, window=window,
            sm_scale=sm_scale, layout=layout, policy=policy)
        stats, acc = stats_merge_collective(
            SoftmaxStats(m=m, l=l), acc, seq_axis, exp_fn=exp_fn)
    out = acc * (1.0 / jnp.maximum(stats.l, 1e-30))
    return out.reshape(b, 1, h, d).astype(q.dtype)


@functools.lru_cache(maxsize=None)
def _sharded_program(mesh, seq_axis, window, sm_scale, layout: str,
                     policy: ExecPolicy):
    """One jitted shard_map program per (mesh, axis, window, scale, layout,
    policy) — eager shard_map would retrace the whole merge every call."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import shard_map

    s_ax = _seq_axis(layout)
    kv_spec = [None] * 4
    kv_spec[s_ax] = seq_axis
    kv_spec = P(*kv_spec)

    def _local(q, k, v, cl):
        local_s = k.shape[s_ax]
        off = jax.lax.axis_index(seq_axis) * local_s
        return decode_attention_partial_merged(
            q, k, v, cl, off, seq_axis=seq_axis, window=window,
            sm_scale=sm_scale, layout=layout, policy=policy)

    return jax.jit(shard_map(
        _local, mesh=mesh,
        in_specs=(P(), kv_spec, kv_spec, P()),
        out_specs=P()))


def decode_attention_sharded(q, k_cache, v_cache, cache_len, *, mesh,
                             seq_axis="model", window=None, sm_scale=None,
                             layout="bshd", policy: ExecPolicy):
    """Sequence-parallel flash decode over a KV cache sharded along S.

    The default layout is "bshd" — matching the dispatch table's
    reference/xla entries and ``cache_specs``, whose sequence sharding
    targets "bshd" caches (head-major caches shard heads when they divide
    the axis).

    q and ``cache_len`` are replicated; ``k_cache``/``v_cache`` are (or
    will be) sharded along their sequence axis over ``mesh``'s
    ``seq_axis``. Each shard runs the Pallas sweep in partial mode with
    ``seq_offset = axis_index * local_S`` and the shards merge per
    ``policy.merge_strategy``: "packed" gathers one contiguous
    [acc | m | l] tile in a single collective; "split" is the pmax + two
    psum form. Token-identical to the unsharded ``decode_attention``
    either way (the merge algebra is exact — only fp summation order
    differs). With ``policy.autotune`` the strategy is picked by timing
    both per (device_kind, shape_bucket) through the dispatch autotuner.
    """
    b = q.shape[0]
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (b,))
    if policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "decode_attention_sharded", policy,
            lambda p: _sharded_program(mesh, seq_axis, window, sm_scale,
                                       layout, p)(q, k_cache, v_cache, clen),
            q, k_cache)
    fn = _sharded_program(mesh, seq_axis, window, sm_scale, layout, policy)
    return fn(q, k_cache, v_cache, clen)


# ------------------------------------------------------------ paged entries

def _prepare_paged(q, k_pool, v_pool, block_tab, cache_len, layout):
    """Group queries, lane-pad d (q AND pools), broadcast cache_len."""
    b, _, h, d = q.shape
    hkv = k_pool.shape[1] if layout == "bhsd" else k_pool.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    d_pad = -(-d // 128) * 128
    if d_pad != d:
        qg = jnp.pad(qg, [(0, 0)] * 3 + [(0, d_pad - d)])
        pad4 = [(0, 0)] * 3 + [(0, d_pad - d)]
        k_pool = jnp.pad(k_pool, pad4)
        v_pool = jnp.pad(v_pool, pad4)
    tab = jnp.asarray(block_tab, jnp.int32)
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                            (b,))
    return qg, k_pool, v_pool, tab, clen


@functools.partial(jax.jit, static_argnames=("window", "sm_scale", "layout",
                                             "interpret", "policy"))
def decode_attention_paged(q, k_pool, v_pool, block_tab, cache_len, *,
                           window=None, sm_scale=None, layout="bshd",
                           interpret=None,
                           policy: Optional[ExecPolicy] = None):
    """Paged flash-decode. q: (B, 1, H, d); pools: (N, page, Hkv, d)
    ("bshd") or (N, Hkv, page, d) ("bhsd"); ``block_tab`` (B, nS) int32
    maps each row's logical pages to physical pool pages (entries past a
    row's extent must reference a valid reserved page — the reserved
    scratch page 0 by convention); ``cache_len`` scalar or (B,) int32.
    The page size is whatever the pool was allocated with (a static shape
    here — never re-tuned per call). Returns (B, 1, H, d)."""
    exp_impl, accum, _, interpret = _policy_kernel_args(policy, 0, interpret)
    b, _, h, d = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg, kp, vp, tab, clen = _prepare_paged(q, k_pool, v_pool, block_tab,
                                           cache_len, layout)
    out = decode_attention_kernel_paged(
        qg, kp, vp, tab, clen, jnp.zeros((1,), jnp.int32), sm_scale=scale,
        interpret=interpret, exp_impl=exp_impl, window=window, layout=layout,
        accum_dtype=accum)
    return out[..., :d].reshape(b, 1, h, d)


def decode_attention_paged_partial_merged(q, k_pool, v_pool, block_tab,
                                          cache_len, seq_offset, *, seq_axis,
                                          window=None, sm_scale=None,
                                          layout="bshd",
                                          policy: ExecPolicy):
    """Shard-local paged sweep + collective merge (call INSIDE shard_map).

    The paged counterpart of ``decode_attention_partial_merged``: the pool
    holds this shard's *local* physical pages, ``block_tab`` its local
    (B, nS_local) table slice with local page ids, ``seq_offset`` the
    absolute position of local logical page 0; ``cache_len`` stays
    global. Statistics fold per ``policy.merge_strategy`` exactly like
    the contiguous path. Returns the normalized (B, 1, H, d) output."""
    from repro.core.softmax import (SoftmaxStats, stats_merge_collective,
                                    stats_merge_collective_packed)
    b, _, h, d = q.shape
    exp_impl, accum, _, interpret = _policy_kernel_args(policy, 0, None)
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    qg, kp, vp, tab, clen = _prepare_paged(q, k_pool, v_pool, block_tab,
                                           cache_len, layout)
    off = jnp.asarray(seq_offset, jnp.int32).reshape(1)
    exp_fn = policy.exp_fn()
    if policy.merge_strategy == "packed":
        packed = decode_attention_kernel_paged_packed(
            qg, kp, vp, tab, clen, off, sm_scale=scale, interpret=interpret,
            exp_impl=exp_impl, window=window, layout=layout,
            accum_dtype=accum)
        stats, acc = stats_merge_collective_packed(packed, seq_axis,
                                                   exp_fn=exp_fn)
        acc = acc[..., :d]
    else:
        m, l, acc = decode_attention_kernel_paged_partial(
            qg, kp, vp, tab, clen, off, sm_scale=scale, interpret=interpret,
            exp_impl=exp_impl, window=window, layout=layout,
            accum_dtype=accum)
        acc = acc[..., :d]
        stats, acc = stats_merge_collective(
            SoftmaxStats(m=m, l=l), acc, seq_axis, exp_fn=exp_fn)
    out = acc * (1.0 / jnp.maximum(stats.l, 1e-30))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_gather(pool, block_tab, layout="bshd"):
    """Materialize a contiguous per-row cache from a paged pool — the
    reference/xla semantics of block-table indirection (and the oracle
    the kernel tests compare against). Returns (B, nS*page, Hkv, d) for
    "bshd" pools, (B, Hkv, nS*page, d) for "bhsd"."""
    tab = jnp.asarray(block_tab, jnp.int32)
    b, ns = tab.shape
    gathered = pool[tab]                       # (B, nS, *page_shape)
    if layout == "bhsd":                       # (B, nS, Hkv, page, d)
        g = gathered.transpose(0, 2, 1, 3, 4)  # (B, Hkv, nS, page, d)
        return g.reshape(b, g.shape[1], ns * g.shape[3], g.shape[4])
    # "bshd": (B, nS, page, Hkv, d)
    return gathered.reshape(b, ns * gathered.shape[2], *gathered.shape[3:])


def decode_attention_paged_policy(q, k_pool, v_pool, block_tab, cache_len, *,
                                  window=None, sm_scale=None, layout="bshd",
                                  policy: ExecPolicy):
    """kernels.dispatch entry for the paged sweep (pallas backend).

    No per-call autotuning: the page size is baked into the pool's shape
    at allocation (``DecodeState`` tunes ``block_page`` once, *before*
    the pool exists)."""
    return decode_attention_paged(q, k_pool, v_pool, block_tab, cache_len,
                                  window=window, sm_scale=sm_scale,
                                  layout=layout, policy=policy)


def decode_attention_policy(q, k_cache, v_cache, cache_len, *, window=None,
                            sm_scale=None, layout="bhsd",
                            policy: ExecPolicy):
    """kernels.dispatch entry: policy-driven blocks + optional autotune.

    Covers every serving configuration — both cache layouts, sliding
    windows, scalar or per-slot cache lengths — through the fused kernel;
    there is no reference fallback."""
    if policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "decode_attention", policy,
            lambda p: decode_attention(q, k_cache, v_cache, cache_len,
                                       window=window, sm_scale=sm_scale,
                                       layout=layout, policy=p),
            q, k_cache)
    return decode_attention(q, k_cache, v_cache, cache_len, window=window,
                            sm_scale=sm_scale, layout=layout, policy=policy)
