"""Fused decode-attention Pallas kernel (flash-decode, VEXP partial softmax).

Substantiates EXPERIMENTS.md §Perf iteration C4: one decode step reads the
KV cache exactly once from HBM — the (m, l, acc) online-softmax statistics
live in VMEM scratch across the KV-block sweep, and the cache is consumed
in its storage dtype (bf16) with f32 accumulation (``accum_dtype="bfloat16"``
drops the scratch statistics to bf16 for the memory/accuracy trade the
ExecPolicy exposes).

Two cache layouts share one kernel body: head-major "bhsd" (B, Hkv, S, hd)
— the §Perf C3 layout — and sequence-major "bshd" (B, S, Hkv, hd); the
BlockSpec index maps place the KV-sweep axis wherever the layout stores it,
so neither layout pays a materialized transpose.

Grid = (nB, Hkv, nS) with the KV sweep innermost; each program handles one
KV head's query group (GQA: G = H // Hkv query rows) for a *block* of
``block_b`` batch rows — decode dots are tiny (G × block_s), so batching
rows into the block amortizes grid/DMA bookkeeping across the slot pool
instead of paying it per row. ``block_b`` is clamped so the K/V blocks
stay a few MB of VMEM.

``cache_len`` is a per-batch-row (B,) vector in SMEM: each row of a block
masks the KV sweep against its own length, so a continuous-batching server
can decode slots whose requests are at different positions in one program
(ragged slot lengths never touch each other's cache rows), and whole KV
blocks past every row's length are skipped.

Sequence parallelism (the paper's §IV-C partial-softmax algebra as an SPMD
primitive): in *partial* mode the kernel emits the raw per-shard
(m, l, acc) statistics instead of the normalized output, and masks its KV
sweep in **global** coordinates via ``seq_offset`` (an SMEM scalar: the
absolute position of this shard's first cache row). *Packed* partial mode
goes one step further and lands the statistics in ONE contiguous
(B, Hkv, G, d+2) tile laid out ``[acc | m | l]`` — the exact buffer the
single-collective merge (``core.softmax.stats_merge_collective_packed``)
all_gathers, so no stat array is ever concatenated outside the kernel.
Shards are merged under ``shard_map`` per the policy's merge strategy —
see ``ops.decode_attention_sharded``.

Sliding windows mask ``cache_len - window <= kpos < cache_len`` (exactly
``window`` tokens including the current one); KV blocks entirely outside
the window are skipped, so a windowed decode over a long linear cache does
O(window) work like the ring-buffer path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vexp import get_exp_fn
# The finite "empty" sentinel must be the SAME value stats_merge_collective
# classifies empty shards against — single-sourced in core.softmax.
from repro.core.softmax import KERNEL_NEG_INF as NEG_INF

DEFAULT_BLOCK_S = 512
DEFAULT_BLOCK_B = 8

_ACCUM_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def _decode_kernel(len_ref, off_ref, q_ref, k_ref, v_ref, *refs,
                   block_b: int, block_s: int, ns: int, s_valid: int,
                   sm_scale: float, exp_impl: str, window, layout: str,
                   partial: bool, packed: bool = False):
    if packed:
        op_ref, m_ref, l_ref, acc_ref = refs
    elif partial:
        om_ref, ol_ref, oacc_ref, m_ref, l_ref, acc_ref = refs
    else:
        (o_ref, m_ref, l_ref, acc_ref) = refs
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (block_b,) per-row lengths of this row block (scalar SMEM reads).
    lens = jnp.stack([len_ref[bi * block_b + i] for i in range(block_b)])
    seq_off = off_ref[0]
    start = si * block_s                 # shard-local block start
    g_start = start + seq_off            # absolute cache position
    exp_fn = get_exp_fn(exp_impl)

    # Block-level liveness: any (row, key) pair inside [len - window, len)?
    row_live = g_start < lens
    if window is not None:
        # first in-window position; blocks fully below it are skipped, so
        # the sweep effectively starts at max(0, cache_len - window)'s block.
        row_live &= (g_start + block_s) > (lens - window)
    live = jnp.any(row_live)

    @pl.when(live)
    def _compute():
        q = q_ref[:, 0].astype(jnp.float32) * sm_scale     # (bb, G, d)
        if layout == "bhsd":
            k = k_ref[:, 0]                                # (bb, bs, d)
            v = v_ref[:, 0]
        else:                                              # "bshd"
            k = k_ref[:, :, 0, :]
            v = v_ref[:, :, 0, :]
        s = jax.lax.dot_general(
            q.astype(k.dtype), k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)            # (bb, G, bs)
        lpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        kpos = lpos + seq_off
        lcol = lens[:, None, None]
        keep = kpos < lcol
        # shard-local padding rows (lpos >= s_valid) may sit at absolute
        # positions that *are* valid on later shards — mask them explicitly.
        keep &= lpos < s_valid
        if window is not None:
            keep &= kpos >= lcol - window
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_ref[...].astype(jnp.float32)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = exp_fn(m_prev - m_new)
        p = exp_fn(s - m_new)
        p = jnp.where(keep, p, 0.0)
        l_ref[...] = (l_ref[...].astype(jnp.float32) * alpha
                      + jnp.sum(p, -1, keepdims=True)).astype(l_ref.dtype)
        acc_ref[...] = (acc_ref[...].astype(jnp.float32) * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v,
                            (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
                        ).astype(acc_ref.dtype)
        m_ref[...] = m_new.astype(m_ref.dtype)

    @pl.when(si == ns - 1)
    def _finalize():
        if packed:
            # one contiguous (block_b, G, d+2) tile per shard laid out as
            # [acc | m | l]: the collective merge gathers this buffer
            # whole — no post-hoc concatenate of three stat arrays on the
            # host side of the kernel.
            op_ref[:, 0] = jnp.concatenate(
                [acc_ref[...].astype(op_ref.dtype),
                 m_ref[...].astype(op_ref.dtype),
                 l_ref[...].astype(op_ref.dtype)], axis=-1)
        elif partial:
            # raw shard statistics: rows this shard never touched stay at
            # (m=NEG_INF, l=0, acc=0) — the merge's identity element.
            om_ref[:, 0] = m_ref[...].astype(om_ref.dtype)
            ol_ref[:, 0] = l_ref[...].astype(ol_ref.dtype)
            oacc_ref[:, 0] = acc_ref[...].astype(oacc_ref.dtype)
        else:
            inv = 1.0 / jnp.maximum(l_ref[...].astype(jnp.float32), 1e-30)
            o_ref[:, 0] = (acc_ref[...].astype(jnp.float32)
                           * inv).astype(o_ref.dtype)


def resolve_block_b(b: int, block_s: int, d: int) -> int:
    """Rows per grid cell: amortize grid overhead, cap K/V block VMEM at a
    few MB (block_b * block_s * d * 2 arrays)."""
    bb = min(b, DEFAULT_BLOCK_B)
    while bb > 1 and bb * block_s * d * 4 * 2 > 8 * 1024 * 1024:
        bb //= 2
    while b % bb:            # b is padded to a block multiple by ops
        bb //= 2
    return max(bb, 1)


def _specs(layout: str, block_b: int, g: int, bs: int, d: int):
    """(smem, q, k/v) BlockSpecs for the given layout; grid (nB, Hkv, nS)."""
    from jax.experimental.pallas import tpu as pltpu
    q_spec = pl.BlockSpec((block_b, 1, g, d),
                          lambda bb, hh, si: (bb, hh, 0, 0))
    if layout == "bhsd":
        kv_spec = pl.BlockSpec((block_b, 1, bs, d),
                               lambda bb, hh, si: (bb, hh, si, 0))
    else:                                  # "bshd": (B, S, Hkv, d)
        kv_spec = pl.BlockSpec((block_b, bs, 1, d),
                               lambda bb, hh, si: (bb, si, hh, 0))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    return smem, q_spec, kv_spec


def _scratch(block_b: int, g: int, d: int, accum_dtype: str):
    from jax.experimental.pallas import tpu as pltpu
    adt = _ACCUM_DTYPES[accum_dtype]
    return [pltpu.VMEM((block_b, g, 1), adt),
            pltpu.VMEM((block_b, g, 1), adt),
            pltpu.VMEM((block_b, g, d), adt)]


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "block_s", "s_valid", "interpret", "exp_impl", "window",
    "layout", "accum_dtype"))
def decode_attention_kernel(q, k_cache, v_cache, cache_len, seq_offset, *,
                            sm_scale: float, s_valid: int,
                            block_s: int = DEFAULT_BLOCK_S,
                            interpret: bool = False,
                            exp_impl: str = "vexp",
                            window=None, layout: str = "bhsd",
                            accum_dtype: str = "float32"):
    """q: (B, Hkv, G, d); caches: (B, Hkv, S, d) ("bhsd") or (B, S, Hkv, d)
    ("bshd"); cache_len: (B,) int32 per-row valid lengths (broadcast a
    scalar before calling); seq_offset: (1,) int32 absolute position of
    this cache slice's first row (zero when unsharded); s_valid: unpadded
    cache length (padded rows above it are never attended).
    Returns (B, Hkv, G, d). S divisible by block_s, B by the row block;
    d lane-padded — all handled by ops."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2] if layout == "bhsd" else k_cache.shape[1]
    bs = min(block_s, smax)
    ns = smax // bs
    bb = resolve_block_b(b, bs, d)
    kernel = functools.partial(
        _decode_kernel, block_b=bb, block_s=bs, ns=ns, s_valid=s_valid,
        sm_scale=sm_scale, exp_impl=exp_impl, window=window, layout=layout,
        partial=False)
    smem, q_spec, kv_spec = _specs(layout, bb, g, bs, d)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(b // bb, hkv, ns),
        in_specs=[smem, smem, q_spec, kv_spec, kv_spec],
        out_specs=pl.BlockSpec((bb, 1, g, d),
                               lambda bb_, hh, si: (bb_, hh, 0, 0)),
        scratch_shapes=_scratch(bb, g, d, accum_dtype),
        interpret=interpret,
    )(cache_len, seq_offset, q, k_cache, v_cache)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "block_s", "s_valid", "interpret", "exp_impl", "window",
    "layout", "accum_dtype"))
def decode_attention_kernel_partial(q, k_cache, v_cache, cache_len,
                                    seq_offset, *, sm_scale: float,
                                    s_valid: int,
                                    block_s: int = DEFAULT_BLOCK_S,
                                    interpret: bool = False,
                                    exp_impl: str = "vexp",
                                    window=None, layout: str = "bhsd",
                                    accum_dtype: str = "float32"):
    """Partial-statistics mode: same sweep, but emits the shard's raw
    (m, l, acc) — shapes (B, Hkv, G, 1) ×2 and (B, Hkv, G, d), all f32 —
    with masking done in *global* positions (``seq_offset`` + local index
    against the global ``cache_len``). A shard whose slice lies entirely
    outside [cache_len - window, cache_len) returns the merge identity
    (NEG_INF, 0, 0)."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2] if layout == "bhsd" else k_cache.shape[1]
    bs = min(block_s, smax)
    ns = smax // bs
    bb = resolve_block_b(b, bs, d)
    kernel = functools.partial(
        _decode_kernel, block_b=bb, block_s=bs, ns=ns, s_valid=s_valid,
        sm_scale=sm_scale, exp_impl=exp_impl, window=window, layout=layout,
        partial=True)
    smem, q_spec, kv_spec = _specs(layout, bb, g, bs, d)
    stat = pl.BlockSpec((bb, 1, g, 1), lambda bb_, hh, si: (bb_, hh, 0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        ],
        grid=(b // bb, hkv, ns),
        in_specs=[smem, smem, q_spec, kv_spec, kv_spec],
        out_specs=[stat, stat,
                   pl.BlockSpec((bb, 1, g, d),
                                lambda bb_, hh, si: (bb_, hh, 0, 0))],
        scratch_shapes=_scratch(bb, g, d, accum_dtype),
        interpret=interpret,
    )(cache_len, seq_offset, q, k_cache, v_cache)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "block_s", "s_valid", "interpret", "exp_impl", "window",
    "layout", "accum_dtype"))
def decode_attention_kernel_packed(q, k_cache, v_cache, cache_len,
                                   seq_offset, *, sm_scale: float,
                                   s_valid: int,
                                   block_s: int = DEFAULT_BLOCK_S,
                                   interpret: bool = False,
                                   exp_impl: str = "vexp",
                                   window=None, layout: str = "bhsd",
                                   accum_dtype: str = "float32"):
    """Packed partial-statistics mode: the same sweep as
    ``decode_attention_kernel_partial`` but the shard's raw statistics
    land in ONE contiguous f32 tile of shape (B, Hkv, G, d + 2), laid out
    ``[acc | m | l]`` along the last axis — the unit the single-collective
    merge (``core.softmax.stats_merge_collective_packed``) all_gathers.
    The two stat lanes ride beyond ``d``; the merge slices them off after
    the fold, so the accumulator's lane padding stays untouched."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2] if layout == "bhsd" else k_cache.shape[1]
    bs = min(block_s, smax)
    ns = smax // bs
    bb = resolve_block_b(b, bs, d)
    kernel = functools.partial(
        _decode_kernel, block_b=bb, block_s=bs, ns=ns, s_valid=s_valid,
        sm_scale=sm_scale, exp_impl=exp_impl, window=window, layout=layout,
        partial=True, packed=True)
    smem, q_spec, kv_spec = _specs(layout, bb, g, bs, d)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d + 2), jnp.float32),
        grid=(b // bb, hkv, ns),
        in_specs=[smem, smem, q_spec, kv_spec, kv_spec],
        out_specs=pl.BlockSpec((bb, 1, g, d + 2),
                               lambda bb_, hh, si: (bb_, hh, 0, 0)),
        scratch_shapes=_scratch(bb, g, d, accum_dtype),
        interpret=interpret,
    )(cache_len, seq_offset, q, k_cache, v_cache)


def decode_attention_bhsd(q, k_cache, v_cache, cache_len, *, sm_scale: float,
                          block_s: int = DEFAULT_BLOCK_S,
                          interpret: bool = False, exp_impl: str = "vexp"):
    """Back-compat alias for the head-major unsharded kernel."""
    return decode_attention_kernel(
        q, k_cache, v_cache, cache_len, jnp.zeros((1,), jnp.int32),
        sm_scale=sm_scale, s_valid=k_cache.shape[2], block_s=block_s,
        interpret=interpret, exp_impl=exp_impl)


# -------------------------------------------------------------- paged sweep
#
# Block-table indirection: the KV "cache" is a pool of fixed-size physical
# pages — "bshd": (N, page, Hkv, d), "bhsd": (N, Hkv, page, d) — and each
# batch row owns a row of ``block_tab`` (B, nS) int32 mapping its logical
# page index to a physical pool page. The table rides in as a
# scalar-prefetch argument (SMEM), so the K/V BlockSpec index maps read
# ``tab[b, si]`` to drive the page DMA — the sweep walks a row's *logical*
# pages while fetching wherever the allocator placed them, and the online
# softmax math is unchanged from the contiguous kernel.
#
# The grid is (B, nS) with ALL KV heads folded into one block (decode
# pages are tiny, so fetching every head's slice of a page in one cell
# amortizes grid/DMA bookkeeping the way ``block_b`` row-batching does for
# the contiguous sweep — per-row tables make row-batching impossible).
# Entries of ``block_tab`` past a row's allocated extent must point at a
# real (reserved/scratch) page: the index map always fetches, compute is
# masked by ``cache_len``.

def _paged_kernel(tab_ref, len_ref, off_ref, q_ref, k_ref, v_ref, *refs,
                  page: int, ns: int, sm_scale: float, exp_impl: str,
                  window, layout: str, partial: bool, packed: bool = False):
    if packed:
        op_ref, m_ref, l_ref, acc_ref = refs
    elif partial:
        om_ref, ol_ref, oacc_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    bi = pl.program_id(0)
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ln = len_ref[bi]
    seq_off = off_ref[0]
    g_start = si * page + seq_off        # absolute position of this page
    exp_fn = get_exp_fn(exp_impl)
    live = g_start < ln
    if window is not None:
        live &= (g_start + page) > (ln - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * sm_scale       # (Hkv, G, d)
        k = k_ref[0]          # (Hkv, page, d) bhsd / (page, Hkv, d) bshd
        v = v_ref[0]
        if layout == "bhsd":
            kdims = (((2,), (2,)), ((0,), (0,)))
            vdims = (((2,), (1,)), ((0,), (0,)))
        else:                                             # "bshd"
            kdims = (((2,), (2,)), ((0,), (1,)))
            vdims = (((2,), (0,)), ((0,), (1,)))
        s = jax.lax.dot_general(q.astype(k.dtype), k, kdims,
                                preferred_element_type=jnp.float32)
        # (Hkv, G, page)
        kpos = g_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        keep = kpos < ln
        if window is not None:
            keep &= kpos >= ln - window
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_ref[...].astype(jnp.float32)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = exp_fn(m_prev - m_new)
        p = exp_fn(s - m_new)
        p = jnp.where(keep, p, 0.0)
        l_ref[...] = (l_ref[...].astype(jnp.float32) * alpha
                      + jnp.sum(p, -1, keepdims=True)).astype(l_ref.dtype)
        acc_ref[...] = (acc_ref[...].astype(jnp.float32) * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, vdims,
                            preferred_element_type=jnp.float32)
                        ).astype(acc_ref.dtype)
        m_ref[...] = m_new.astype(m_ref.dtype)

    @pl.when(si == ns - 1)
    def _finalize():
        if packed:
            op_ref[0] = jnp.concatenate(
                [acc_ref[...].astype(op_ref.dtype),
                 m_ref[...].astype(op_ref.dtype),
                 l_ref[...].astype(op_ref.dtype)], axis=-1)
        elif partial:
            om_ref[0] = m_ref[...].astype(om_ref.dtype)
            ol_ref[0] = l_ref[...].astype(ol_ref.dtype)
            oacc_ref[0] = acc_ref[...].astype(oacc_ref.dtype)
        else:
            inv = 1.0 / jnp.maximum(l_ref[...].astype(jnp.float32), 1e-30)
            o_ref[0] = (acc_ref[...].astype(jnp.float32)
                        * inv).astype(o_ref.dtype)


def _paged_call(q, k_pool, v_pool, block_tab, cache_len, seq_offset, *,
                sm_scale, interpret, exp_impl, window, layout, accum_dtype,
                partial, packed):
    from jax.experimental.pallas import tpu as pltpu
    b, hkv, g, d = q.shape
    page = k_pool.shape[2] if layout == "bhsd" else k_pool.shape[1]
    ns = block_tab.shape[1]
    kernel = functools.partial(
        _paged_kernel, page=page, ns=ns, sm_scale=sm_scale,
        exp_impl=exp_impl, window=window, layout=layout, partial=partial,
        packed=packed)
    q_spec = pl.BlockSpec((1, hkv, g, d),
                          lambda bi, si, tab, ln, off: (bi, 0, 0, 0))
    if layout == "bhsd":
        kv_spec = pl.BlockSpec(
            (1, hkv, page, d),
            lambda bi, si, tab, ln, off: (tab[bi, si], 0, 0, 0))
    else:
        kv_spec = pl.BlockSpec(
            (1, page, hkv, d),
            lambda bi, si, tab, ln, off: (tab[bi, si], 0, 0, 0))
    out_map = lambda bi, si, tab, ln, off: (bi, 0, 0, 0)   # noqa: E731
    adt = _ACCUM_DTYPES[accum_dtype]
    scratch = [pltpu.VMEM((hkv, g, 1), adt), pltpu.VMEM((hkv, g, 1), adt),
               pltpu.VMEM((hkv, g, d), adt)]
    if packed:
        out_shape = jax.ShapeDtypeStruct((b, hkv, g, d + 2), jnp.float32)
        out_specs = pl.BlockSpec((1, hkv, g, d + 2), out_map)
    elif partial:
        out_shape = [jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
                     jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
                     jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32)]
        stat = pl.BlockSpec((1, hkv, g, 1), out_map)
        out_specs = [stat, stat, pl.BlockSpec((1, hkv, g, d), out_map)]
    else:
        out_shape = jax.ShapeDtypeStruct(q.shape, q.dtype)
        out_specs = pl.BlockSpec((1, hkv, g, d), out_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3, grid=(b, ns),
        in_specs=[q_spec, kv_spec, kv_spec], out_specs=out_specs,
        scratch_shapes=scratch)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        block_tab, cache_len, seq_offset, q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "interpret", "exp_impl", "window", "layout", "accum_dtype"))
def decode_attention_kernel_paged(q, k_pool, v_pool, block_tab, cache_len,
                                  seq_offset, *, sm_scale: float,
                                  interpret: bool = False,
                                  exp_impl: str = "vexp", window=None,
                                  layout: str = "bshd",
                                  accum_dtype: str = "float32"):
    """Paged flash-decode. q: (B, Hkv, G, d); pools: (N, page, Hkv, d)
    ("bshd") or (N, Hkv, page, d) ("bhsd"); block_tab: (B, nS) int32
    physical page per logical page (entries past a row's extent must
    reference a valid reserved page); cache_len: (B,) int32; seq_offset:
    (1,) int32 absolute position of logical page 0 (shard-local tables).
    Returns (B, Hkv, G, d)."""
    return _paged_call(q, k_pool, v_pool, block_tab, cache_len, seq_offset,
                       sm_scale=sm_scale, interpret=interpret,
                       exp_impl=exp_impl, window=window, layout=layout,
                       accum_dtype=accum_dtype, partial=False, packed=False)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "interpret", "exp_impl", "window", "layout", "accum_dtype"))
def decode_attention_kernel_paged_partial(q, k_pool, v_pool, block_tab,
                                          cache_len, seq_offset, *,
                                          sm_scale: float,
                                          interpret: bool = False,
                                          exp_impl: str = "vexp",
                                          window=None, layout: str = "bshd",
                                          accum_dtype: str = "float32"):
    """Paged partial-statistics sweep: raw (m, l, acc) per shard, masked in
    global coordinates — the paged counterpart of
    ``decode_attention_kernel_partial`` (block tables shard with the
    sequence axis, so each shard sweeps its local table slice)."""
    return _paged_call(q, k_pool, v_pool, block_tab, cache_len, seq_offset,
                       sm_scale=sm_scale, interpret=interpret,
                       exp_impl=exp_impl, window=window, layout=layout,
                       accum_dtype=accum_dtype, partial=True, packed=False)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "interpret", "exp_impl", "window", "layout", "accum_dtype"))
def decode_attention_kernel_paged_packed(q, k_pool, v_pool, block_tab,
                                         cache_len, seq_offset, *,
                                         sm_scale: float,
                                         interpret: bool = False,
                                         exp_impl: str = "vexp",
                                         window=None, layout: str = "bshd",
                                         accum_dtype: str = "float32"):
    """Paged packed partial mode: one contiguous (B, Hkv, G, d+2) f32
    [acc | m | l] tile per shard — the single-collective merge unit."""
    return _paged_call(q, k_pool, v_pool, block_tab, cache_len, seq_offset,
                       sm_scale=sm_scale, interpret=interpret,
                       exp_impl=exp_impl, window=window, layout=layout,
                       accum_dtype=accum_dtype, partial=True, packed=True)
