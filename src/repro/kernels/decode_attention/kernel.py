"""Fused decode-attention Pallas kernel (flash-decode, VEXP partial softmax).

Substantiates EXPERIMENTS.md §Perf iteration C4: one decode step reads the
KV cache exactly once from HBM — the (m, l, acc) online-softmax statistics
live in VMEM scratch across the KV-block sweep, and the cache is consumed
in its storage dtype (bf16) with f32 accumulation. Head-major ("bhsd")
cache layout: (B, Hkv, S, hd), the §Perf C3 layout.

Grid = (B, Hkv, nS) with the KV sweep innermost; each program handles one
KV head's query group (GQA: G = H // Hkv query rows).

``cache_len`` is a per-batch-row (B,) vector in SMEM: each grid row masks
its KV sweep against its own length, so a continuous-batching server can
decode slots whose requests are at different positions in one program
(ragged slot lengths never touch each other's cache rows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vexp import get_exp_fn

NEG_INF = -1e30
DEFAULT_BLOCK_S = 512


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_s: int, ns: int,
                   sm_scale: float, exp_impl: str):
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[bi]
    start = si * block_s
    exp_fn = get_exp_fn(exp_impl)

    @pl.when(start < cache_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale     # (G, d)
        k = k_ref[0, 0]                                    # (bs, d) bf16/f32
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q.astype(k.dtype), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (G, bs)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = exp_fn(m_prev - m_new)
        p = exp_fn(s - m_new)
        p = jnp.where(kpos < cache_len, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        inv = 1.0 / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] * inv).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_s",
                                             "interpret", "exp_impl"))
def decode_attention_bhsd(q, k_cache, v_cache, cache_len, *,
                          sm_scale: float,
                          block_s: int = DEFAULT_BLOCK_S,
                          interpret: bool = False,
                          exp_impl: str = "vexp"):
    """q: (B, Hkv, G, d); caches: (B, Hkv, S, d); cache_len: (B,) int32
    per-row valid lengths (broadcast a scalar before calling).
    Returns (B, Hkv, G, d). S divisible by block_s; d lane-padded by ops."""
    b, hkv, g, d = q.shape
    smax = k_cache.shape[2]
    bs = min(block_s, smax)
    ns = smax // bs
    kernel = functools.partial(_decode_kernel, block_s=bs, ns=ns,
                               sm_scale=sm_scale, exp_impl=exp_impl)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, si: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bb, hh, si: (bb, hh, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bb, hh, si: (bb, hh, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, hh, si: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, q, k_cache, v_cache)
