from .ops import (decode_attention, decode_attention_partial,
                  decode_attention_partial_packed,
                  decode_attention_partial_merged,
                  decode_attention_sharded, decode_attention_policy)
from .ref import decode_attention_ref
