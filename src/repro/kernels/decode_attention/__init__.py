from .ops import decode_attention
from .ref import decode_attention_ref
