"""jit'd public wrapper for the FlashAttention-2 Pallas kernel.

Handles (B, S, H, D) layout, GQA, head-dim / sequence padding to lane
alignment, and provides a custom VJP whose backward pass is the pure-jnp
flash reference (recompute; forward speed is what the paper optimizes —
its evaluation is inference).

Policy-aware: ``flash_attention`` accepts an ``ExecPolicy`` as its last
non-differentiable argument (hashable -> static, so jit caches per policy);
``flash_attention_policy`` is the kernels.dispatch entry point and applies
block-size autotuning when the policy requests it.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.policy import ExecPolicy
from .kernel import flash_attention_bhsd
from .ref import flash_attention_ref


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    target = -(-s // mult) * mult
    if target == s:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - s)
    return jnp.pad(x, pads)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, causal=True, window=None, sm_scale=None,
                    block_q=128, block_k=128, interpret=None,
                    policy: Optional[ExecPolicy] = None):
    """FlashAttention-2 with pluggable partial-softmax exp. q (B,Sq,H,D),
    k/v (B,Sk,Hkv,D). Returns (B,Sq,H,D). A policy overrides block sizes,
    interpret mode and the exp backend."""
    return _fa_fwd_impl(q, k, v, causal, window, sm_scale, block_q, block_k,
                        interpret, policy)


def _fa_fwd_impl(q, k, v, causal, window, sm_scale, block_q, block_k,
                 interpret, policy):
    exp_impl, accum = "vexp", "float32"
    if policy is not None:
        exp_impl = policy.exp_backend
        block_q, block_k = policy.block_q, policy.block_k
        accum = policy.accum_dtype
        if interpret is None:
            interpret = policy.interpret_resolved()
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    # (B,S,H,D) -> (B,H,S,D); pad D to 128 lanes, S to block multiples.
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 3, 128), 2, block_q)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 3, 128), 2, block_k)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 3, 128), 2, block_k)
    out = flash_attention_bhsd(
        qt, kt, vt, sm_scale=scale, causal=causal, window=window,
        sk_valid=sk, block_q=block_q, block_k=block_k, interpret=interpret,
        exp_impl=exp_impl, accum_dtype=accum)
    return out[:, :, :sq, :d].transpose(0, 2, 1, 3)


def _fa_fwd(q, k, v, causal, window, sm_scale, block_q, block_k, interpret,
            policy):
    out = _fa_fwd_impl(q, k, v, causal, window, sm_scale, block_q, block_k,
                       interpret, policy)
    return out, (q, k, v)


def _fa_bwd(causal, window, sm_scale, block_q, block_k, interpret, policy,
            res, g):
    q, k, v = res
    exp_impl = policy.exp_backend if policy is not None else "vexp"
    # Recompute-based backward through the pure-jnp flash reference
    # (identical math, so gradients are consistent with the kernel fwd).
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention_ref(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            exp_impl=exp_impl),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_policy(q, k, v, *, causal=True, window=None,
                           sm_scale=None, policy: ExecPolicy):
    """kernels.dispatch entry: policy-driven blocks + optional autotune."""
    if policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "flash_attention", policy,
            lambda p: _fa_fwd_impl(q, k, v, causal, window, sm_scale,
                                   p.block_q, p.block_k, None, p),
            q, k, v)
    return flash_attention(q, k, v, causal, window, sm_scale,
                           policy.block_q, policy.block_k, None, policy)
