"""Pure-jnp oracle for the FlashAttention-2 kernel."""

from repro.core.attention import attention_flash, attention_xla


def flash_attention_ref(q, k, v, *, causal=True, window=None, sm_scale=None,
                        exp_impl="vexp"):
    """Oracle with identical math (partial softmax with the selected exp
    backend), (B,S,H,D) layout."""
    return attention_flash(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale, exp_impl=exp_impl)


def attention_exact_ref(q, k, v, *, causal=True, window=None, sm_scale=None):
    """Exact-exp materialized attention, for accuracy comparisons."""
    return attention_xla(q, k, v, causal=causal, window=window,
                         sm_scale=sm_scale, exp_impl="exact")
