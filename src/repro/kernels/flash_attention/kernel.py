"""FlashAttention-2 forward Pallas kernel with the VEXP partial softmax.

TPU adaptation of the paper's optimized FlashAttention-2 (§IV-D): the Snitch
implementation streams K/V tiles HBM→SPM with DMA double-buffering and runs
the partial softmax (partial MAX / EXP / NORM with VFEXP) per tile; here the
Pallas grid walks KV blocks with the same online (m, l, acc) statistics,
Q/K/V tiles staged HBM→VMEM by the pipeline emitter, scores computed on the
MXU and the exp on the VPU via the bit-twiddled VEXP datapath.

Layout: q (B, H, Sq, D), k/v (B, Hkv, Sk, D), GQA resolved in the index maps
(query head h reads KV head h // group). Grid = (B, H, nQ, nK), KV innermost
so the VMEM scratch carries (m, l, acc) across the KV sweep.

Causal/windowed masking skips fully-masked KV blocks via pl.when — the same
work-skipping the paper gets from FlashAttention's tile scheduling.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vexp import get_exp_fn

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               sm_scale: float, causal: bool, window, block_q: int,
               block_k: int, nk: int, sk_valid: int, exp_impl: str):
    # (m, l, acc) live in scratch in the policy's accum dtype (see
    # flash_attention_bhsd); math happens in f32, stores round back down.
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Static-shape bounds check: is any (q, k) pair in this tile live?
    # q position >= k position for causal; within window if windowed.
    live = k_start < sk_valid
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 > q_start - window

    exp_fn = get_exp_fn(exp_impl)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = kpos < sk_valid
        if causal:
            keep &= kpos <= qpos
        if window is not None:
            keep &= kpos > qpos - window
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[...].astype(jnp.float32)
        m_blk = jnp.max(s, axis=-1, keepdims=True)          # partial MAX
        m_new = jnp.maximum(m_prev, m_blk)
        alpha = exp_fn(m_prev - m_new)                      # rescale
        p = exp_fn(s - m_new)                               # partial EXP
        p = jnp.where(keep, p, 0.0)
        l_new = (l_ref[...].astype(jnp.float32) * alpha
                 + jnp.sum(p, axis=-1, keepdims=True))
        acc_ref[...] = (acc_ref[...].astype(jnp.float32) * alpha
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())))
                        ).astype(acc_ref.dtype)
        m_ref[...] = m_new.astype(m_ref.dtype)
        l_ref[...] = l_new.astype(l_ref.dtype)

    @pl.when(ki == nk - 1)
    def _finalize():
        # partial NORM: one reciprocal per row, multiply through.
        l = l_ref[...].astype(jnp.float32)
        inv = 1.0 / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = (acc_ref[...].astype(jnp.float32)
                       * inv).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "window", "block_q", "block_k",
                     "sk_valid", "interpret", "exp_impl", "accum_dtype"))
def flash_attention_bhsd(q, k, v, *, sm_scale: float, causal: bool,
                         window, sk_valid: int,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False,
                         exp_impl: str = "vexp",
                         accum_dtype: str = "float32"):
    """q (B,H,Sq,D); k,v (B,Hkv,Sk,D); dims divisible by blocks/lane tiles.

    sk_valid: number of valid KV positions (Sk may be padded above it).
    accum_dtype: dtype of the (m, l, acc) VMEM scratch — "float32" is the
    paper-faithful setting; "bfloat16" halves scratch bytes at an accuracy
    cost the policy sweep quantifies.
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    bq, bk = min(block_q, sq), min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _fa_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=bq, block_k=bk, nk=nk, sk_valid=sk_valid, exp_impl=exp_impl)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        scratch_shapes=[
            pltpu_scratch((bq, 1), accum_dtype),
            pltpu_scratch((bq, 1), accum_dtype),
            pltpu_scratch((bq, d), accum_dtype),
        ],
        interpret=interpret,
    )(q, k, v)


def pltpu_scratch(shape, accum_dtype: str = "float32"):
    """VMEM scratch (indirection keeps the TPU import optional on CPU)."""
    from jax.experimental.pallas import tpu as pltpu
    dt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32
    return pltpu.VMEM(shape, dt)
