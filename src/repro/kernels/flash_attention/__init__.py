from .ops import flash_attention, flash_attention_policy
from .ref import flash_attention_ref, attention_exact_ref
