from .ops import flash_attention
from .ref import flash_attention_ref, attention_exact_ref
