"""Pallas TPU kernels for the paper's compute hot spots, plus the
policy-driven dispatch layer.

Packages: ``vexp`` (elementwise exponential), ``softmax`` (fused row
softmax), ``flash_attention`` (FlashAttention-2 forward), and
``decode_attention`` (flash-decode over a KV cache). Each provides
``kernel.py`` (the Pallas body — exp backend arrives as a static
``exp_impl`` argument, never a hardcoded import), ``ops.py`` (shape
handling + ``ExecPolicy`` static argument) and ``ref.py`` (pure-jnp
oracle).

``dispatch.py`` maps (op, policy.kernel_backend) onto an implementation
and owns the shape-bucketed block-size autotune cache. Import via::

    from repro.kernels.dispatch import dispatch
    out = dispatch("softmax", policy)(x, policy=policy)
"""
