"""Kernel dispatch table + shape-bucketed block-size autotuner.

One entry point — ``dispatch(op, policy)`` — maps every numeric op in the
stack onto the implementation the ``ExecPolicy`` selects:

    op                 pallas                      reference            xla
    ----------------   -------------------------  ------------------   ----
    vexp               kernels.vexp (tiled)        core vexp (untiled)  same
    softmax            kernels.softmax (fused)     core softmax         core
    flash_attention    kernels.flash_attention     core attention_flash core attention_xla
    decode_attention   kernels.decode_attention    core decode (bhsd)   core decode

All returned callables accept ``policy=`` and thread the policy's exp
backend / block sizes / interpret flag down to the kernel bodies, so a
single policy switch flips numerics end to end. ``decode_attention``
implementations (all three backends) accept a scalar *or* per-slot
``(B,)`` ``cache_len`` — the serving engine's continuous-batching
contract — and mask each batch row against its own length.

Autotuning: ``autotune_policy(op, policy, *shapes)`` times a small set of
candidate block sizes on first sight of a (device, op, shape-bucket) key and
memoizes the winner, so repeated shapes never re-time. Shape buckets round
dims up to powers of two — production serving sees few buckets even under
ragged batching.
"""

from __future__ import annotations

import functools
import math
import time
from typing import Callable, Dict, Tuple

import jax

from repro.runtime.policy import ExecPolicy

# ------------------------------------------------------------------ registry

# (op, backend) -> "module:function". Lazy import paths keep this module
# free of circular imports (ops modules import dispatch for autotuning).
_TABLE: Dict[Tuple[str, str], str] = {}

OPS = ("vexp", "softmax", "flash_attention", "decode_attention")


def register(op: str, backend: str, target: str) -> None:
    _TABLE[(op, backend)] = target


def _load(target: str) -> Callable:
    mod_name, fn_name = target.split(":")
    mod = __import__(mod_name, fromlist=[fn_name])
    return getattr(mod, fn_name)


register("vexp", "pallas", "repro.kernels.vexp.ops:vexp")
register("vexp", "reference", "repro.kernels.dispatch:_vexp_fallback")
register("vexp", "xla", "repro.kernels.dispatch:_vexp_fallback")

register("softmax", "pallas", "repro.kernels.softmax.ops:softmax")
register("softmax", "reference", "repro.kernels.dispatch:_softmax_fallback")
register("softmax", "xla", "repro.kernels.dispatch:_softmax_fallback")

register("flash_attention", "pallas",
         "repro.kernels.flash_attention.ops:flash_attention_policy")
register("flash_attention", "reference",
         "repro.kernels.dispatch:_attention_reference")
register("flash_attention", "xla", "repro.kernels.dispatch:_attention_xla")

register("decode_attention", "pallas",
         "repro.kernels.decode_attention.ops:decode_attention_policy")
register("decode_attention", "reference",
         "repro.kernels.dispatch:_decode_fallback")
register("decode_attention", "xla", "repro.kernels.dispatch:_decode_fallback")


def dispatch(op: str, policy: ExecPolicy) -> Callable:
    """The callable implementing ``op`` under ``policy``.

    The returned function takes the op's arrays/kwargs plus ``policy=``;
    callers pass the same policy through (it is a static jit argument in
    the Pallas wrappers, so each policy compiles once and caches).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; one of {OPS}")
    try:
        target = _TABLE[(op, policy.kernel_backend)]
    except KeyError:
        raise ValueError(
            f"no implementation registered for op={op!r} "
            f"backend={policy.kernel_backend!r}")
    return _load(target)


# ------------------------------------------ non-pallas backend adapters

def _vexp_fallback(x, *, policy: ExecPolicy):
    """reference/xla vexp: the untiled core datapath (XLA fuses it)."""
    return policy.exp_fn()(x)


def _softmax_fallback(x, axis=-1, *, policy: ExecPolicy):
    from repro.core.softmax import softmax as core_softmax
    return core_softmax(x, axis=axis, exp_impl=policy.exp_backend)


def _attention_reference(q, k, v, *, causal=True, window=None, sm_scale=None,
                         policy: ExecPolicy):
    from repro.core.attention import attention_flash
    return attention_flash(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale, exp_impl=policy.exp_backend,
                           block_k=policy.block_k)


def _attention_xla(q, k, v, *, causal=True, window=None, sm_scale=None,
                   policy: ExecPolicy):
    from repro.core.attention import attention_xla
    return attention_xla(q, k, v, causal=causal, window=window,
                         sm_scale=sm_scale, exp_impl=policy.exp_backend)


def _decode_fallback(q, k_cache, v_cache, cache_len, *, window=None,
                     sm_scale=None, layout="bshd", policy: ExecPolicy):
    from repro.core.attention import decode_attention
    return decode_attention(q, k_cache, v_cache, cache_len, window=window,
                            sm_scale=sm_scale, exp_impl=policy.exp_backend,
                            layout=layout)


# ----------------------------------------------------------------- autotune

# Candidate block sizes per op. Each candidate is a dict of policy-field
# overrides; the tuner clamps to the workload in the ops wrappers (kernels
# min() blocks against actual dims).
CANDIDATES = {
    "softmax": [{"block_rows": r} for r in (32, 64, 128, 256)],
    "vexp": [{"block_rows": r} for r in (128, 256, 512)],
    "flash_attention": [{"block_q": q, "block_k": k}
                        for q, k in ((64, 64), (128, 128),
                                     (128, 256), (256, 128))],
    "decode_attention": [{"block_s": s} for s in (256, 512, 1024)],
}

# (device_kind, op, shape_bucket, policy_sans_blocks) -> winning overrides
_AUTOTUNE_CACHE: Dict[tuple, dict] = {}
_STATS = {"hits": 0, "misses": 0}


def autotune_cache_stats() -> dict:
    return dict(_STATS)


def autotune_cache_clear() -> None:
    _AUTOTUNE_CACHE.clear()
    _STATS["hits"] = 0
    _STATS["misses"] = 0


def _bucket_dim(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def shape_bucket(*arrays) -> tuple:
    """Pow2-rounded shape+dtype key; ragged shapes share few buckets."""
    return tuple((tuple(_bucket_dim(d) for d in a.shape), str(a.dtype))
                 for a in arrays)


def _device_kind() -> str:
    dev = jax.devices()[0]
    return f"{dev.platform}:{getattr(dev, 'device_kind', '')}"


def _time_call(fn, n_warmup=1, n_timed=3) -> float:
    for _ in range(n_warmup):
        jax.block_until_ready(fn())
    best = math.inf
    for _ in range(n_timed):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_policy(op: str, policy: ExecPolicy, run: Callable[[ExecPolicy], object],
                    *arrays) -> ExecPolicy:
    """Return ``policy`` with block sizes tuned for these array shapes.

    ``run(candidate_policy)`` must execute the op end to end (the ops
    wrappers pass a closure over their own jitted kernel). First call per
    (device, op, shape bucket) times every candidate; later calls are pure
    cache hits — no re-timing on a repeated shape.

    Timing is only meaningful eagerly: under an outer jit trace the arrays
    are tracers and wall-clock would measure tracing, not the kernel. In
    that case return the cached winner if one exists for this bucket
    (tuned eagerly earlier, e.g. by a warmup call) and otherwise fall back
    to the policy's static block sizes without polluting the cache.
    """
    base = policy.replace(autotune=False)
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        key = (_device_kind(), op, shape_bucket(*arrays),
               (base.exp_backend, base.kernel_backend, base.accum_dtype,
                base.interpret))
        cached = _AUTOTUNE_CACHE.get(key)
        if cached is not None:
            _STATS["hits"] += 1
            return base.replace(**cached)
        return base
    # Block sizes are what's being tuned, so key on everything else.
    key = (_device_kind(), op, shape_bucket(*arrays),
           (base.exp_backend, base.kernel_backend, base.accum_dtype,
            base.interpret))
    cached = _AUTOTUNE_CACHE.get(key)
    if cached is not None:
        _STATS["hits"] += 1
        return base.replace(**cached)
    _STATS["misses"] += 1
    best_overrides, best_t = {}, math.inf
    for overrides in CANDIDATES.get(op, [{}]):
        cand = base.replace(**overrides)
        try:
            t = _time_call(lambda: run(cand))
        except Exception:
            continue        # candidate invalid for this shape; skip
        if t < best_t:
            best_t, best_overrides = t, overrides
    _AUTOTUNE_CACHE[key] = best_overrides
    return base.replace(**best_overrides)
