"""Kernel dispatch table + shape-bucketed block-size autotuner.

One entry point — ``dispatch(op, policy)`` — maps every numeric op in the
stack onto the implementation the ``ExecPolicy`` selects:

    op                        pallas                      reference            xla
    -----------------------   -------------------------  ------------------   ----
    vexp                      kernels.vexp (tiled)        core vexp (untiled)  same
    softmax                   kernels.softmax (fused)     core softmax         core
    flash_attention           kernels.flash_attention     core attention_flash core attention_xla
    decode_attention          kernels.decode_attention    core decode          core decode
    decode_attention_sharded  shard_map partial +         core decode (GSPMD)  core decode (GSPMD)
                              packed/split stats merge

All returned callables accept ``policy=`` and thread the policy's exp
backend / block sizes / interpret flag down to the kernel bodies, so a
single policy switch flips numerics end to end. ``decode_attention``
implementations (all three backends) accept a scalar *or* per-slot
``(B,)`` ``cache_len`` — the serving engine's continuous-batching
contract — and mask each batch row against its own length.

Autotuning: ``autotune_policy(op, policy, *shapes)`` times a small set of
candidate block sizes on first sight of a (device, op, shape-bucket) key and
memoizes the winner, so repeated shapes never re-time. Shape buckets round
dims up to powers of two — production serving sees few buckets even under
ragged batching. Winners additionally persist to disk (JSON at
``$REPRO_AUTOTUNE_CACHE``, default ``~/.cache/repro/autotune.json``;
``off`` disables) keyed by (device_kind, op, shape_bucket, policy), loaded
lazily on the first lookup — a serving restart on the same device kind
skips re-timing entirely.
"""

from __future__ import annotations

import functools
import json
import math
import os
import tempfile
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.analysis.registry import hot_path
from repro.runtime.policy import ExecPolicy

# ------------------------------------------------------------------ registry

# (op, backend) -> "module:function". Lazy import paths keep this module
# free of circular imports (ops modules import dispatch for autotuning).
_TABLE: Dict[Tuple[str, str], str] = {}

OPS = ("vexp", "softmax", "flash_attention", "decode_attention",
       "decode_attention_sharded", "decode_attention_paged")


def register(op: str, backend: str, target: str) -> None:
    _TABLE[(op, backend)] = target


def _load(target: str) -> Callable:
    mod_name, fn_name = target.split(":")
    mod = __import__(mod_name, fromlist=[fn_name])
    return getattr(mod, fn_name)


register("vexp", "pallas", "repro.kernels.vexp.ops:vexp")
register("vexp", "reference", "repro.kernels.dispatch:_vexp_fallback")
register("vexp", "xla", "repro.kernels.dispatch:_vexp_fallback")

register("softmax", "pallas", "repro.kernels.softmax.ops:softmax")
register("softmax", "reference", "repro.kernels.dispatch:_softmax_fallback")
register("softmax", "xla", "repro.kernels.dispatch:_softmax_fallback")

register("flash_attention", "pallas",
         "repro.kernels.flash_attention.ops:flash_attention_policy")
register("flash_attention", "reference",
         "repro.kernels.dispatch:_attention_reference")
register("flash_attention", "xla", "repro.kernels.dispatch:_attention_xla")

register("decode_attention", "pallas",
         "repro.kernels.decode_attention.ops:decode_attention_policy")
register("decode_attention", "reference",
         "repro.kernels.dispatch:_decode_fallback")
register("decode_attention", "xla", "repro.kernels.dispatch:_decode_fallback")

# Sequence-parallel decode over a KV cache sharded along S: the pallas
# backend runs the partial-stats kernel per shard + the psum stats merge
# under shard_map; the other backends express the same reduction in jnp
# and let GSPMD lower the sharded max/sum to the partial-softmax merge.
register("decode_attention_sharded", "pallas",
         "repro.kernels.decode_attention.ops:decode_attention_sharded")
register("decode_attention_sharded", "reference",
         "repro.kernels.dispatch:_decode_sharded_fallback")
register("decode_attention_sharded", "xla",
         "repro.kernels.dispatch:_decode_sharded_fallback")

# Paged decode over a block pool + per-row block table: the pallas backend
# drives the page DMA from the scalar-prefetched table inside the kernel;
# the reference/xla backends materialize the gather (pool[tab]) and run
# the contiguous core reduction — same semantics, one extra copy.
register("decode_attention_paged", "pallas",
         "repro.kernels.decode_attention.ops:decode_attention_paged_policy")
register("decode_attention_paged", "reference",
         "repro.kernels.dispatch:_decode_paged_fallback")
register("decode_attention_paged", "xla",
         "repro.kernels.dispatch:_decode_paged_fallback")


def dispatch(op: str, policy: ExecPolicy) -> Callable:
    """The callable implementing ``op`` under ``policy``.

    The returned function takes the op's arrays/kwargs plus ``policy=``;
    callers pass the same policy through (it is a static jit argument in
    the Pallas wrappers, so each policy compiles once and caches).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; one of {OPS}")
    try:
        target = _TABLE[(op, policy.kernel_backend)]
    except KeyError:
        raise ValueError(
            f"no implementation registered for op={op!r} "
            f"backend={policy.kernel_backend!r}")
    return _load(target)


# ------------------------------------------ non-pallas backend adapters

def _vexp_fallback(x, *, policy: ExecPolicy):
    """reference/xla vexp: the untiled core datapath (XLA fuses it)."""
    return policy.exp_fn()(x)


def exp_callable(policy: Optional[ExecPolicy] = None,
                 exp_impl: str = "vexp") -> Callable:
    """Elementwise exp for model-internal gates under a policy.

    The recurrent families' exponentials — the RG-LRU gate
    ``a = exp(c·r·log a)``, the SSD decays/softplus and the SiLU gates —
    are the softmax-free sites where the paper's exp-backend choice still
    applies. This is their one resolution rule: ``policy.exp_backend``
    wins, the legacy ``exp_impl`` config string is the fallback — so a
    serving ``--policy-groups`` spec flips recurrent-gate numerics exactly
    like it flips attention softmax numerics. Every kernel backend
    resolves to the core datapath here: gates fuse into the surrounding
    elementwise work under XLA, and a per-gate ``pallas_call`` would cost
    more than the exp itself (the tiled kernel stays reserved for the
    standalone ``vexp`` op above).
    """
    from repro.core.vexp import get_exp_fn
    return get_exp_fn(policy.exp_backend if policy is not None else exp_impl)


def _softmax_fallback(x, axis=-1, *, policy: ExecPolicy):
    from repro.core.softmax import softmax as core_softmax
    return core_softmax(x, axis=axis, exp_impl=policy.exp_backend)


def _attention_reference(q, k, v, *, causal=True, window=None, sm_scale=None,
                         policy: ExecPolicy):
    from repro.core.attention import attention_flash
    return attention_flash(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale, exp_impl=policy.exp_backend,
                           block_k=policy.block_k)


def _attention_xla(q, k, v, *, causal=True, window=None, sm_scale=None,
                   policy: ExecPolicy):
    from repro.core.attention import attention_xla
    return attention_xla(q, k, v, causal=causal, window=window,
                         sm_scale=sm_scale, exp_impl=policy.exp_backend)


@hot_path
def _decode_fallback(q, k_cache, v_cache, cache_len, *, window=None,
                     sm_scale=None, layout="bshd", policy: ExecPolicy):
    from repro.core.attention import decode_attention
    return decode_attention(q, k_cache, v_cache, cache_len, window=window,
                            sm_scale=sm_scale, exp_impl=policy.exp_backend,
                            layout=layout)


@hot_path
def _decode_paged_fallback(q, k_pool, v_pool, block_tab, cache_len, *,
                           window=None, sm_scale=None, layout="bshd",
                           policy: ExecPolicy):
    """reference/xla paged decode: gather the block table to a contiguous
    per-row cache and run the core reduction (the oracle semantics of the
    paged pallas sweep)."""
    from repro.core.attention import decode_attention
    from repro.kernels.decode_attention.ops import paged_gather
    k = paged_gather(k_pool, block_tab, layout)
    v = paged_gather(v_pool, block_tab, layout)
    return decode_attention(q, k, v, cache_len, window=window,
                            sm_scale=sm_scale, exp_impl=policy.exp_backend,
                            layout=layout)


@hot_path
def _decode_sharded_fallback(q, k_cache, v_cache, cache_len, *, mesh=None,
                             seq_axis="model", window=None, sm_scale=None,
                             layout="bshd", policy: ExecPolicy):
    """reference/xla sharded decode: the core reduction is written as pure
    max/sum over the cache's S axis, so jit + GSPMD lowers a seq-sharded
    cache to per-shard partials + all-reduce without explicit collectives
    (mesh/seq_axis are accepted for signature parity and unused)."""
    from repro.core.attention import decode_attention
    return decode_attention(q, k_cache, v_cache, cache_len, window=window,
                            sm_scale=sm_scale, exp_impl=policy.exp_backend,
                            layout=layout)


# ----------------------------------------------------------------- autotune

# Candidate block sizes per op. Each candidate is a dict of policy-field
# overrides; the tuner clamps to the workload in the ops wrappers (kernels
# min() blocks against actual dims).
CANDIDATES = {
    "softmax": [{"block_rows": r} for r in (32, 64, 128, 256)],
    "vexp": [{"block_rows": r} for r in (128, 256, 512)],
    "flash_attention": [{"block_q": q, "block_k": k}
                        for q, k in ((64, 64), (128, 128),
                                     (128, 256), (256, 128))],
    "decode_attention": [{"block_s": s} for s in (256, 512, 1024)],
    # Sequence-parallel decode tunes the *merge strategy*: one packed
    # all_gather of the contiguous (acc | m | l) tile vs the pmax + 2×psum
    # split form. Same algebra; the winner is interconnect-dependent.
    "decode_attention_sharded": [{"merge_strategy": "packed"},
                                 {"merge_strategy": "split"}],
    # Paged decode tunes the page size — but only at POOL CONSTRUCTION
    # (the page is the pool's physical block shape; DecodeState times
    # candidates on a synthetic pool before allocating the real one).
    "decode_attention_paged": [{"block_page": p} for p in (32, 64, 128)],
}

# repr((device_kind, op, shape_bucket, policy_sans_blocks)) -> winning
# overrides. String keys so the cache round-trips through JSON unchanged:
# the in-process winners are persisted to disk and re-loaded on the next
# process start, so serving restarts skip re-timing entirely.
_AUTOTUNE_CACHE: Dict[str, dict] = {}
_STATS = {"hits": 0, "misses": 0, "disk_loaded": 0}
_DISK_STATE = {"loaded": False}

# Path resolution: $REPRO_AUTOTUNE_CACHE (a file path; "off"/"0" disables
# persistence) -> ~/.cache/repro/autotune.json.
_DISK_ENV = "REPRO_AUTOTUNE_CACHE"
_CACHE_VERSION = 1


def autotune_cache_path() -> Optional[str]:
    raw = os.environ.get(_DISK_ENV, "").strip()
    if raw.lower() in ("0", "off", "none", "disabled"):
        return None
    if raw:
        return raw
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def load_autotune_cache(path: Optional[str] = None) -> int:
    """Merge the on-disk autotune cache into the in-process one (in-process
    entries win). Returns the number of entries loaded; missing/corrupt
    files load nothing. Called lazily on the first autotune lookup."""
    _DISK_STATE["loaded"] = True
    path = path if path is not None else autotune_cache_path()
    if not path:
        return 0
    try:
        with open(path) as fh:
            payload = json.load(fh)
        entries = payload.get("entries", {})
    except (OSError, ValueError):
        return 0
    n = 0
    for key, overrides in entries.items():
        if isinstance(key, str) and isinstance(overrides, dict) \
                and key not in _AUTOTUNE_CACHE:
            _AUTOTUNE_CACHE[key] = overrides
            n += 1
    _STATS["disk_loaded"] += n
    return n


def save_autotune_cache(path: Optional[str] = None) -> Optional[str]:
    """Atomically persist the in-process cache; best-effort (a read-only
    filesystem must never break serving). Returns the path written.

    Concurrent-serve safe: the write goes through a private tmpfile +
    ``os.replace`` (readers never observe a torn file), and the entries a
    *different* process persisted since we last read the file are merged
    back in before writing (in-process winners take precedence on key
    collisions — both processes timed the same bucket, either answer is
    valid). Two engines racing the JSON therefore converge on the union
    of their winners instead of the last writer clobbering the first.
    """
    path = path if path is not None else autotune_cache_path()
    if not path or not _AUTOTUNE_CACHE:
        return None
    try:
        cache_dir = os.path.dirname(path) or "."
        os.makedirs(cache_dir, exist_ok=True)
        merged: Dict[str, dict] = {}
        try:
            with open(path) as fh:
                on_disk = json.load(fh).get("entries", {})
            merged.update({k: v for k, v in on_disk.items()
                           if isinstance(k, str) and isinstance(v, dict)})
        except (OSError, ValueError, AttributeError):
            pass                      # missing/corrupt file: start fresh
        merged.update(_AUTOTUNE_CACHE)
        fd, tmp = tempfile.mkstemp(dir=cache_dir, prefix=".autotune-")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump({"version": _CACHE_VERSION, "entries": merged},
                          fh, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)        # never leave tmp droppings behind
            except OSError:
                pass
            raise
        return path
    except OSError:
        return None


def autotune_cache_stats() -> dict:
    return dict(_STATS, entries=len(_AUTOTUNE_CACHE))


def autotune_cache_clear() -> None:
    _AUTOTUNE_CACHE.clear()
    _STATS["hits"] = 0
    _STATS["misses"] = 0
    _STATS["disk_loaded"] = 0
    _DISK_STATE["loaded"] = False


def _bucket_dim(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def shape_bucket(*arrays) -> tuple:
    """Pow2-rounded shape+dtype key; ragged shapes share few buckets."""
    return tuple((tuple(_bucket_dim(d) for d in a.shape), str(a.dtype))
                 for a in arrays)


def _device_kind() -> str:
    dev = jax.devices()[0]
    return f"{dev.platform}:{getattr(dev, 'device_kind', '')}"


def _time_call(fn, n_warmup=1, n_timed=3) -> float:
    for _ in range(n_warmup):
        jax.block_until_ready(fn())
    best = math.inf
    for _ in range(n_timed):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_policy(op: str, policy: ExecPolicy, run: Callable[[ExecPolicy], object],
                    *arrays) -> ExecPolicy:
    """Return ``policy`` with block sizes tuned for these array shapes.

    ``run(candidate_policy)`` must execute the op end to end (the ops
    wrappers pass a closure over their own jitted kernel). First call per
    (device, op, shape bucket) times every candidate; later calls are pure
    cache hits — no re-timing on a repeated shape.

    Timing is only meaningful eagerly: under an outer jit trace the arrays
    are tracers and wall-clock would measure tracing, not the kernel. In
    that case return the cached winner if one exists for this bucket
    (tuned eagerly earlier, e.g. by a warmup call) and otherwise fall back
    to the policy's static block sizes without polluting the cache.
    """
    base = policy.replace(autotune=False)
    if not _DISK_STATE["loaded"]:
        load_autotune_cache()
    # Block sizes are what's being tuned, so key on everything else.
    key = repr((_device_kind(), op, shape_bucket(*arrays),
                (base.exp_backend, base.kernel_backend, base.accum_dtype,
                 base.interpret)))
    cached = _AUTOTUNE_CACHE.get(key)
    if cached is not None:
        _STATS["hits"] += 1
        return base.replace(**cached)
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return base
    _STATS["misses"] += 1
    best_overrides, best_t = {}, math.inf
    for overrides in CANDIDATES.get(op, [{}]):
        cand = base.replace(**overrides)
        try:
            t = _time_call(lambda: run(cand))
        except Exception:
            continue        # candidate invalid for this shape; skip
        if t < best_t:
            best_t, best_overrides = t, overrides
    _AUTOTUNE_CACHE[key] = best_overrides
    save_autotune_cache()
    return base.replace(**best_overrides)
