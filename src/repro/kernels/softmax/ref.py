"""Pure-jnp oracle for the fused softmax kernel."""

import jax.numpy as jnp

from repro.core.vexp import vexp_f32


def softmax_ref(x, axis=-1):
    """Same algorithm (max-subtract, vexp, reciprocal-multiply), un-tiled."""
    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=axis, keepdims=True)
    e = vexp_f32(xf - m)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return (e * (1.0 / s)).astype(x.dtype)


def softmax_exact_ref(x, axis=-1):
    import jax
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(x.dtype)
