"""jit'd public wrapper for the fused softmax kernel (arbitrary shapes)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import softmax_rows, NEG_INF


@functools.partial(jax.jit, static_argnames=("axis", "interpret"))
def softmax(x: jax.Array, axis: int = -1, *,
            interpret: bool | None = None) -> jax.Array:
    """Fused VEXP softmax along ``axis`` for any-rank inputs.

    Moves ``axis`` last, flattens leading dims, pads the reduction dim to a
    lane multiple with NEG_INF (whose vexp is exactly 0, so padding does not
    perturb the denominator), runs the kernel, and restores layout.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    axis = axis % x.ndim
    perm = None
    if axis != x.ndim - 1:
        perm = list(range(x.ndim))
        perm[axis], perm[-1] = perm[-1], perm[axis]
        x = jnp.transpose(x, perm)
    shape = x.shape
    n = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, n)
    n_pad = -(-n // 128) * 128
    if n_pad != n:
        x2 = jnp.pad(x2, ((0, 0), (0, n_pad - n)),
                     constant_values=jnp.asarray(NEG_INF, x.dtype))
    block_rows = max(1, min(64, rows))
    rows_pad = -(-rows // block_rows) * block_rows
    if rows_pad != rows:
        x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, 0)))
    out = softmax_rows(x2, block_rows=block_rows, interpret=interpret)
    out = out[:rows, :n].reshape(shape)
    if perm is not None:
        out = jnp.transpose(out, perm)
    return out
