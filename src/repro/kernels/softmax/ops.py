"""jit'd public wrapper for the fused softmax kernel (arbitrary shapes).

Policy-aware: an ``ExecPolicy`` supplies the exp backend, row-block size and
interpret mode as one static jit argument; with ``policy.autotune`` the row
block is picked by timing candidates once per (device, shape bucket).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.runtime.policy import ExecPolicy
from .kernel import softmax_rows, NEG_INF


@functools.partial(jax.jit, static_argnames=("axis", "interpret", "policy"))
def _softmax_impl(x, axis, interpret, policy):
    exp_impl = policy.exp_backend if policy is not None else "vexp"
    block_rows = policy.block_rows if policy is not None else 64
    axis = axis % x.ndim
    perm = None
    if axis != x.ndim - 1:
        perm = list(range(x.ndim))
        perm[axis], perm[-1] = perm[-1], perm[axis]
        x = jnp.transpose(x, perm)
    shape = x.shape
    n = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, n)
    n_pad = -(-n // 128) * 128
    if n_pad != n:
        x2 = jnp.pad(x2, ((0, 0), (0, n_pad - n)),
                     constant_values=jnp.asarray(NEG_INF, x.dtype))
    block_rows = max(1, min(block_rows, rows))
    rows_pad = -(-rows // block_rows) * block_rows
    if rows_pad != rows:
        x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, 0)))
    out = softmax_rows(x2, block_rows=block_rows, interpret=interpret,
                       exp_impl=exp_impl)
    out = out[:rows, :n].reshape(shape)
    if perm is not None:
        out = jnp.transpose(out, perm)
    return out


def softmax(x: jax.Array, axis: int = -1, *,
            interpret: bool | None = None,
            policy: Optional[ExecPolicy] = None) -> jax.Array:
    """Fused softmax along ``axis`` for any-rank inputs.

    Moves ``axis`` last, flattens leading dims, pads the reduction dim to a
    lane multiple with NEG_INF (whose exp is exactly 0, so padding does not
    perturb the denominator), runs the kernel, and restores layout.
    """
    if interpret is None:
        interpret = (policy.interpret_resolved() if policy is not None
                     else jax.default_backend() == "cpu")
    if policy is not None and policy.autotune:
        from repro.kernels.dispatch import autotune_policy
        policy = autotune_policy(
            "softmax", policy,
            lambda p: _softmax_impl(x, axis, interpret, p), x)
    return _softmax_impl(x, axis, interpret, policy)
