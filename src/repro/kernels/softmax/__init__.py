from .ops import softmax
from .ref import softmax_ref, softmax_exact_ref
