"""Fused row-softmax Pallas kernel with the VEXP exponential.

TPU counterpart of the paper's optimized Softmax kernel (§IV-C, Fig. 4):
one VMEM pass per row block performs

  MAX   row max (the paper's VFMAX/FREP loop),
  EXP   vexp(x - max) with the sum accumulated in the same pass
        (the paper's VFEXP + VFADD inside one FREP loop),
  NORM  a single reciprocal then a pointwise multiply (VFMUL), never a
        per-element divide.

Rows live entirely in VMEM for one grid step, so HBM traffic is exactly
read-once/write-once — the same property the Snitch kernel gets from its
SSR-streamed SPM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vexp import get_exp_fn

NEG_INF = -1e30


def _softmax_kernel(x_ref, o_ref, *, exp_impl: str):
    exp_fn = get_exp_fn(exp_impl)
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)                   # MAX
    e = exp_fn(x - m)                                        # EXP (+ sum)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e * (1.0 / s)).astype(o_ref.dtype)         # NORM


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "exp_impl"))
def softmax_rows(x: jax.Array, *, block_rows: int = 64,
                 interpret: bool = False,
                 exp_impl: str = "vexp") -> jax.Array:
    """Softmax along the last axis of a 2D array.

    The row length must be lane-aligned (padding handled by ops.py with
    NEG_INF so padded lanes contribute exp() = 0 to the sum).
    """
    m, n = x.shape
    bm = min(block_rows, m)
    grid = (m // bm,)
    return pl.pallas_call(
        functools.partial(_softmax_kernel, exp_impl=exp_impl),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
