"""Runtime execution-policy layer.

``ExecPolicy`` is the single object that decides *how* the numerics run —
which exponential backend (exact transcendental vs. the paper's VEXP
approximation vs. the bit-exact hardware model), which kernel backend
(Pallas TPU kernels vs. pure-jnp reference vs. XLA-fused), block sizes, and
interpret/accumulation settings — resolved once from model-config fields,
environment variables, and per-call overrides, then threaded through core,
kernels, models, serving and training.
"""

from .policy import (ExecPolicy, resolve_policy, policy_from_env,
                     parse_policy_groups,
                     EXP_BACKENDS, KERNEL_BACKENDS, ENV_PREFIX)

__all__ = ["ExecPolicy", "resolve_policy", "policy_from_env",
           "parse_policy_groups",
           "EXP_BACKENDS", "KERNEL_BACKENDS", "ENV_PREFIX"]
