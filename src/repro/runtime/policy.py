"""ExecPolicy: one hashable object deciding numerics + kernels end to end.

The paper's premise is swappable exponentiation (§III: exact transcendental
vs. Schraudolph-based VEXP vs. the bit-exact RTL model) with kernel-level
integration (§IV-C/D). This module makes that a first-class runtime policy
instead of ad-hoc ``exp_impl`` strings and hardcoded kernel imports:

  resolution precedence (highest wins)
    1. per-call overrides        resolve_policy(cfg, exp_backend="exact")
    2. environment variables     REPRO_EXP_BACKEND=vexp_hw ...
    3. model-config fields       cfg.exp_impl / cfg.attention_impl / blocks
    4. library defaults          ExecPolicy()

``ExecPolicy`` is a frozen dataclass — hashable, so the kernels' ``ops.py``
wrappers take it as a *static* jit argument and XLA caches one executable
per policy (flipping a backend never silently retraces an old cache entry).
"""

from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass, replace
from typing import Callable, Mapping, Optional

EXP_BACKENDS = ("exact", "vexp", "vexp_hw")
KERNEL_BACKENDS = ("pallas", "reference", "xla")
ACCUM_DTYPES = ("float32", "bfloat16")
MERGE_STRATEGIES = ("packed", "split")

# Canonical correspondence between policy kernel backends and the legacy
# ``attention_impl`` names (the pure-jnp flash scan is the reference
# implementation). core.attention and configs.base import these — keep a
# single source of truth so a new backend only needs adding here.
KERNEL_BACKEND_TO_ATTN_IMPL = {"pallas": "pallas", "reference": "flash",
                               "xla": "xla"}
ATTN_IMPL_TO_KERNEL_BACKEND = {v: k for k, v in
                               KERNEL_BACKEND_TO_ATTN_IMPL.items()}

ENV_PREFIX = "REPRO_"

# env var -> policy field (suffix appended to ENV_PREFIX)
_ENV_FIELDS = {
    "EXP_BACKEND": "exp_backend",
    "KERNEL_BACKEND": "kernel_backend",
    "BLOCK_Q": "block_q",
    "BLOCK_K": "block_k",
    "BLOCK_ROWS": "block_rows",
    "BLOCK_S": "block_s",
    "BLOCK_PAGE": "block_page",
    "INTERPRET": "interpret",
    "ACCUM_DTYPE": "accum_dtype",
    "AUTOTUNE": "autotune",
    "MERGE_STRATEGY": "merge_strategy",
    "PREFILL_CHUNK": "prefill_chunk",
    "DEGRADE_EXP_BACKEND": "degrade_exp_backend",
    "SPEC_K": "spec_k",
    "DRAFT_EXP_BACKEND": "draft_exp_backend",
    "SPEC_VERIFY": "spec_verify",
}

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


@dataclass(frozen=True)
class ExecPolicy:
    """How to execute the softmax/attention stack.

    exp_backend     "exact" | "vexp" | "vexp_hw"   (core.vexp.EXP_FNS).
                    Governs every exponential in the stack, not just the
                    attention softmax: the recurrent families' gates —
                    hybrid's RG-LRU ``a = exp(c·r·log a)``, ssm's SSD
                    decays / softplus / SiLU — resolve through
                    ``kernels.dispatch.exp_callable(policy, ...)``, so a
                    serving policy group flips recurrent-gate numerics
                    exactly like softmax numerics.
    kernel_backend  "pallas"    — the Pallas TPU kernels (interpreted on CPU)
                    "reference" — pure-jnp blockwise implementations
                    "xla"       — XLA-fused materialized paths
    block_q/k       FlashAttention tile sizes (Pallas); block_k also feeds
                    the reference flash scan's KV block.
    block_rows      fused-softmax row-block size.
    block_s         decode-attention KV block size.
    block_page      paged-KV physical block (page) size in tokens: the
                    unit of the paged pool's free-list allocator AND the
                    paged decode kernel's sweep step (one page fetch per
                    grid cell). Fixed at pool construction — the
                    autotuner times candidates once, before the pool is
                    allocated, never per call.
    interpret       Pallas interpreter flag; None = auto (CPU -> True).
    accum_dtype     accumulation dtype of the Pallas kernels' (m, l, acc)
                    scratch statistics ("float32" is the paper-faithful
                    setting; "bfloat16" trades accuracy for scratch bytes
                    and is rejected on non-pallas backends, which always
                    accumulate in f32).
    autotune        pick block sizes by timing candidates per device+shape
                    bucket (memoized in kernels.dispatch).
    merge_strategy  how sequence-parallel decode folds per-shard softmax
                    statistics: "packed" all_gathers one contiguous
                    (acc | m | l) tile — a single collective per merge —
                    and folds locally; "split" is the pmax + 2×psum
                    three-collective form. Identical algebra either way;
                    autotune times both per (device kind, shape bucket).
    prefill_chunk   serving prefill chunk size in tokens. 0 (default) keeps
                    the monolithic one-wave prefill; > 0 streams each
                    prompt into its slot in fixed-size chunks interleaved
                    with decode steps (the engine runs at most one chunk
                    per tick, bounding the decode latency any single
                    prompt can add). Families may round the width up to
                    their invariant unit (ssm: ``cfg.ssm_chunk``) — see
                    ``DecodeState.chunk_width``.
    degrade_exp_backend
                    the exp backend a serving group flagged as
                    degradable (``--degrade-groups``) drops to under
                    sustained pool pressure. Defaults to "vexp_hw" — the
                    paper's bit-exact RTL model, whose ~0.78% accuracy
                    envelope is exactly the license for trading numerics
                    for throughput on bulk traffic. The engine restores
                    the group's own backend when pressure clears.
    spec_k          policy-speculative decoding: number of draft tokens
                    proposed per decode burst under the draft policy
                    before ONE batched verify step under this policy
                    scores all of them (longest agreeing prefix + bonus
                    token accepted — lossless for greedy decoding). 0
                    (default) keeps plain one-token decode; >= 2 enables
                    the speculative loop for serving groups that opt in
                    (``--spec-groups``) on families with cheap rollback.
    draft_exp_backend
                    the exp backend the k draft steps run under. Defaults
                    to "vexp_hw" — the paper's bit-exact RTL model: its
                    ~0.78% relative error rarely moves an argmax, so the
                    draft chain agrees with the exact verifier almost
                    always while every *emitted* token still comes from
                    the verify program under this policy's own backend.
    spec_verify     how the exact verifier scores the k+1 candidates:
                    "scan" (default) replays them as a fused scan of the
                    *same* decode-step program plain decode runs —
                    bitwise-identical tokens and cache by construction,
                    every family. "chunk" scores all lanes in ONE
                    batched prefill-chunk pass (reads cache + weights
                    once per burst — the throughput mode) but its
                    attention program differs from the decode step's by
                    ~1 bf16 ulp, which can flip argmax on near-tie
                    logits; KV-cache states only.
    """

    exp_backend: str = "vexp"
    kernel_backend: str = "pallas"
    block_q: int = 128
    block_k: int = 128
    block_rows: int = 64
    block_s: int = 512
    block_page: int = 64
    interpret: Optional[bool] = None
    accum_dtype: str = "float32"
    autotune: bool = False
    merge_strategy: str = "packed"
    prefill_chunk: int = 0
    degrade_exp_backend: str = "vexp_hw"
    spec_k: int = 0
    draft_exp_backend: str = "vexp_hw"
    spec_verify: str = "scan"

    def __post_init__(self):
        if self.exp_backend not in EXP_BACKENDS:
            raise ValueError(
                f"exp_backend {self.exp_backend!r} not in {EXP_BACKENDS}")
        if self.degrade_exp_backend not in EXP_BACKENDS:
            raise ValueError(
                f"degrade_exp_backend {self.degrade_exp_backend!r} "
                f"not in {EXP_BACKENDS}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"kernel_backend {self.kernel_backend!r} "
                f"not in {KERNEL_BACKENDS}")
        if self.accum_dtype not in ACCUM_DTYPES:
            raise ValueError(
                f"accum_dtype {self.accum_dtype!r} not in {ACCUM_DTYPES}")
        if self.merge_strategy not in MERGE_STRATEGIES:
            raise ValueError(
                f"merge_strategy {self.merge_strategy!r} "
                f"not in {MERGE_STRATEGIES}")
        if self.accum_dtype == "bfloat16" and self.kernel_backend != "pallas":
            # Only the Pallas kernels carry (m, l, acc) in policy-selected
            # scratch dtypes; the reference/xla paths accumulate in f32
            # unconditionally. Accepting the field there would hash two
            # numerically-identical programs under different jit keys and
            # silently ignore the requested numerics.
            raise ValueError(
                f"accum_dtype='bfloat16' is only honored by the pallas "
                f"kernel backend (got kernel_backend="
                f"{self.kernel_backend!r}); the reference/xla paths "
                f"always accumulate in float32")
        for f in ("block_q", "block_k", "block_rows", "block_s",
                  "block_page"):
            v = getattr(self, f)
            if not (isinstance(v, int) and v > 0):
                raise ValueError(f"{f} must be a positive int, got {v!r}")
        pc = self.prefill_chunk
        if not (isinstance(pc, int) and pc >= 0):
            raise ValueError(f"prefill_chunk must be an int >= 0 "
                             f"(0 = monolithic prefill), got {pc!r}")
        if self.draft_exp_backend not in EXP_BACKENDS:
            raise ValueError(
                f"draft_exp_backend {self.draft_exp_backend!r} "
                f"not in {EXP_BACKENDS}")
        sk = self.spec_k
        if not (isinstance(sk, int) and sk >= 0) or sk == 1:
            raise ValueError(
                f"spec_k must be 0 (plain decode) or an int >= 2 "
                f"(draft burst length), got {sk!r}")
        if self.spec_verify not in ("scan", "chunk"):
            raise ValueError(
                f"spec_verify must be 'scan' (bitwise-identical replay "
                f"of exact decode steps) or 'chunk' (one batched "
                f"all-lanes scoring pass; KV caches only), "
                f"got {self.spec_verify!r}")

    # ------------------------------------------------------------ accessors

    def exp_fn(self) -> Callable:
        """The exp callable for this policy (dtype-safe for all backends)."""
        from repro.core.vexp import get_exp_fn
        return get_exp_fn(self.exp_backend)

    def interpret_resolved(self) -> bool:
        """Concrete interpret flag (auto-selects on CPU hosts)."""
        if self.interpret is not None:
            return self.interpret
        import jax
        return jax.default_backend() == "cpu"

    def replace(self, **kw) -> "ExecPolicy":
        return replace(self, **kw)

    def describe(self) -> str:
        return (f"exp={self.exp_backend} kernel={self.kernel_backend} "
                f"blocks=(q{self.block_q},k{self.block_k},"
                f"r{self.block_rows},s{self.block_s},"
                f"p{self.block_page}) "
                f"accum={self.accum_dtype} merge={self.merge_strategy} "
                f"autotune={self.autotune} chunk={self.prefill_chunk} "
                f"degrade={self.degrade_exp_backend} "
                f"spec_k={self.spec_k} draft={self.draft_exp_backend} "
                f"spec_verify={self.spec_verify}")

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------- resolution

def _parse(field: str, raw: str):
    if field in ("block_q", "block_k", "block_rows", "block_s",
                 "block_page", "prefill_chunk", "spec_k"):
        try:
            return int(raw)
        except ValueError:
            raise ValueError(f"env override for {field} must be an int, "
                             f"got {raw!r}")
    if field in ("interpret", "autotune"):
        low = raw.strip().lower()
        if low in _TRUTHY:
            return True
        if low in _FALSY:
            return False
        raise ValueError(f"env override for {field} must be boolean-ish, "
                         f"got {raw!r}")
    return raw.strip()


def policy_from_env(env: Optional[Mapping[str, str]] = None) -> dict:
    """Policy field overrides present in the environment (validated)."""
    env = os.environ if env is None else env
    out = {}
    for suffix, field in _ENV_FIELDS.items():
        raw = env.get(ENV_PREFIX + suffix)
        if raw is not None and raw != "":
            out[field] = _parse(field, raw)
    return out


def _config_fields(cfg) -> dict:
    """Policy fields derivable from a ModelConfig (duck-typed: any object
    with the numeric-execution attributes works, so this module never
    imports repro.configs)."""
    out = {}
    exp = getattr(cfg, "exp_impl", None)
    if exp:
        out["exp_backend"] = exp
    kb = getattr(cfg, "kernel_backend", "") or ""
    if kb:
        out["kernel_backend"] = kb
    else:
        attn = getattr(cfg, "attention_impl", None)
        if attn:
            out["kernel_backend"] = ATTN_IMPL_TO_KERNEL_BACKEND.get(attn,
                                                                    attn)
    bk = getattr(cfg, "attn_block_k", 0)
    if bk:
        out["block_k"] = bk
    bq = getattr(cfg, "attn_block_q", 0)
    if bq:
        out["block_q"] = bq
    if getattr(cfg, "autotune_blocks", False):
        out["autotune"] = True
    return out


def resolve_policy(cfg=None, *, env: Optional[Mapping[str, str]] = None,
                   base: Optional[ExecPolicy] = None,
                   **overrides) -> ExecPolicy:
    """Resolve the effective ExecPolicy.

    Precedence: explicit ``overrides`` > environment variables
    (``REPRO_EXP_BACKEND`` etc.; pass ``env={}`` to ignore the process
    environment) > ``cfg`` fields > ``base`` (library defaults).
    Values are validated; unknown override names raise.
    """
    fields = {f.name for f in dataclasses.fields(ExecPolicy)}
    bad = set(overrides) - fields
    if bad:
        raise ValueError(f"unknown policy override(s) {sorted(bad)}; "
                         f"valid: {sorted(fields)}")
    merged = dataclasses.asdict(base) if base is not None else {}
    if cfg is not None:
        merged.update(_config_fields(cfg))
    merged.update(policy_from_env(env))
    merged.update({k: v for k, v in overrides.items() if v is not None})
    return ExecPolicy(**merged)


def parse_policy_groups(spec: str, cfg=None, *,
                        base: Optional[ExecPolicy] = None,
                        env: Optional[Mapping[str, str]] = None,
                        ) -> dict:
    """Parse a serving ``--policy-groups`` spec into named ExecPolicies.

    Format: ``name=exp_backend[/kernel_backend]`` entries joined by commas,
    e.g. ``"eval=exact,bulk=vexp"`` or ``"eval=exact/xla,bulk=vexp_hw"``.
    Each group resolves through the normal precedence chain (the named
    backends act as per-call overrides on top of env/config/base), so one
    server can batch eval traffic under exact numerics next to bulk
    traffic under the paper's VEXP approximation.

    When ``base`` is given it is an *already-resolved* policy (config,
    env and CLI overrides applied); ``cfg`` is then ignored and the
    process environment is not re-read (unless an ``env`` mapping is
    passed explicitly), so neither can shadow explicit overrides baked
    into the base (e.g. a CLI ``--kernel-backend`` beating
    ``cfg.attention_impl`` or a stale ``REPRO_EXP_BACKEND``).
    """
    if base is not None:
        cfg = None
        if env is None:
            env = {}
    groups = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, val = part.partition("=")
        name, val = name.strip(), val.strip()
        if not sep or not name or not val:
            raise ValueError(
                f"bad policy-group entry {part!r}; expected "
                f"name=exp_backend[/kernel_backend]")
        if name in groups:
            raise ValueError(f"duplicate policy group {name!r}")
        exp, _, kb = val.partition("/")
        overrides = {"exp_backend": exp.strip()}
        if kb.strip():
            overrides["kernel_backend"] = kb.strip()
        groups[name] = resolve_policy(cfg, base=base, env=env, **overrides)
    if not groups:
        raise ValueError(f"empty policy-groups spec {spec!r}")
    return groups
