"""Seeded fault injection for the serving stack (the chaos harness).

The slot engine's fault-tolerance claims — cancelled requests release
their pages, a poisoned slot is quarantined instead of streaming
garbage, admission pressure degrades service instead of crashing the
loop — are only claims until faults actually fire. ``FaultInjector``
makes them fire deterministically: a seeded RNG plus named injection
points threaded through ``launch/serve.py``, ``models/decode_state.py``
and ``models/block_pool.py``.

Design constraints (mirrors the hot-path contract):

* **Off by default, zero-cost when off.** Every call site guards with
  ``if injector is not None`` — disabled serving pays one attribute
  check per scheduling event and nothing per decode step.
* **Scheduling events only.** Faults fire at admission, chunk dispatch
  and decode dispatch — host-side decision points the engine already
  owns. No injection point adds a device sync, and the chunk/decode
  dispatch paths stay STEP_STRICT under ``repro.analysis``.
* **Deterministic per seed.** Points fire either on an explicit
  ``schedule`` (the Nth evaluation of that point) or at a seeded
  ``rate``; given the same seed and the same engine event order, the
  same faults fire. ``REPRO_FAULT_SEED`` seeds the CLI/CI runs.

This module is numpy-only (importable without jax) like block_pool.
"""

from __future__ import annotations

import os
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

FAULT_SEED_ENV = "REPRO_FAULT_SEED"

# The injection-point catalog. Call sites pass these names to ``fire``;
# anything else is a typo we want loud, not a silently-dead fault.
POINTS = (
    # admission rejected at the DecodeState entry (contiguous pools have
    # no allocator to exhaust, so this is how THEIR OutOfBlocks path is
    # exercised; paged pools get it too, upstream of any reservation)
    "admit.out_of_blocks",
    # allocation fails inside BlockAllocator._alloc_one — mid-alloc_cols,
    # so the all-or-nothing rollback and attach-release paths actually run
    "alloc.out_of_blocks",
    # the decode dispatch raises (donated carry must be presumed consumed)
    "decode.step_error",
    # NaNs written into one live slot's private state; the decode
    # program's finite-logits guard must catch it and the engine must
    # quarantine the slot
    "decode.poison",
    # a prefill chunk dispatch stalls (straggler chunk)
    "chunk.delay",
    # prefix-cache chains invalidated (the recovery action for detected
    # corruption: drop the entry, never serve it)
    "prefix.corrupt",
)


class InjectedFault(RuntimeError):
    """Raised by injection points that simulate a failed dispatch."""


class FaultInjector:
    """Seeded, named-point fault injector.

    Each point fires either on an explicit ``schedule`` (a set of event
    indices: the point's Nth evaluation, 0-based) or with probability
    ``rates[point]`` per evaluation. ``limits[point]`` optionally caps
    the total number of fires. Per-point evaluation and fire counters
    (``seen``/``fired``) make test assertions and smoke-run reports
    exact.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Mapping[str, float]] = None,
                 schedule: Optional[Mapping[str, Iterable[int]]] = None,
                 limits: Optional[Mapping[str, int]] = None,
                 delay_s: float = 0.002):
        for m in (rates, schedule, limits):
            for point in (m or ()):
                if point not in POINTS:
                    raise ValueError(f"unknown injection point {point!r}; "
                                     f"catalog: {POINTS}")
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.rates = dict(rates or {})
        self.schedule = {k: frozenset(int(i) for i in v)
                         for k, v in (schedule or {}).items()}
        self.limits = dict(limits or {})
        self.delay_s = float(delay_s)
        self.seen: dict = {}      # point -> fire() evaluations
        self.fired: dict = {}     # point -> times it actually fired

    def fire(self, point: str) -> bool:
        """Should ``point`` fault at this evaluation? Counts either way."""
        n = self.seen.get(point, 0)
        self.seen[point] = n + 1
        if point in self.schedule:
            hit = n in self.schedule[point]
        elif point in self.rates:
            hit = float(self.rng.random()) < self.rates[point]
        else:
            hit = False
        if hit and self.fired.get(point, 0) >= self.limits.get(point, 1 << 62):
            hit = False
        if hit:
            self.fired[point] = self.fired.get(point, 0) + 1
        return hit

    def choose(self, seq: Sequence):
        """Deterministically pick a victim (e.g. which slot to poison)."""
        return seq[int(self.rng.integers(len(seq)))]

    def stats(self) -> dict:
        return {"seed": self.seed,
                "fired": dict(self.fired),
                "seen": dict(self.seen)}

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None,
                 **kw) -> "FaultInjector":
        """Injector seeded from ``REPRO_FAULT_SEED`` (default 0)."""
        env = os.environ if env is None else env
        return cls(seed=int(env.get(FAULT_SEED_ENV, "0") or "0"), **kw)


def default_chaos_rates() -> dict:
    """The smoke/benchmark chaos mix: every catalog point enabled at a
    rate a short run will actually fire, low enough that the workload
    still completes (step errors requeue whole pools, so they stay
    rarest)."""
    return {
        "admit.out_of_blocks": 0.10,
        "alloc.out_of_blocks": 0.02,
        "decode.step_error": 0.03,
        "decode.poison": 0.05,
        "chunk.delay": 0.10,
        "prefix.corrupt": 0.05,
    }
