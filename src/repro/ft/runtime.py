"""Fault-tolerance runtime: preemption handling, straggler detection,
restart supervision. Designed for 1000+-node fleets where per-step failures
are routine; everything here is host-side and cheap.

Components
----------
PreemptionGuard
    Installs SIGTERM/SIGINT handlers (the signals TPU preemptions deliver)
    and exposes ``should_stop``; the train loop checks it once per step and
    takes a final synchronous checkpoint before exiting cleanly.

StragglerDetector
    Tracks a rolling window of per-step wall times; flags steps slower than
    ``threshold``× the rolling median. On a real fleet the flagged host ids
    feed the scheduler's replace/restart policy; here the detector powers
    tests and logs. (At the collective level, stragglers are mitigated
    structurally: fixed-shape steps + XLA's latency-hiding scheduler; at
    the fleet level, detection->replacement is the standard mitigation.)

run_supervised
    In-process restart supervisor: runs a step function, catches crashes,
    restores the latest checkpoint and resumes — the single-process model
    of a cluster controller's restart-from-checkpoint loop. Used by tests
    to prove checkpoint/restart correctness (bitwise-identical resume).
"""

from __future__ import annotations

import collections
import signal
import statistics
import time
from typing import Callable


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:          # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def trigger(self):                  # tests / manual drain
        self._stop = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerDetector:
    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = collections.deque(maxlen=window)
        self.threshold = threshold
        self.flagged: list[tuple[int, float, float]] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.window) >= 5:
            med = statistics.median(self.window)
            if seconds > self.threshold * med:
                self.flagged.append((step, seconds, med))
                is_straggler = True
        self.window.append(seconds)
        return is_straggler

    @property
    def median(self) -> float:
        return statistics.median(self.window) if self.window else 0.0


def run_supervised(make_state: Callable, step_fn: Callable,
                   save_fn: Callable, restore_fn: Callable,
                   n_steps: int, *, max_restarts: int = 3,
                   ckpt_every: int = 10):
    """Crash-tolerant driver. step_fn(state, step) -> state (may raise);
    save_fn(state, step); restore_fn() -> (state, step) or None.

    Returns (final_state, restarts_used).
    """
    restarts = 0
    restored = restore_fn()
    state, start = restored if restored else (make_state(), 0)
    step = start
    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            if step % ckpt_every == 0:
                save_fn(state, step)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            restored = restore_fn()
            state, step = restored if restored else (make_state(), 0)
    return state, restarts
