from .inject import (FAULT_SEED_ENV, FaultInjector, InjectedFault, POINTS,
                     default_chaos_rates)
from .runtime import PreemptionGuard, StragglerDetector, run_supervised
