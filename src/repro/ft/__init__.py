from .runtime import PreemptionGuard, StragglerDetector, run_supervised
