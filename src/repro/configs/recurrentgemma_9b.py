"""recurrentgemma-9b [hybrid]: 38L d=4096 16H MQA(kv=1) ff=12288 V=256000.

RG-LRU + local attention, pattern (rec, rec, attn) => attn_period=3,
window 2048. Sub-quadratic => long_500k RUNS. [arXiv:2402.19427; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    attn_period=3, lru_width=4096, sliding_window=2048,
    act="gelu", rope_pct=0.5, logit_softcap=30.0,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2402.19427",
)
