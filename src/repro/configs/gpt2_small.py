"""gpt2-small — the paper's own evaluation model (GPT-2 Small, head dim 64).

Used by the FlashAttention-2 and end-to-end benchmarks to mirror the
paper's GPT-2 configuration (12L, d=768, 12H, MHA).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gpt2-small", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=50257, head_dim=64,
    act="gelu", norm="layernorm", use_bias=True, tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="paper (GPT-2 small)",
)
