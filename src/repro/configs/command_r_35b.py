"""command-r-35b [dense]: 40L d=8192 64H GQA(kv=8) ff=22528 V=256000.

GQA, no-bias, parallel attention+FFN blocks, non-tied large vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
long_500k skipped: pure full attention (quadratic) — see DESIGN.md §4.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000, head_dim=128,
    parallel_block=True, use_bias=False, norm="layernorm", act="swiglu",
    rope_theta=8_000_000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic); "
                             "sub-quadratic required for 500k decode"},
    source="hf:CohereForAI/c4ai-command-r-v01",
)
