"""internvl2-1b [vlm]: 24L d=896 14H GQA(kv=2) ff=4864 V=151655.

InternViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (256 tokens, 1024-dim) projected into the LM. The backbone is
the InternLM2/Qwen2-style LM given above. [arXiv:2404.16821; hf]
long_500k skipped: pure full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, head_dim=64,
    act="swiglu", rope_theta=1_000_000.0,
    n_vision_tokens=256, vision_embed_dim=1024,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="arXiv:2404.16821",
)
