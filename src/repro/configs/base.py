"""Model configuration schema + the assigned input-shape sets.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<arch_id>.py``; all register into ``configs.REGISTRY``.
Every config provides ``reduced()`` — a tiny same-family variant used by the
CPU smoke tests (full configs are exercised only via the dry-run).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# The assigned LM-family shape set (seq_len, global_batch, kind).
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    causal: bool = True             # False for encoder-only (hubert)
    rope_theta: float = 10000.0
    rope_pct: float = 1.0           # fraction of head_dim rotated (stablelm)
    sliding_window: Optional[int] = None
    parallel_block: bool = False    # command-r style parallel attn+FFN
    use_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # hybrid (recurrentgemma / griffin)
    attn_period: int = 0            # 1 attention layer per `attn_period`
    lru_width: int = 0
    conv_width: int = 4
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # modality stubs
    n_vision_tokens: int = 0        # vlm: precomputed patch embeddings
    vision_embed_dim: int = 0
    frame_input_dim: int = 0        # audio: precomputed frame features
    # numerics / execution — resolved into a runtime.ExecPolicy (see
    # exec_policy()); env vars REPRO_* and per-call overrides take
    # precedence over these fields.
    exp_impl: str = "vexp"          # the paper's knob: vexp | exact | vexp_hw
    attention_impl: str = "flash"   # flash | xla | pallas
    kernel_backend: str = ""        # pallas | reference | xla; "" -> derive
                                    # from attention_impl
    attn_block_q: int = 0           # Pallas FA query tile; 0 -> policy default
    autotune_blocks: bool = False   # time candidate block sizes per shape
    # perf knobs (EXPERIMENTS.md §Perf): matmul input dtype for attention
    # score/PV and decode cache reads ("bf16" = MXU-native inputs with f32
    # accumulation; "f32" = conservative upcast-everything baseline), and
    # the FlashAttention KV block size (acc rescale traffic ~ Sk/block).
    attn_mm_dtype: str = "f32"
    attn_block_k: int = 512
    logits_mm_dtype: str = "f32"    # serving logits matmul input dtype
    # decode KV-cache layout: "bshd" (seq-major, baseline) or "bhsd"
    # (head-major: no transpose before the decode einsums, and the head
    # dim shards over `model` when n_kv_heads divides it) — §Perf iter C3.
    kv_cache_layout: str = "bshd"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512           # chunked cross-entropy seq chunk
    # dry-run cost accounting: unroll every internal scan so XLA's
    # HloCostAnalysis (which counts while bodies once) sees the full work.
    unroll_scans: bool = False
    # which assigned shapes apply (others recorded as skipped + why)
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: dict = field(default_factory=dict)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to 256 so the vocab dim shards
        evenly on any mesh axis (standard large-scale practice). Logits in
        the padded range are masked to -inf at the serving boundary."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def n_params(self) -> float:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, h, hkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * hkv) + h * hd * d
        if self.family == "ssm":
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
            ng = self.ssm_ngroups
            per = d * (2 * di + 2 * ng * ds + nh) + di * d + di + nh * 2
            return self.n_layers * per + 2 * v * d
        n_mats = 3 if self.act == "swiglu" else 2
        if self.family == "moe":
            ffn = n_mats * d * f * self.n_experts + d * self.n_experts
        else:
            ffn = n_mats * d * f
        per = attn + ffn
        if self.family == "hybrid":
            # attn only on every attn_period-th layer; others RG-LRU
            w = self.lru_width or d
            rec = d * w * 2 + w * d + 3 * w + w * self.conv_width + 3 * d * f
            n_attn = self.n_layers // max(self.attn_period, 1)
            n_rec = self.n_layers - n_attn
            return n_attn * per + n_rec * rec + 2 * v * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per + emb

    def n_params_active(self) -> float:
        """Active params per token (MoE counts top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.act == "swiglu" else 2
        dense_ffn = n_mats * d * f * (self.n_experts - self.top_k)
        return self.n_params() - self.n_layers * dense_ffn

    def n_params_matmul(self) -> float:
        """Active params that participate in matmuls (excludes the
        embedding lookup table — gathers contribute no FLOPs)."""
        return self.n_params_active() - self.vocab * self.d_model

    def exec_policy(self, **overrides) -> "ExecPolicy":
        """The effective execution policy for this config.

        Precedence: ``overrides`` > ``REPRO_*`` env vars > config fields
        (exp_impl / attention_impl / kernel_backend / attn_block_*) >
        library defaults. The result is hashable and is what the kernels'
        ops wrappers take as their static jit argument.
        """
        from repro.runtime.policy import resolve_policy
        return resolve_policy(self, **overrides)

    def with_policy(self, policy) -> "ModelConfig":
        """Project an ExecPolicy back onto the config's execution fields.

        Model families that read ``cfg.exp_impl`` / ``cfg.attention_impl``
        directly (ssm, hybrid, moe) follow the policy through this
        projection — the api layer applies it at entry, so every family
        honors one policy object without per-function threading.
        """
        from repro.runtime.policy import KERNEL_BACKEND_TO_ATTN_IMPL
        impl = KERNEL_BACKEND_TO_ATTN_IMPL[policy.kernel_backend]
        return replace(self, exp_impl=policy.exp_backend,
                       attention_impl=impl,
                       kernel_backend=policy.kernel_backend,
                       attn_block_k=policy.block_k,
                       attn_block_q=policy.block_q,
                       autotune_blocks=policy.autotune)

    def optimized(self) -> "ModelConfig":
        """The beyond-paper perf configuration (EXPERIMENTS.md §Perf):
        bf16 matmul inputs with f32 accumulation, larger FA KV blocks,
        head-major decode cache. The paper-faithful baseline is the
        default construction."""
        return replace(self, attn_mm_dtype="bf16", attn_block_k=2048,
                       logits_mm_dtype="bf16", kv_cache_layout="bhsd")

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, min(self.n_layers, 2) if self.attn_period == 0
                         else self.attn_period + 1),  # +1 => tail covered
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads
            else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            lru_width=128 if self.lru_width else 0,
            sliding_window=16 if self.sliding_window else None,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_state=min(self.ssm_state, 32),
            ssm_chunk=16,
            n_vision_tokens=min(self.n_vision_tokens, 8),
            vision_embed_dim=min(self.vision_embed_dim, 64),
            frame_input_dim=min(self.frame_input_dim, 64),
            loss_chunk=64,
            remat=False,
        )
