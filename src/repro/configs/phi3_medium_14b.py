"""phi3-medium-14b [dense]: 40L d=5120 40H GQA(kv=10) ff=17920 V=100352.

RoPE + SwiGLU + GQA. [arXiv:2404.14219; unverified]
long_500k skipped: pure full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352, head_dim=128,
    act="swiglu", rope_theta=10000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="arXiv:2404.14219",
)
