"""grok-1-314b [moe]: 64L d=6144 48H GQA(kv=8) ff=32768 V=131072, 8e top-2.

8 experts / top-2. E=8 does not divide the model axis (16), so the sharding
rules use TP-inside-expert (d_ff 32768/16) instead of pure EP.
[hf:xai-org/grok-1; unverified]. long_500k skipped: full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128,
    n_experts=8, top_k=2, act="gelu", logit_softcap=30.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="hf:xai-org/grok-1",
)
