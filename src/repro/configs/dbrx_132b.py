"""dbrx-132b [moe]: 40L d=6144 48H GQA(kv=8) ff=10752 V=100352, 16e top-4.

Fine-grained MoE: 16 experts / top-4 — E=16 divides the model axis exactly,
so expert parallelism is the natural sharding. [hf:databricks/dbrx-base;
unverified]. long_500k skipped: full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    n_experts=16, top_k=4, act="swiglu",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="hf:databricks/dbrx-base",
)
