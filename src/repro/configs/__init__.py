"""Config registry: one module per assigned architecture (+ the paper's)."""

from .base import ModelConfig, InputShape, SHAPES

from . import (command_r_35b, h2o_danube3_4b, phi3_medium_14b, stablelm_3b,
               grok1_314b, dbrx_132b, recurrentgemma_9b, internvl2_1b,
               mamba2_1_3b, hubert_xlarge, gpt2_small)

REGISTRY = {m.CONFIG.arch_id: m.CONFIG for m in (
    command_r_35b, h2o_danube3_4b, phi3_medium_14b, stablelm_3b,
    grok1_314b, dbrx_132b, recurrentgemma_9b, internvl2_1b,
    mamba2_1_3b, hubert_xlarge, gpt2_small)}

ASSIGNED = [a for a in REGISTRY if a != "gpt2-small"]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; one of {list(REGISTRY)}")
