"""stablelm-3b [dense]: 32L d=2560 32H GQA(kv=32=MHA) ff=6912 V=50304.

Partial rotary (25%) per the stablelm family. [hf:stabilityai/stablelm-2;
unverified]. long_500k skipped: pure full attention.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, head_dim=80,
    rope_pct=0.25, act="swiglu", norm="layernorm", use_bias=False,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full-attention arch (quadratic)"},
    source="hf:stabilityai/stablelm-2-1_6b",
)
