"""mamba2-1.3b [ssm]: 48L d=2048 attn-free, state=128 (SSD). V=50280.

State-space duality; expand=2 => d_inner 4096, headdim 64 => 64 heads.
Attention-free => softmax kernel inapplicable (DESIGN.md §4) but the SSD
decays/softplus/silu all use vexp. Sub-quadratic => long_500k RUNS.
[arXiv:2405.21060; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2405.21060",
)
