"""hubert-xlarge [audio]: 48L d=1280 16H MHA ff=5120 V=504 classes.

Encoder-only (bidirectional); conv feature extractor is a STUB —
input_specs() provides precomputed 512-dim frame features, projected to
d_model with learned positions. No decode step => decode_32k / long_500k
skipped. [arXiv:2106.07447; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    causal=False, rope_pct=0.0, act="gelu", norm="layernorm", use_bias=True,
    frame_input_dim=512,
    shapes=("train_4k", "prefill_32k"),
    skip_notes={"decode_32k": "encoder-only arch: no autoregressive decode",
                "long_500k": "encoder-only arch: no decode shapes"},
    source="arXiv:2106.07447",
)
