"""h2o-danube-3-4b [dense]: 24L d=3840 32H GQA(kv=8) ff=10240 V=32000.

llama+mistral mix with sliding-window attention. [arXiv:2401.16818;
unverified]. SWA => O(window) decode, so long_500k RUNS for this arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, head_dim=120,
    sliding_window=4096, act="swiglu", rope_theta=10000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2401.16818",
)
