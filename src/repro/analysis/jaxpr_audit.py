"""Layer 2: audits over *lowered programs* (imports JAX; runs in pytest).

Where the AST layer reads source, this layer reads what XLA will
actually execute. Three audits, each a report function plus an assert
wrapper that raises a typed ``AssertionError`` subclass:

* **collectives** — count and kinds of StableHLO collective ops in the
  lowered program. The serving contract (PR-4) is a hard budget: the
  packed sharded decode step is exactly ONE ``all_gather`` per layer,
  and unsharded programs are collective-free.
* **donation** — every ``donate_argnums`` buffer must actually be
  consumed (aliased to an output) by the lowered program. XLA only
  *warns* on an unconsumed donation at execution time; a dtype drift in
  the carry silently turns donation off and doubles decode-state memory
  (the PR-5 bf16 conv-state bug). Consumed donations show up as
  ``tf.aliasing_output`` attributes on ``@main`` parameters.
* **carry stability** — the decode carry pytree (state, positions) must
  come out of the step with the same treedef, dtypes, shapes (and
  shardings, when present) it went in with. Checked abstractly via
  ``jax.eval_shape``, so no device execution is needed.
* **output shardings** — a designated output of the COMPILED program
  must carry exactly an expected sharding pytree. The serving contract
  (PR-8): the sharded chunk-prefill program's cache output carries the
  pool sharding, so admitted rows are produced in place on the mesh and
  the engine never re-places them with a post-prefill ``device_put``.
  This one compiles (``eval_shape`` does not expose output shardings) —
  cheap at test shapes, and the jit cache makes it free on a program
  the engine already built.

All three accept either a jitted callable plus example/abstract args, an
already-``.lower()``-ed object, or (for the text-based audits) the
StableHLO text itself — keeping them cheap to aim at any program the
engine builds.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp  # noqa: F401  (callers pass jnp dtypes through us)

# StableHLO collective op names as they appear in lowered text. Matched
# with a trailing delimiter so e.g. `all_gather` never counts
# `all_gather_something`.
COLLECTIVE_KINDS = (
    "all_gather",
    "all_reduce",
    "all_to_all",
    "collective_permute",
    "collective_broadcast",
    "reduce_scatter",
)

_COLLECTIVE_RE = re.compile(
    r'"?stablehlo\.(' + "|".join(COLLECTIVE_KINDS) + r')"?[\s("]')


class AuditError(AssertionError):
    """Base for audit failures (AssertionError so pytest renders it)."""


class CollectiveBudgetError(AuditError):
    pass


class DonationError(AuditError):
    pass


class CarryStabilityError(AuditError):
    pass


class OutputShardingError(AuditError):
    pass


def lowered_text(target, *args, **kwargs) -> str:
    """StableHLO text for ``target``.

    ``target`` may be: the text itself (str), a ``Lowered`` object, or a
    callable — jitted callables are ``.lower(*args)``-ed directly, plain
    callables are wrapped in ``jax.jit`` first (fine for inspection; the
    wrapper is never executed)."""
    if isinstance(target, str):
        return target
    if hasattr(target, "as_text"):
        return target.as_text()
    if hasattr(target, "lower"):
        return target.lower(*args, **kwargs).as_text()
    return jax.jit(target).lower(*args, **kwargs).as_text()


# ------------------------------------------------------------- collectives

def collective_counts(target, *args, **kwargs) -> dict:
    """``{kind: count}`` over every collective in the lowered program
    (kinds with zero occurrences are omitted)."""
    text = lowered_text(target, *args, **kwargs)
    counts: dict = {}
    for m in _COLLECTIVE_RE.finditer(text):
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


def assert_collective_budget(target, budget: dict, *args, **kwargs):
    """Assert the program's collectives are EXACTLY ``budget``
    (``{kind: count}``); kinds absent from the budget must not appear at
    all. ``budget={}`` asserts a collective-free program."""
    got = collective_counts(target, *args, **kwargs)
    want = {k: v for k, v in budget.items() if v}
    if got != want:
        raise CollectiveBudgetError(
            f"collective budget violated: program has {got or 'none'}, "
            f"budget allows {want or 'none'} — the serving contract is "
            f"a hard per-layer collective count, any drift is a perf "
            f"regression")
    return got


# ---------------------------------------------------------------- donation

_MAIN_SIG_RE = re.compile(r"func\.func\s+public\s+@main\((.*?)\)\s*->",
                          re.DOTALL)
# Two lowerings of a consumed donation: plain jit pairs the donated
# input to its output at trace time (``tf.aliasing_output = N``);
# shard_map programs defer the pairing to XLA and mark the param
# ``jax.buffer_donor = true`` instead. A dropped donation (the PR-5
# dtype drift) loses the attribute in the plain-jit case, which is
# where the engine's unsharded programs live — the strong check.
_ALIAS_ATTRS = ("tf.aliasing_output", "jax.buffer_donor")


@dataclass
class DonationReport:
    donated_leaves: int            # array leaves in donated arg positions
    aliased_params: int            # @main params carrying aliasing_output

    @property
    def fully_consumed(self) -> bool:
        return self.aliased_params >= self.donated_leaves


def donation_report(target, donate_argnums, *args, **kwargs):
    """How many donated buffers the lowered program actually consumes.

    ``target`` must be the jitted-with-donation callable (or its
    ``Lowered``/text); ``donate_argnums`` re-states the donated arg
    positions so the expected leaf count can be derived from ``args``.
    When ``target`` is pre-lowered text, pass the expected leaf count
    directly as ``donate_argnums`` (int)."""
    if isinstance(donate_argnums, int):
        expected = donate_argnums
    else:
        expected = 0
        for i in donate_argnums:
            expected += len(jax.tree_util.tree_leaves(args[i]))
    text = lowered_text(target, *args, **kwargs)
    m = _MAIN_SIG_RE.search(text)
    aliased = sum(m.group(1).count(a) for a in _ALIAS_ATTRS) if m else 0
    return DonationReport(donated_leaves=expected, aliased_params=aliased)


def assert_all_donated(target, donate_argnums, *args, **kwargs):
    rep = donation_report(target, donate_argnums, *args, **kwargs)
    if not rep.fully_consumed:
        raise DonationError(
            f"donation not consumed: {rep.donated_leaves} donated "
            f"buffer leaves but only {rep.aliased_params} aliased "
            f"outputs in the lowered program — an unconsumed donation "
            f"silently doubles decode-state memory (the PR-5 dtype-"
            f"drift class)")
    return rep


# ---------------------------------------------------------- carry stability

def _leaf_desc(leaf):
    shape = tuple(getattr(leaf, "shape", ()))
    dtype = getattr(leaf, "dtype", None)
    sharding = getattr(leaf, "sharding", None)
    return shape, dtype, sharding


def _path_str(path) -> str:
    return jax.tree_util.keystr(path) or "<root>"


def carry_mismatches(carry_in, carry_out) -> list:
    """Human-readable mismatch list between two carry pytrees. Empty
    means the carry is stable (same treedef; every leaf keeps shape and
    dtype; shardings compared when both sides expose one)."""
    in_leaves, in_def = jax.tree_util.tree_flatten_with_path(carry_in)
    out_leaves, out_def = jax.tree_util.tree_flatten_with_path(carry_out)
    if in_def != out_def:
        return [f"carry treedef changed across the step: "
                f"{in_def} -> {out_def}"]
    out = []
    for (path, a), (_, b) in zip(in_leaves, out_leaves):
        (sa, da, ha), (sb, db, hb) = _leaf_desc(a), _leaf_desc(b)
        where = _path_str(path)
        if da != db:
            out.append(f"{where}: dtype {da} -> {db} (dtype drift "
                       f"defeats donation — the PR-5 bug class)")
        if sa != sb:
            out.append(f"{where}: shape {sa} -> {sb}")
        if ha is not None and hb is not None and ha != hb:
            out.append(f"{where}: sharding {ha} -> {hb}")
    return out


def carry_report(fn, args, carry_map: dict, kwargs=None) -> list:
    """Audit a step function's carry abstractly.

    ``carry_map`` maps input arg position -> output tuple index for each
    carried value (e.g. ``{2: 1, 3: 2}`` for
    ``decode_fn(params, tok, cache, pos, live) -> (logits, cache,
    pos')``). Runs under ``jax.eval_shape`` — abstract, no FLOPs, and
    donation on the jitted ``fn`` is ignored so the same program object
    the engine runs can be audited directly."""
    outs = jax.eval_shape(fn, *args, **(kwargs or {}))
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    msgs = []
    for argnum, outidx in sorted(carry_map.items()):
        for m in carry_mismatches(args[argnum], outs[outidx]):
            msgs.append(f"carry arg {argnum} -> out {outidx}: {m}")
    return msgs


def assert_carry_stable(fn, args, carry_map: dict, kwargs=None):
    msgs = carry_report(fn, args, carry_map, kwargs=kwargs)
    if msgs:
        raise CarryStabilityError(
            "decode carry is not stable across the step:\n  "
            + "\n  ".join(msgs))


# --------------------------------------------------------- output shardings

def output_shardings(target, *args, **kwargs):
    """Per-output sharding pytree of the COMPILED program.

    ``target`` may be a ``Compiled`` object, a ``Lowered`` object, a
    jitted callable, or a plain callable (wrapped in ``jax.jit``).
    Callables/Lowereds are compiled here — this audit genuinely needs
    the compiler's placement decision, which neither the jaxpr nor
    ``eval_shape`` exposes."""
    if hasattr(target, "output_shardings"):            # Compiled
        return target.output_shardings
    if hasattr(target, "lower"):                       # jitted callable
        target = target.lower(*args, **kwargs)
    elif not hasattr(target, "compile"):               # plain callable
        target = jax.jit(target).lower(*args, **kwargs)
    return target.compile().output_shardings


def output_sharding_report(fn, out_index, want, *args, **kwargs) -> list:
    """Mismatches between output ``out_index``'s compiled shardings and
    the expected sharding pytree ``want`` (same treedef as that output;
    pass ``out_index=None`` to compare the whole output tuple). Leaves
    compare via ``Sharding.is_equivalent_to`` at each output's rank —
    placement-equal shardings match even when spelled differently.
    Empty list == contract holds."""
    got = output_shardings(fn, *args, **kwargs)
    outs = jax.eval_shape(fn, *args, **kwargs)
    if out_index is not None:
        got, outs = got[out_index], outs[out_index]
    g_leaves, g_def = jax.tree_util.tree_flatten_with_path(got)
    w_leaves, w_def = jax.tree_util.tree_flatten(want)
    o_leaves = jax.tree_util.tree_leaves(outs)
    if g_def != w_def:
        return [f"output treedef differs from the expected sharding "
                f"tree: {g_def} != {w_def}"]
    msgs = []
    for (path, g), w, o in zip(g_leaves, w_leaves, o_leaves):
        same = (g.is_equivalent_to(w, o.ndim)
                if hasattr(g, "is_equivalent_to") else g == w)
        if not same:
            msgs.append(f"{_path_str(path)}: compiled output sharding "
                        f"{g} != expected {w}")
    return msgs


def assert_output_sharding(fn, out_index, want, *args, **kwargs):
    msgs = output_sharding_report(fn, out_index, want, *args, **kwargs)
    if msgs:
        raise OutputShardingError(
            "program output does not carry the expected sharding (rows "
            "would need a re-placement device_put — the copy this "
            "contract exists to forbid):\n  " + "\n  ".join(msgs))
    return output_shardings(fn, *args, **kwargs)
