"""AST lint rules over ``src/repro/**`` (Layer 1 of the analyzer).

Pure stdlib: the analyzed modules are never imported, so the rules run
in a bare CI job (and on fixture files with planted violations that
would not even import). Each rule is a class with a stable kebab-case
``name`` and a ``check(SourceModule) -> [Finding]``; applicability is
path-suffix based, with constructor overrides so the test suite can aim
a rule at fixture files.

The rule catalog (severities in parentheses):

``host-sync-in-hot-path``
    ``.item()``/``.tolist()``, ``jax.device_get``, ``block_until_ready``
    (either form), ``jax.device_put``, ``np.asarray``/``np.array`` on a
    non-literal (error); bare ``int()``/``float()``/``bool()`` on a
    non-constant (warn — the argument may be a host scalar) — inside
    functions marked ``@hot_path`` or registered in
    ``registry.HOT_PATH_FUNCTIONS``. Inside jitted closures these are
    trace-time bugs; in the engine loop they are per-token host syncs.

``refcount-pairing``
    Raw mutation of ``.refs`` storage outside the refcount primitives
    (error — the PR-6 ``cow()`` leak: a raw decrement skipped the
    free-list return), and allocation/incref loops with no
    release-on-exception guard (error — a mid-loop raise strands every
    reference already taken).

``jit-retrace-hazard``
    Mutable default argument on a jitted function (error — each call
    with the default re-traces or, worse, silently shares state across
    traces), and ``functools.lru_cache`` over a function whose
    parameters flow into array ops (warn — array-keyed memoization
    either crashes on unhashable inputs or pins device buffers alive).

``engine-family-branch``
    ``launch/serve.py`` must stay family-agnostic: any ``*.family``
    attribute access or ``NotImplemented``/``NotImplementedError``
    escape hatch in the engine is an error (PR-5 contract).

``silent-fallback``
    ``decode_attention_policy`` must route every configuration to the
    fused kernel — no branch on layout/window/cache_len, no call into
    the reference reduction (PR-3 contract); core ``decode_attention``'s
    pallas gate must not test layout or window either.
"""

from __future__ import annotations

import ast
import fnmatch
import os
from dataclasses import dataclass, field

from . import registry
from .findings import Finding, Severity


def canon_path(path: str) -> str:
    """Stable path identity for baselines: posix separators, stripped to
    the ``repro/``-rooted suffix when one exists (the same file must
    match whether the analyzer was invoked as ``src/repro``, ``.`` or an
    absolute path)."""
    p = path.replace(os.sep, "/")
    marker = "/repro/"
    i = p.find(marker)
    if i >= 0:
        return p[i + 1:]
    if p.startswith("repro/"):
        return p
    return p.lstrip("./")


def _dotted(node) -> str | None:
    """'jax.numpy.asarray' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_LITERALS = (ast.List, ast.Tuple, ast.Dict, ast.Set, ast.ListComp,
             ast.DictComp, ast.SetComp, ast.GeneratorExp, ast.Constant)


@dataclass
class SourceModule:
    """One parsed file + the derived maps every rule needs."""

    path: str
    text: str
    tree: ast.AST
    parents: dict = field(default_factory=dict)
    # function node -> dotted qualname ("Class.method", "outer.inner")
    qualnames: dict = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str) -> "SourceModule":
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        tree = ast.parse(text, filename=path)
        mod = cls(path=path, text=text, tree=tree)
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                mod.parents[child] = parent
        def visit(node, stack):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = stack + [child.name]
                    mod.qualnames[child] = ".".join(q)
                    visit(child, q)
                elif isinstance(child, ast.ClassDef):
                    visit(child, stack + [child.name])
                else:
                    visit(child, stack)
        visit(tree, [])
        return mod

    @property
    def canon(self) -> str:
        return canon_path(self.path)

    def functions(self):
        """(node, qualname) for every (async) function def."""
        return self.qualnames.items()

    def enclosing_function(self, node):
        """Qualname of the innermost function containing ``node`` ('' at
        module level)."""
        cur = node
        while cur is not None:
            if cur in self.qualnames:
                return self.qualnames[cur]
            cur = self.parents.get(cur)
        return ""

    def ancestors(self, node):
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)


def _walk_in_function(fn_node):
    """Walk a function's own code: descends everything except nested
    function/class defs (those are audited under their own qualname)."""
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _contains(root, node) -> bool:
    for n in ast.walk(root):
        if n is node:
            return True
    return False


class Rule:
    name = "rule"

    def applies(self, mod: SourceModule) -> bool:
        return True

    def check(self, mod: SourceModule):
        raise NotImplementedError      # noqa — abstract, not an escape hatch


def _suffix_match(path: str, suffixes) -> bool:
    c = canon_path(path)
    return any(c.endswith(canon_path(s)) for s in suffixes)


# --------------------------------------------------------------- host sync

_SYNC_METHODS = {"item": ".item()", "tolist": ".tolist()",
                 "block_until_ready": ".block_until_ready()"}
_SYNC_DOTTED = {
    "jax.block_until_ready": "device sync",
    "jax.device_get": "device->host transfer",
    "jax.device_put": "host->device transfer",
}
_NP_ROOTS = ("np", "numpy")
_SCALARIZERS = ("int", "float", "bool")


class HostSyncRule(Rule):
    """Host syncs/transfers inside registered hot-path functions."""

    name = "host-sync-in-hot-path"

    def __init__(self, extra_functions=None):
        # extra (path suffix -> qualname globs) on top of the registry —
        # the fixture tests register their planted modules here.
        self.extra_functions = dict(extra_functions or {})

    def _registered_globs(self, mod):
        globs = []
        for table in (registry.HOT_PATH_FUNCTIONS, self.extra_functions):
            for suffix, pats in table.items():
                if _suffix_match(mod.path, (suffix,)):
                    globs.extend(pats)
        return globs

    def _hot_functions(self, mod):
        globs = self._registered_globs(mod)
        hot = set()
        for node, qual in mod.functions():
            marked = any(
                (_dotted(d) or "").split(".")[-1] == "hot_path"
                for d in node.decorator_list)
            if marked or any(fnmatch.fnmatch(qual, g) for g in globs):
                hot.add(node)
        # nested defs of a hot function are hot too (jitted closures)
        for node, qual in mod.functions():
            if node in hot:
                continue
            if any(a in hot for a in mod.ancestors(node)):
                hot.add(node)
        return hot

    def check(self, mod):
        out = []
        for fn in self._hot_functions(mod):
            qual = mod.qualnames[fn]
            for node in _walk_in_function(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = self._classify(node)
                if f is None:
                    continue
                detail, sev, msg = f
                out.append(Finding(
                    rule=self.name, severity=sev, path=mod.path,
                    line=node.lineno, symbol=qual, detail=detail,
                    message=msg))
        return out

    @staticmethod
    def _classify(call):
        func = call.func
        if isinstance(func, ast.Attribute):
            dotted = _dotted(func)
            if dotted in _SYNC_DOTTED:
                return (dotted, Severity.ERROR,
                        f"{dotted}(...) is a {_SYNC_DOTTED[dotted]} — "
                        f"hot-path steps must stay async on device")
            root = dotted.split(".")[0] if dotted else None
            if root in _NP_ROOTS and func.attr in ("asarray", "array"):
                arg = call.args[0] if call.args else None
                if arg is not None and not isinstance(arg, _LITERALS):
                    d = f"{root}.{func.attr}"
                    return (d, Severity.ERROR,
                            f"{d}(...) on a non-literal materializes a "
                            f"device value on the host (blocking sync)")
                return None
            if func.attr in _SYNC_METHODS and not call.args:
                d = _SYNC_METHODS[func.attr]
                return (d, Severity.ERROR,
                        f"{d} blocks on the device value — one sync per "
                        f"call in the decode hot path")
        elif isinstance(func, ast.Name) and func.id in _SCALARIZERS:
            if len(call.args) == 1 and not isinstance(call.args[0],
                                                      ast.Constant):
                return (f"{func.id}()", Severity.WARN,
                        f"{func.id}(...) scalarizes its argument — a "
                        f"blocking sync if it is a device array (host "
                        f"mirrors are fine; justify in baseline)")
        return None


# ---------------------------------------------------------------- refcount

class RefcountRule(Rule):
    """Refcount-pairing discipline in the page-pool bookkeeping."""

    name = "refcount-pairing"

    def __init__(self, targets=None, slot_targets=None):
        self.targets = tuple(targets or registry.ALLOC_MODULES)
        self.slot_targets = tuple(slot_targets
                                  or registry.SLOT_CONTRACT_FILES)

    def applies(self, mod):
        return _suffix_match(mod.path, self.targets + self.slot_targets)

    def check(self, mod):
        if not self.applies(mod):
            return []
        out = []
        if _suffix_match(mod.path, self.targets):
            for node in ast.walk(mod.tree):
                out.extend(self._raw_refs(mod, node))
                if isinstance(node, ast.Call):
                    out.extend(self._unguarded_alloc(mod, node))
        if _suffix_match(mod.path, self.slot_targets):
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    out.extend(self._unguarded_slot_reserve(mod, node))
                    out.extend(self._unguarded_spec_snapshot(mod, node))
        return out

    def _raw_refs(self, mod, node):
        targets = []
        if isinstance(node, ast.AugAssign):
            targets = [node.target]
        elif isinstance(node, ast.Assign):
            targets = node.targets
        hits = []
        for t in targets:
            refs_store = (
                isinstance(t, ast.Subscript)
                and isinstance(t.value, ast.Attribute)
                and t.value.attr == "refs")
            if not refs_store:
                continue
            qual = mod.enclosing_function(t)
            if qual.split(".")[-1] in registry.REFS_PRIMITIVES:
                continue
            hits.append(Finding(
                rule=self.name, severity=Severity.ERROR, path=mod.path,
                line=t.lineno, symbol=qual, detail="refs[...]-mutation",
                message="raw refcount mutation outside the incref/decref "
                        "primitives — a raw decrement skips the free-list "
                        "return (the PR-6 cow() leak class)"))
        return hits

    def _unguarded_alloc(self, mod, call):
        name = None
        if isinstance(call.func, ast.Attribute):
            name = call.func.attr
        elif isinstance(call.func, ast.Name):
            name = call.func.id
        if name not in registry.ALLOC_CALLS:
            return []
        qual = mod.enclosing_function(call)
        if qual.split(".")[-1] in registry.REFS_PRIMITIVES + ("alloc_cols",):
            pass  # the primitives guard internally; still checked below
        in_loop = guarded = False
        for anc in mod.ancestors(call):
            if isinstance(anc, (ast.For, ast.While)):
                in_loop = True
            if isinstance(anc, ast.Try):
                in_body = any(_contains(s, call) for s in anc.body)
                if in_body and self._releases(anc):
                    guarded = True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        if in_loop and not guarded:
            return [Finding(
                rule=self.name, severity=Severity.ERROR, path=mod.path,
                line=call.lineno, symbol=qual,
                detail=f"unguarded-{name}-loop",
                message=f"loop accumulates references via {name}(...) "
                        f"with no release-on-exception guard — a mid-loop "
                        f"raise strands every page already taken")]
        return []

    def _unguarded_slot_reserve(self, mod, call):
        """Slot-reservation pairing in the engine (PR-9).

        ``begin_chunk`` reserves a slot's pool state (pages, prefix
        refs, table row) and hands back a cursor; until the request is
        published into the engine's in-flight map, the loop body is the
        only holder. A reserve issued inside an admission loop must
        therefore have SOME try in that loop whose handlers/finally
        reach a slot release (abort_chunk/reset_slots/...) — otherwise
        one raise between reserve and publish strands the reservation,
        which is exactly the leak class the cancellation and abort
        paths can reintroduce."""
        name = None
        if isinstance(call.func, ast.Attribute):
            name = call.func.attr
        elif isinstance(call.func, ast.Name):
            name = call.func.id
        if name not in registry.SLOT_RESERVE_CALLS:
            return []
        qual = mod.enclosing_function(call)
        loop = None
        for anc in mod.ancestors(call):
            if loop is None and isinstance(anc, (ast.For, ast.While)):
                loop = anc
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        if loop is None:
            return []
        guarded = any(
            isinstance(n, ast.Try) and self._releases(
                n, names=registry.SLOT_RELEASE_CALLS)
            for n in ast.walk(loop))
        if guarded:
            return []
        return [Finding(
            rule=self.name, severity=Severity.ERROR, path=mod.path,
            line=call.lineno, symbol=qual,
            detail="unguarded-slot-reserve",
            message=f"{name}(...) reserves a slot's pages/prefix refs "
                    f"inside an admission loop with no slot release "
                    f"(abort_chunk/reset_slots) reachable on the "
                    f"exception path — one raise between reserve and "
                    f"publish strands the reservation")]

    def _unguarded_spec_snapshot(self, mod, call):
        """Speculative-burst snapshot pairing (PR-10).

        Unlike ``begin_chunk`` (loop-shaped admission), a
        ``spec_snapshot`` is a straight-line reserve: it hands back the
        burst's only rollback token, then the draft steps advance the
        donated pool positions in place. Any raise between snapshot and
        the verify that folds the rollback into the carry (an injected
        dispatch fault, a cancellation surfacing mid-burst) strands the
        pool mid-draft — so the snapshot must sit inside SOME try whose
        handlers/finally reach a rollback or recovery call
        (spec_restore / verify_step / reset_slots / recovery). Checked
        on every snapshot call, loop or not."""
        name = None
        if isinstance(call.func, ast.Attribute):
            name = call.func.attr
        elif isinstance(call.func, ast.Name):
            name = call.func.id
        if name not in registry.SPEC_SNAPSHOT_CALLS:
            return []
        qual = mod.enclosing_function(call)
        guarded = False
        for anc in mod.ancestors(call):
            if isinstance(anc, ast.Try):
                in_body = any(_contains(s, call) for s in anc.body)
                if in_body and self._releases(
                        anc, names=registry.SPEC_SNAPSHOT_RELEASES):
                    guarded = True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        if guarded:
            return []
        return [Finding(
            rule=self.name, severity=Severity.ERROR, path=mod.path,
            line=call.lineno, symbol=qual,
            detail="unguarded-spec-snapshot",
            message=f"{name}() takes the burst's rollback token with no "
                    f"rollback/recovery (spec_restore/verify_step/"
                    f"reset_slots) reachable on the exception path — a "
                    f"raise mid-burst strands the pool with draft "
                    f"positions advanced and no way back")]

    @staticmethod
    def _releases(try_node, names=None) -> bool:
        names = names if names is not None else registry.RELEASE_CALLS
        region = [s for h in try_node.handlers for s in h.body]
        region += try_node.finalbody
        for stmt in region:
            for n in ast.walk(stmt):
                if isinstance(n, ast.Call):
                    nm = (n.func.attr if isinstance(n.func, ast.Attribute)
                          else n.func.id if isinstance(n.func, ast.Name)
                          else None)
                    if nm in names:
                        return True
        return False


# ----------------------------------------------------------------- retrace

class RetraceRule(Rule):
    """jit-retrace / array-memoization hazards."""

    name = "jit-retrace-hazard"

    _MUTABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                ast.SetComp)
    _ARRAY_ROOTS = ("jnp", "np", "numpy")

    def check(self, mod):
        out = []
        jitted = self._jitted_names(mod)
        for node, qual in mod.functions():
            if self._is_jit_decorated(node) or node.name in jitted:
                out.extend(self._mutable_defaults(mod, node, qual))
            if self._is_lru_cached(node):
                out.extend(self._lru_array_args(mod, node, qual))
        # lambdas handed straight to jax.jit
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Call) and self._is_jit(node.func)
                    and node.args
                    and isinstance(node.args[0], ast.Lambda)):
                lam = node.args[0]
                for d in list(lam.args.defaults) + \
                        [d for d in lam.args.kw_defaults if d is not None]:
                    if isinstance(d, self._MUTABLE):
                        out.append(self._mutable_finding(
                            mod, d, mod.enclosing_function(node) or
                            "<lambda>"))
        return out

    @staticmethod
    def _is_jit(func_expr) -> bool:
        d = _dotted(func_expr)
        return d is not None and (d == "jit" or d.endswith(".jit"))

    def _is_jit_decorated(self, fn) -> bool:
        for dec in fn.decorator_list:
            if self._is_jit(dec):
                return True
            if isinstance(dec, ast.Call):
                if self._is_jit(dec.func):
                    return True
                d = _dotted(dec.func) or ""
                if d.split(".")[-1] == "partial" and any(
                        self._is_jit(a) for a in dec.args):
                    return True
        return False

    def _jitted_names(self, mod):
        names = set()
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Call) and self._is_jit(node.func)
                    and node.args and isinstance(node.args[0], ast.Name)):
                names.add(node.args[0].id)
        return names

    def _mutable_defaults(self, mod, fn, qual):
        out = []
        defaults = list(fn.args.defaults) + \
            [d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            if isinstance(d, self._MUTABLE):
                out.append(self._mutable_finding(mod, d, qual))
        return out

    def _mutable_finding(self, mod, node, qual):
        return Finding(
            rule=self.name, severity=Severity.ERROR, path=mod.path,
            line=node.lineno, symbol=qual, detail="mutable-default",
            message="mutable default argument on a jitted function — "
                    "unhashable as a static arg and shared across "
                    "traces; every call risks a silent retrace")

    @staticmethod
    def _is_lru_cached(fn) -> bool:
        for dec in fn.decorator_list:
            d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
            if d and d.split(".")[-1] == "lru_cache":
                return True
        return False

    # containers in array-op args are shape/axis metadata (``(n,)`` in
    # ``jnp.zeros``), not array values — skipped, as are nested calls
    # (they own their own args) and dtype constructors on config scalars.
    _SKIP_NODES = (ast.Call, ast.Tuple, ast.List, ast.Dict, ast.Set)
    _METADATA_ATTRS = ("dtype",)

    def _lru_array_args(self, mod, fn, qual):
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)}
        for node in _walk_in_function(fn):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if not d or d.split(".")[0] not in self._ARRAY_ROOTS:
                continue
            if d.split(".")[-1] in self._METADATA_ATTRS:
                continue
            stack = list(node.args) + [kw.value for kw in node.keywords]
            while stack:
                n = stack.pop()
                if isinstance(n, self._SKIP_NODES):
                    continue
                if not (isinstance(n, ast.Name) and n.id in params):
                    stack.extend(ast.iter_child_nodes(n))
                    continue
                return [Finding(
                            rule=self.name, severity=Severity.WARN,
                            path=mod.path, line=fn.lineno, symbol=qual,
                            detail="lru_cache-array-arg",
                            message=f"functools.lru_cache over {fn.name!r}"
                                    f" whose parameter {n.id!r} flows into"
                                    f" {d} — array-keyed memoization "
                                    f"crashes on unhashable inputs or "
                                    f"pins device buffers alive")]
        return []


# ---------------------------------------------------------- engine contract

class EngineContractRule(Rule):
    """serve.py stays family-branch-free (PR-5 acceptance, as AST)."""

    name = "engine-family-branch"

    def __init__(self, targets=None):
        self.targets = tuple(targets or registry.ENGINE_CONTRACT_FILES)

    def applies(self, mod):
        return _suffix_match(mod.path, self.targets)

    def check(self, mod):
        if not self.applies(mod):
            return []
        out = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == "family":
                out.append(Finding(
                    rule=self.name, severity=Severity.ERROR,
                    path=mod.path, line=node.lineno,
                    symbol=mod.enclosing_function(node), detail=".family",
                    message="family attribute access in the engine — "
                            "every family-specific decision belongs "
                            "behind the DecodeState protocol"))
            if isinstance(node, ast.Name) and node.id in (
                    "NotImplementedError", "NotImplemented"):
                out.append(Finding(
                    rule=self.name, severity=Severity.ERROR,
                    path=mod.path, line=node.lineno,
                    symbol=mod.enclosing_function(node), detail=node.id,
                    message=f"{node.id} escape hatch in the engine — the "
                            f"slot engine must serve every family it "
                            f"admits"))
        return out


# ----------------------------------------------------------- silent fallback

class FallbackContractRule(Rule):
    """Kernel-routing functions must not silently fall back (PR-3)."""

    name = "silent-fallback"

    def __init__(self, contracts=None):
        self.contracts = tuple(contracts or registry.FALLBACK_CONTRACTS)

    def applies(self, mod):
        return _suffix_match(mod.path,
                             tuple(c["path"] for c in self.contracts))

    def check(self, mod):
        out = []
        for spec in self.contracts:
            if not _suffix_match(mod.path, (spec["path"],)):
                continue
            fn = next((node for node, q in mod.functions()
                       if q.split(".")[-1] == spec["function"]), None)
            if fn is None:
                continue
            out.extend(self._check_fn(mod, fn, spec))
        return out

    def _check_fn(self, mod, fn, spec):
        out = []
        qual = mod.qualnames[fn]
        required = spec.get("require_call")
        req_call = None
        if required:
            for node in _walk_in_function(fn):
                if isinstance(node, ast.Call):
                    d = _dotted(node.func) or ""
                    if d.split(".")[-1].startswith(required) \
                            or required in d:
                        req_call = node
                        break
            if req_call is None:
                out.append(Finding(
                    rule=self.name, severity=Severity.ERROR,
                    path=mod.path, line=fn.lineno, symbol=qual,
                    detail=f"missing-{required}",
                    message=f"{qual} no longer routes through "
                            f"{required} — the fused-kernel contract "
                            f"is gone"))
                return out
        if spec.get("gate_only") and req_call is not None:
            ifs = [a for a in mod.ancestors(req_call)
                   if isinstance(a, ast.If) and _contains(fn, a)]
        else:
            ifs = [n for n in _walk_in_function(fn)
                   if isinstance(n, ast.If)]
        forbid = set(spec.get("forbid_if_names", ()))
        for node in ifs:
            names = {n.id for n in ast.walk(node.test)
                     if isinstance(n, ast.Name)}
            for bad in sorted(names & forbid):
                out.append(Finding(
                    rule=self.name, severity=Severity.ERROR,
                    path=mod.path, line=node.lineno, symbol=qual,
                    detail=f"if-{bad}",
                    message=f"{qual} branches on {bad!r} — a "
                            f"configuration-gated fallback is exactly "
                            f"the silent-reference-fallback class this "
                            f"contract forbids"))
        for node in _walk_in_function(fn):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func) or (
                node.func.attr if isinstance(node.func, ast.Attribute)
                else "")
            for sub in spec.get("forbid_call_substrings", ()):
                if d and sub in d:
                    out.append(Finding(
                        rule=self.name, severity=Severity.ERROR,
                        path=mod.path, line=node.lineno, symbol=qual,
                        detail=f"call-{sub}",
                        message=f"{qual} calls {d} — the reference "
                                f"reduction must not be reachable from "
                                f"the kernel entry point"))
        return out


# ------------------------------------------------------------------ runner

ALL_RULES = (HostSyncRule(), RefcountRule(), RetraceRule(),
             EngineContractRule(), FallbackContractRule())


def _expand(paths):
    files = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d != "__pycache__"
                                 and not d.startswith("."))
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    return files


def run_rules(paths, rules=None):
    """Run ``rules`` (default: the full catalog) over ``paths`` (files
    or directories). Returns (findings, n_files)."""
    rules = list(ALL_RULES if rules is None else rules)
    findings = []
    files = _expand(paths)
    for path in files:
        try:
            mod = SourceModule.parse(path)
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse-error", severity=Severity.ERROR, path=path,
                line=e.lineno or 0, symbol="", detail="syntax-error",
                message=f"cannot parse: {e.msg}"))
            continue
        for rule in rules:
            findings.extend(rule.check(mod))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return findings, len(files)
