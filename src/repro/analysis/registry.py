"""Hot-path registry: which code the serving contracts bind to.

Two ways into the registry, both consumed purely at the AST level (the
analyzer never imports the analyzed modules):

* the ``@hot_path`` marker decorator — zero-overhead identity, placed on
  the per-decode-step functions and the serve-loop scheduling functions.
  The AST rule recognizes the decorator *by name* (``hot_path`` /
  ``registry.hot_path``), so fixture files don't need the import to be
  analyzable;
* ``HOT_PATH_FUNCTIONS`` — qualname globs per path suffix, for functions
  that cannot carry a decorator (the jitted inner closures of the
  decode-program builders).

Marking discipline (enforced by tests, documented in README):

* **per-decode-step code** (``DecodeState.step``, ``decode_once``, the
  ``decode_fn`` closures, ``transformer.decode_step*``, the dispatch
  decode adapters) must lint CLEAN — no baseline entries allowed; a
  host sync here runs once per generated token;
* **scheduling-event code** (``admit``, ``_finish``, ``Server.stats``)
  is audited by the same rule; its per-event syncs are by design (PR-2/
  PR-6 conventions) and live in ``baseline.toml`` with justifications,
  so any NEW sync added to these functions still fails CI.

This module must stay import-light (stdlib only): model modules import
it for the marker, and the CLI runs without JAX installed.
"""

from __future__ import annotations

_HOT_ATTR = "__repro_hot_path__"


def hot_path(fn):
    """Mark ``fn`` as serving-hot-path for ``repro.analysis``.

    Identity decorator: returns ``fn`` unchanged apart from a marker
    attribute, so decorated functions keep their source (``inspect``),
    signature, and jit behavior. The host-sync lint rule matches the
    decorator syntactically; the attribute exists for runtime
    introspection and tests.
    """
    try:
        setattr(fn, _HOT_ATTR, True)
    except (AttributeError, TypeError):   # builtins/partials: marker only
        pass
    return fn


def is_hot_path(fn) -> bool:
    return bool(getattr(fn, _HOT_ATTR, False))


# Qualname globs (fnmatch) of hot-path functions that cannot carry the
# decorator, per path suffix: the jitted closures inside the decode/
# prefill program builders. Host calls inside these would either break
# tracing outright or constant-fold a host value into the compiled
# program — both are bugs the lint catches before a test has to.
HOT_PATH_FUNCTIONS = {
    "repro/models/decode_state.py": (
        "_programs.decode_fn",
        "_programs.decode_local",
        "_programs.prefill_fn",
        "_programs.prefill_plain_fn",
        "_programs.chunk_fn",
        "_paged_programs.decode_fn",
        "_paged_programs.decode_local",
        "_paged_programs.prefill_hist_fn",
        "_paged_programs.chunk_fn",
        "_spec_programs.score_fn",
        "_spec_programs.verify_fn",
        "_spec_programs._scan",
        "_spec_programs._scan.body",
    ),
}

# Per-decode-step symbols that must stay finding-free: baseline entries
# covering them are rejected by the CLI (a justified suppression is for
# scheduling-event code only — the decode step itself has no acceptable
# host work). Matched as (path suffix, qualname glob).
STEP_STRICT = (
    ("repro/launch/serve.py", "_Group.decode_once"),
    ("repro/launch/serve.py", "Server.step"),
    # the chunk-step path runs every tick a prompt is streaming — it is
    # held to the same zero-host-sync bar as the decode step (completion
    # dispatch included: TTFT is sampled at the scheduling event, never
    # at a sync)
    ("repro/launch/serve.py", "_Group.prefill_chunk_once"),
    ("repro/launch/serve.py", "_Group._chunk_done"),
    # the speculative burst runs in place of the decode step — same
    # bar: acceptance folds into the device carry, mirrors advance as
    # upper bounds, the one settling sync lives in _settle_slot (a
    # scheduling event, not here)
    ("repro/launch/serve.py", "_Group.decode_spec_once"),
    ("repro/models/decode_state.py", "_spec_programs.*"),
    ("repro/models/decode_state.py", "*step"),
    ("repro/models/decode_state.py", "*prefill_chunk_into"),
    ("repro/models/decode_state.py", "_programs.*"),
    ("repro/models/decode_state.py", "_paged_programs.*"),
    ("repro/models/transformer.py", "decode_step*"),
    ("repro/kernels/dispatch.py", "_decode*"),
)

# Modules holding refcounted-page bookkeeping: the refcount-pairing rule
# (raw .refs mutation, unguarded allocation loops) applies here. The
# ``fixtures/analysis`` entries are the analyzer's own planted-violation
# test modules (never on the ``make analyze`` path, which scans
# ``src/repro`` only) — registered here so the CLI reproduces each
# finding end to end.
ALLOC_MODULES = (
    "repro/models/block_pool.py",
    "repro/models/decode_state.py",
    "fixtures/analysis/bad_refcount.py",
    "fixtures/analysis/clean.py",
)
# Methods allowed to touch ``.refs`` storage directly — the refcount
# primitives themselves plus construction/verification.
REFS_PRIMITIVES = ("incref", "decref", "_alloc_one", "__init__", "check")
# Call names that take a page reference (allocate or incref) — a loop
# accumulating these needs a release-on-exception guard.
ALLOC_CALLS = ("_alloc_one", "alloc_cols", "incref", "attach")
# Call names that release page references (what a guard must reach).
RELEASE_CALLS = ("decref", "_evict_one", "drop_all", "release")

# Slot-reservation pairing in the serving engine (PR-9). ``begin_chunk``
# takes a slot's full pool reservation (pages, prefix refs, table row)
# and hands the engine a cursor; until the request is published into
# ``prefilling`` the engine is the only holder. A reserve call issued
# inside an admission loop therefore needs a release reachable on the
# exception path — one raise between reserve and publish strands the
# whole reservation. (``prefill_into`` is all-or-nothing inside the
# state and releases internally, so only ``begin_chunk`` is engine-side
# pairing.)
SLOT_RESERVE_CALLS = ("begin_chunk",)
SLOT_RELEASE_CALLS = ("abort_chunk", "reset_slots", "decref", "recover")

# Speculative-burst snapshot pairing (PR-10). ``spec_snapshot`` hands
# the engine the only rollback token for the burst; the draft steps and
# the donated verify program then consume the carry. A raise anywhere
# between snapshot and verify (injected dispatch fault, cancellation)
# leaves the pool positions advanced by the drafts with no way back —
# so a snapshot must sit inside a try whose exception path reaches a
# rollback/recovery call. ``verify_step`` is listed because a
# finally-block settling through verify also discharges the token.
SPEC_SNAPSHOT_CALLS = ("spec_snapshot",)
SPEC_SNAPSHOT_RELEASES = ("spec_restore", "verify_step", "reset_slots",
                          "_recover_step_fault")
SLOT_CONTRACT_FILES = (
    "repro/launch/serve.py",
    "fixtures/analysis/bad_slot_leak.py",       # planted-violation fixture
    "fixtures/analysis/bad_snapshot_leak.py",   # planted-violation fixture
)

# Engine source contracts (promoted from test source-string greps).
# serve.py: no family branch, no not-implemented escape hatch.
ENGINE_CONTRACT_FILES = (
    "repro/launch/serve.py",
    "fixtures/analysis/bad_family_branch.py",   # planted-violation fixture
)

# Kernel-routing contracts: (path suffix, function, forbidden names in
# any If test, required-call substring or None). ``decode_attention_policy``
# must not branch at all on layout/window/cache_len (PR-3: no silent
# reference fallback); core ``decode_attention``'s pallas-routing gate
# must reach the fused kernel without testing layout or window.
FALLBACK_CONTRACTS = (
    {
        "path": "repro/kernels/decode_attention/ops.py",
        "function": "decode_attention_policy",
        "forbid_if_names": ("layout", "window", "cache_len", "cl"),
        "forbid_call_substrings": ("core_decode", "_decode_fallback",
                                   "attention_xla", "attention_flash"),
        "require_call": "decode_attention",
    },
    {
        "path": "repro/core/attention.py",
        "function": "decode_attention",
        # only the If that routes to the kernel is constrained; the rule
        # finds it by the required call below.
        "forbid_if_names": ("layout", "window"),
        "forbid_call_substrings": (),
        "require_call": "decode_attention_policy",
        "gate_only": True,
    },
    {   # planted-violation fixture (tests/fixtures/analysis)
        "path": "fixtures/analysis/bad_fallback.py",
        "function": "decode_attention_policy",
        "forbid_if_names": ("layout", "window", "cache_len"),
        "forbid_call_substrings": ("core_decode",),
        "require_call": "decode_attention",
    },
)
