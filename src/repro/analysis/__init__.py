"""Hot-path contract analyzer: the repo's serving invariants as checks.

The paper's wins come from keeping the Softmax/attention hot path free of
hidden overheads; at the program level this repo depends on the same
discipline — "one collective per layer, zero host syncs, donated
buffers" — and each of those contracts has already been violated once by
an innocent-looking change (a bf16 conv-state dtype drift that silently
defeated donation; a ``cow()`` refcount leak on an eviction path). This
package turns the contracts into CI-enforced checks, in two layers:

**Layer 1 — AST lint** (stdlib-only; no JAX import, runs anywhere):
source rules over ``src/repro/**`` driven by the hot-path registry
(``registry.hot_path`` marker + config lists):

* ``host-sync-in-hot-path`` — ``.item()``, ``jax.device_get``,
  ``block_until_ready``, ``np.asarray`` (and, at warn severity,
  ``int()/float()/bool()``) inside functions marked ``@hot_path``;
* ``refcount-pairing`` — raw ``.refs`` mutation outside the
  ``incref``/``decref`` primitives and allocation loops with no
  release-on-exception guard (the PR-6 ``cow()`` leak class);
* ``jit-retrace-hazard`` — mutable default arguments on jitted
  functions, ``functools.lru_cache`` keyed on array arguments;
* ``engine-family-branch`` / ``silent-fallback`` — the prose contracts
  (serve.py family-branch-free; ``decode_attention_policy`` has no
  reference fallback; core routing never gates on layout/window)
  promoted from source-string greps to real AST rules.

Findings diff against ``baseline.toml`` (every suppression carries a
justification); ``python -m repro.analysis src/repro`` exits nonzero on
anything new. See ``cli.py`` for flags.

**Layer 2 — jaxpr/lowering audit** (``jaxpr_audit``; imports JAX, runs
under pytest): takes a jitted callable + args and reports collective
count/kinds per lowered program (the PR-4 one-collective-per-layer
budget), donation consumption (every ``donate_argnums`` buffer actually
aliased in the lowered program), and carry stability (the decode carry
pytree keeps identical dtypes/shapes/shardings across the step — the
exact PR-5 bug class).

Import note: this ``__init__`` must stay stdlib-only — model modules
import ``repro.analysis.registry`` for the ``hot_path`` marker, so any
heavyweight import here would cycle or slow every model import.
``jaxpr_audit`` is exposed lazily for the same reason.
"""

from __future__ import annotations

from .findings import Finding, Severity, format_findings  # noqa: F401
from .registry import hot_path  # noqa: F401
from .rules import ALL_RULES, run_rules  # noqa: F401

__all__ = [
    "Finding", "Severity", "format_findings", "hot_path",
    "ALL_RULES", "run_rules", "jaxpr_audit",
]


def __getattr__(name):
    if name == "jaxpr_audit":            # lazy: pulls in jax
        import importlib
        return importlib.import_module(".jaxpr_audit", __name__)
    raise AttributeError(name)
