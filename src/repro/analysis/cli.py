"""``python -m repro.analysis`` — the AST-lint layer as a CI gate.

Exit codes:

* **0** — no findings, or every finding is covered by a justified
  baseline entry (stale entries are reported but don't fail);
* **1** — at least one NEW finding (not in the baseline);
* **2** — config error: unparseable baseline, a suppression without a
  real justification, or a suppression covering step-strict code.

Stdlib-only by design: the CI job that runs this does not install JAX.
"""

from __future__ import annotations

import argparse
import json
import sys

from .baseline import (DEFAULT_BASELINE, Baseline, BaselineError,
                       load_baseline, write_baseline)
from .findings import RunResult
from .rules import ALL_RULES, run_rules


def run_analysis(paths, *, baseline_path: str | None = None,
                 use_baseline: bool = True, rules=None) -> RunResult:
    """Library entry point (the pytest wrappers call this)."""
    findings, _ = run_rules(paths, rules=rules)
    base = load_baseline(baseline_path) if use_baseline else Baseline("")
    return base.split(findings)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Hot-path contract lint over repro sources "
                    "(AST layer; the jaxpr audit layer runs under "
                    "pytest, see repro.analysis.jaxpr_audit).")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze "
                         "(e.g. src/repro)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline TOML (default: the package's "
                         "baseline.toml)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding "
                         "(exit 1 if any)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to --baseline with "
                         "placeholder reasons (placeholders still fail "
                         "validation until justified)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            doc = (r.__doc__ or "").strip().splitlines()[0]
            print(f"{r.name:<24} {doc}")
        return 0

    findings, n_files = run_rules(args.paths)

    if args.write_baseline:
        n = write_baseline(args.baseline, findings)
        print(f"wrote {n} suppression(s) to {args.baseline} — fill in "
              f"each 'reason' before this baseline will validate")
        return 0

    try:
        base = (Baseline("") if args.no_baseline
                else load_baseline(args.baseline))
    except BaselineError as e:
        print(f"repro.analysis: baseline error: {e}", file=sys.stderr)
        return 2

    res = base.split(findings)

    if args.as_json:
        print(json.dumps({
            "files": n_files,
            "new": [f.__dict__ | {"severity": str(f.severity)}
                    for f in res.new],
            "suppressed": len(res.suppressed),
            "stale": res.stale,
        }, indent=2, default=str))
    else:
        _report(res, n_files)
    return 1 if res.failed else 0


def _report(res: RunResult, n_files: int) -> None:
    for f in res.new:
        print(f.render())
    for e in res.stale:
        print(f"stale baseline entry (matched nothing — remove it): "
              f"{e['rule']} {e['path']} [{e.get('symbol', '')}] "
              f"{e['detail']}")
    verdict = "FAIL" if res.failed else "ok"
    print(f"repro.analysis: {verdict} — {len(res.findings)} finding(s) "
          f"({len(res.new)} new, {len(res.suppressed)} suppressed, "
          f"{len(res.stale)} stale) over {n_files} file(s)")


if __name__ == "__main__":   # pragma: no cover
    sys.exit(main())
