"""Baseline suppression file: load/match/write ``baseline.toml``.

Every entry suppresses exactly one finding key — ``(rule, path, symbol,
detail)``, line-insensitive — and MUST carry a non-placeholder ``reason``
string. An entry without a justification, or one covering a symbol the
registry marks step-strict (per-decode-step code has no acceptable host
work), is a *config error*: the CLI exits 2 without running to green.

Python 3.10 has no ``tomllib``, and the repo takes no third-party deps,
so this module carries a parser for the TOML subset the file actually
uses: comments, ``key = "string"`` / ``key = <int>`` pairs, and
``[[suppress]]`` array-of-table headers. ``tomllib`` is preferred when
the interpreter has it (3.11+), keeping the file honest TOML.
"""

from __future__ import annotations

import fnmatch
import os
import re
from dataclasses import dataclass, field

from . import registry
from .findings import RunResult
from .rules import canon_path

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.toml")

_PLACEHOLDER = re.compile(r"^\s*(TODO|FIXME|XXX)\b", re.IGNORECASE)

_KEYS = ("rule", "path", "symbol", "detail", "reason")


class BaselineError(ValueError):
    """Malformed baseline file or illegal suppression (exit code 2)."""


@dataclass
class Baseline:
    path: str
    entries: list = field(default_factory=list)   # list[dict]

    def split(self, findings) -> RunResult:
        """Diff findings against the baseline.

        Returns a RunResult with ``new`` (unsuppressed findings — these
        fail the run), ``suppressed``, and ``stale`` (baseline entries
        that matched nothing — reported so the file shrinks as debt is
        paid, but not failing)."""
        used = [False] * len(self.entries)
        res = RunResult(findings=list(findings))
        for f in findings:
            hit = None
            for i, e in enumerate(self.entries):
                if self._matches(e, f):
                    hit = i
                    break
            if hit is None:
                res.new.append(f)
            else:
                used[hit] = True
                res.suppressed.append(f)
        res.stale = [e for e, u in zip(self.entries, used) if not u]
        return res

    @staticmethod
    def _matches(entry, finding) -> bool:
        return (entry["rule"] == finding.rule
                and entry["path"] == canon_path(finding.path)
                and entry["symbol"] == finding.symbol
                and entry["detail"] == finding.detail)


def _parse_mini_toml(text: str, path: str) -> dict:
    """Parse the TOML subset baseline.toml uses (see module docstring)."""
    doc: dict = {}
    current: dict | None = None    # table being filled (None = top level)
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[suppress]]":
            current = {}
            doc.setdefault("suppress", []).append(current)
            continue
        if line.startswith("["):
            raise BaselineError(
                f"{path}:{lineno}: unsupported table {line!r} (only "
                f"[[suppress]] entries)")
        m = re.match(r'^([A-Za-z_][\w-]*)\s*=\s*(.+?)\s*$', line)
        if not m:
            raise BaselineError(f"{path}:{lineno}: cannot parse {raw!r}")
        key, val = m.group(1), m.group(2)
        if val.startswith('"') and val.endswith('"') and len(val) >= 2:
            parsed: object = val[1:-1]
        elif re.fullmatch(r"-?\d+", val):
            parsed = int(val)
        else:
            raise BaselineError(
                f"{path}:{lineno}: value for {key!r} must be a quoted "
                f"string or integer, got {val!r}")
        (doc if current is None else current)[key] = parsed
    return doc


def load_baseline(path: str | None = None) -> Baseline:
    """Load and validate ``baseline.toml``. Raises BaselineError on a
    malformed file, a missing/placeholder justification, or an entry
    covering step-strict code. A missing file is an empty baseline."""
    path = path or DEFAULT_BASELINE
    if not os.path.exists(path):
        return Baseline(path=path)
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    try:
        import tomllib
        doc = tomllib.loads(text)
    except ModuleNotFoundError:
        doc = _parse_mini_toml(text, path)
    except Exception as e:   # tomllib parse failure
        raise BaselineError(f"{path}: invalid TOML: {e}") from e

    entries = doc.get("suppress", [])
    if not isinstance(entries, list):
        raise BaselineError(f"{path}: 'suppress' must be array-of-tables")
    for i, e in enumerate(entries):
        where = f"{path}: [[suppress]] #{i + 1}"
        missing = [k for k in _KEYS if not isinstance(e.get(k), str)
                   or not e.get(k).strip()]
        # symbol/detail may be empty strings only when explicitly given
        for opt in ("symbol",):
            if opt in missing and isinstance(e.get(opt), str):
                missing.remove(opt)
        if missing:
            raise BaselineError(
                f"{where}: missing or empty field(s): {', '.join(missing)}"
                f" — every suppression needs rule/path/symbol/detail and "
                f"a justification ('reason')")
        if _PLACEHOLDER.match(e["reason"]):
            raise BaselineError(
                f"{where}: placeholder justification {e['reason']!r} — "
                f"write the actual reason this finding is acceptable")
        e["path"] = canon_path(e["path"])
        for suffix, glob in registry.STEP_STRICT:
            if e["path"].endswith(canon_path(suffix)) and \
                    fnmatch.fnmatch(e["symbol"], glob):
                raise BaselineError(
                    f"{where}: {e['symbol']!r} in {e['path']} is "
                    f"step-strict (per-decode-step code) — fix the "
                    f"finding; suppressions are for scheduling-event "
                    f"code only")
    return Baseline(path=path, entries=list(entries))


def write_baseline(path: str, findings) -> int:
    """Write a baseline covering ``findings`` with placeholder reasons.

    Deliberately NOT a way to get to green: the placeholders fail
    validation until a human replaces each with a real justification.
    Returns the number of entries written."""
    seen = set()
    lines = [
        "# repro.analysis baseline — suppressed findings, one table per",
        "# finding key (rule/path/symbol/detail; line-insensitive).",
        "# Every entry MUST carry a real justification in 'reason';",
        "# placeholder reasons (TODO/FIXME) fail validation.",
        "",
        "version = 1",
    ]
    for f in findings:
        key = (f.rule, canon_path(f.path), f.symbol, f.detail)
        if key in seen:
            continue
        seen.add(key)
        lines += [
            "",
            "[[suppress]]",
            f'rule = "{f.rule}"',
            f'path = "{canon_path(f.path)}"',
            f'symbol = "{f.symbol}"',
            f'detail = "{f.detail}"',
            'reason = "TODO: justify this suppression"',
        ]
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")
    return len(seen)
