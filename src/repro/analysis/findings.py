"""Finding/severity model shared by every analysis rule.

A finding's *identity* (``Finding.key``) is deliberately line-insensitive:
``(rule, path, symbol, detail)``. Lines shift on every edit; what the
baseline suppresses is "this construct in this function", not "line 212".
``detail`` is a short stable token for the flagged construct (e.g. the
call that syncs: ``"jax.block_until_ready"``), so two different syncs in
one function baseline independently while a pure reformat stays quiet.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.IntEnum):
    """Ordered so ``max()`` over findings gives the run's worst level."""

    WARN = 1     # suspicious; host-scalar false positives possible
    ERROR = 2    # a contract violation: fix it or justify it in baseline

    def __str__(self) -> str:  # pragma: no cover - trivial
        return self.name.lower()


@dataclass(frozen=True)
class Finding:
    rule: str          # rule name (kebab-case, stable)
    severity: Severity
    path: str          # posix path as given to the runner
    line: int          # 1-indexed source line (display only; not identity)
    symbol: str        # enclosing qualname ("" for module level)
    detail: str        # stable token for the construct (baseline identity)
    message: str       # human sentence

    @property
    def key(self) -> tuple:
        return (self.rule, self.path, self.symbol, self.detail)

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.path}:{self.line}: {self.severity} "
                f"{self.rule}{sym}: {self.message}")


@dataclass
class RunResult:
    """One analysis run: raw findings split against a baseline."""

    findings: list = field(default_factory=list)   # all Finding objects
    new: list = field(default_factory=list)        # not covered by baseline
    suppressed: list = field(default_factory=list)
    stale: list = field(default_factory=list)      # baseline entries unused

    @property
    def failed(self) -> bool:
        return bool(self.new)


def format_findings(findings, *, header: str | None = None) -> str:
    lines = [header] if header else []
    lines += [f.render() for f in findings]
    return "\n".join(lines)
