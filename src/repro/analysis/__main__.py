"""Entry point: ``python -m repro.analysis src/repro``."""

import sys

from .cli import main

sys.exit(main())
