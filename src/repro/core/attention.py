"""Attention with VEXP softmax: reference, FlashAttention-2, and decode paths.

Shape convention: q, k, v are (B, S, H, D) / (B, S, H_kv, D). GQA is handled
by grouping query heads over KV heads (no materialized KV repeat).

Three implementations, selected by ``impl``:

``"xla"``     plain materialized-scores attention (oracle; XLA fuses this
              well for short sequences under remat),
``"flash"``   FlashAttention-2 structured scan over KV blocks with online
              (m, l) statistics — the paper's partial softmax (§III-B/IV-D),
``"pallas"``  the Pallas TPU kernel (kernels/flash_attention), gated behind
              a flag because this container lowers for CPU.

``decode_attention`` is the single-token path used by serve_step: it supports
a sequence-sharded KV cache (sequence-parallel "flash-decode"); because it is
written as max/sum reductions over the cache's sequence axis, GSPMD lowers
the sharded reduction to the partial-softmax merge + all-reduce automatically.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .vexp import get_exp_fn

NEG_INF = -1e30  # finite mask value: keeps vexp branches NaN-free


def _resolve(exp_impl) -> Callable:
    return exp_impl if callable(exp_impl) else get_exp_fn(exp_impl)


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """(B,Sq,H,D) x (B,Sk,Hkv,D) -> scores (B, Hkv, G, Sq, Sk)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale


def _mask(sq: int, sk: int, *, causal: bool, window: Optional[int],
          q_offset: int | jax.Array = 0) -> Optional[jax.Array]:
    """Boolean (Sq, Sk) mask (True = keep). q_offset is the absolute position
    of q[0] minus that of k[0] (for prefill/decode with caches); a (B,)
    array gives each batch row its own offset (chunked prefill cursors) and
    widens the mask to (B, Sq, Sk)."""
    if not causal and window is None:
        return None
    qoff = jnp.asarray(q_offset)
    if qoff.ndim:
        qpos = jnp.arange(sq)[None, :, None] + qoff.reshape(-1, 1, 1)
        kpos = jnp.arange(sk)[None, None, :]
    else:
        qpos = jnp.arange(sq)[:, None] + qoff
        kpos = jnp.arange(sk)[None, :]
    keep = kpos <= qpos if causal else jnp.ones_like(kpos <= qpos)
    if window is not None:
        keep &= kpos > qpos - window
    return keep


def attention_xla(q, k, v, *, causal=True, window=None, exp_impl="vexp",
                  q_offset=0, sm_scale=None, kv_valid=None):
    """Reference attention: materializes the score matrix.

    ``kv_valid`` is an optional (B, Sk) boolean mask of real (non-padding)
    key positions — padded prompt rows in a ragged serving batch must
    neither be attended nor contribute to the softmax normalizer.
    """
    exp_fn = _resolve(exp_impl)
    d = q.shape[-1]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    s = _gqa_scores(q.astype(jnp.float32), k.astype(jnp.float32), scale)
    msk = _mask(q.shape[1], k.shape[1], causal=causal, window=window,
                q_offset=q_offset)
    if msk is not None and msk.ndim == 2:
        msk = msk[None]                            # -> (1|B, Sq, Sk)
    if kv_valid is not None:
        kvm = kv_valid[:, None, :]                 # (B, 1, Sk)
        msk = kvm if msk is None else msk & kvm
    if msk is not None:
        s = jnp.where(msk[:, None, None], s, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = exp_fn(s - m)
    if msk is not None:
        p = jnp.where(msk[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p * (1.0 / jnp.maximum(l, 1e-30))          # NORM: reciprocal-multiply
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    b, sq, hkv, g, dd = o.shape
    return o.reshape(b, sq, hkv * g, dd).astype(q.dtype)


def attention_flash(q, k, v, *, causal=True, window=None, exp_impl="vexp",
                    q_offset=0, sm_scale=None, block_k=512, unroll=False,
                    mm_dtype="f32", kv_valid=None):
    """FlashAttention-2-structured attention (pure JAX scan over KV blocks).

    Maintains per-row running (m, l, acc); each block applies the paper's
    partial-softmax update: rescale by exp(m_old - m_new), accumulate
    exp(s - m_new) and its V-weighted sum. Never materializes (Sq, Sk).

    mm_dtype="bf16" feeds the score/PV matmuls MXU-native bf16 inputs with
    f32 accumulation (preferred_element_type) — (m, l, acc) statistics stay
    f32, so only matmul *inputs* lose precision (§Perf iteration A1).

    ``kv_valid``: optional (B, Sk) boolean mask of real key positions —
    padding rows of a ragged prompt batch are masked out of every block's
    score/normalizer update.
    """
    exp_fn = _resolve(exp_impl)
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    mdt = jnp.bfloat16 if mm_dtype == "bf16" else jnp.float32
    block_k = min(block_k, sk)
    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        kp, vp = k, v
    kb = kp.reshape(b, nblk, block_k, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nblk, block_k, hkv, d).transpose(1, 0, 2, 3, 4)
    if kv_valid is not None:
        kvp = jnp.pad(kv_valid, ((0, 0), (0, pad))) if pad else kv_valid
        kvb = kvp.reshape(b, nblk, block_k).transpose(1, 0, 2)
    else:
        # all-true single-row mask: broadcasts over batch, keeps one scan
        # body for both the masked and unmasked cases.
        kvb = jnp.ones((nblk, 1, block_k), bool)
    qg = (q.astype(jnp.float32) * scale).astype(mdt) \
        .reshape(b, sq, hkv, g, d)

    # q_offset may be a (B,) array (chunked prefill: per-slot cursors) —
    # qpos is then per-row and the block mask widens over the batch.
    qpos = jnp.arange(sq)[None, :] + jnp.asarray(q_offset).reshape(-1, 1)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, iblk, kvblk = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kblk.astype(mdt),
                       preferred_element_type=jnp.float32)
        kpos = iblk * block_k + jnp.arange(block_k)
        keep = jnp.broadcast_to(kpos[None, None, :] < sk,
                                (qpos.shape[0], sq, block_k))
        if causal:
            keep &= kpos[None, None, :] <= qpos[:, :, None]
        if window is not None:
            keep &= kpos[None, None, :] > qpos[:, :, None] - window
        keep = keep & kvblk[:, None, :]              # (B|1, Sq, bk)
        s = jnp.where(keep[:, None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        alpha = exp_fn(m - m_new)
        p = exp_fn(s - m_new[..., None])
        p = jnp.where(keep[:, None, None], p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(mdt), vblk.astype(mdt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nblk), kvb), unroll=unroll)
    out = acc * (1.0 / jnp.maximum(l, 1e-30))[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ExecPolicy kernel backends -> legacy impl names (single source of truth).
from repro.runtime.policy import KERNEL_BACKEND_TO_ATTN_IMPL as _BACKEND_TO_IMPL  # noqa: E402,E501


def attention(q, k, v, *, causal=True, window=None, exp_impl="vexp",
              q_offset=0, sm_scale=None, impl="flash", block_k=512,
              unroll=False, mm_dtype="f32", kv_valid=None, policy=None):
    """Full-sequence attention with selectable implementation.

    A ``runtime.ExecPolicy`` (if given) decides impl, exp backend and block
    sizes in one object; the explicit keyword arguments remain for direct
    use and for q_offset paths the Pallas kernel does not cover.

    ``kv_valid``: optional (B, Sk) boolean key-validity mask for ragged
    (padded) prompt batches — masked key positions are excluded from both
    attention weights and the softmax normalizer.
    """
    if policy is not None:
        impl = _BACKEND_TO_IMPL[policy.kernel_backend]
        exp_impl = policy.exp_backend
        block_k = policy.block_k
    # The Pallas kernel has no q_offset or per-row key-mask support (its
    # masks index from position 0); those paths take the reference flash
    # scan or the masking would be silently wrong.
    if impl == "pallas" and (kv_valid is not None or
                             not (isinstance(q_offset, int) and q_offset == 0)):
        impl = "flash"
    if impl == "xla":
        return attention_xla(q, k, v, causal=causal, window=window,
                             exp_impl=exp_impl, q_offset=q_offset,
                             sm_scale=sm_scale, kv_valid=kv_valid)
    if impl == "flash":
        return attention_flash(q, k, v, causal=causal, window=window,
                               exp_impl=exp_impl, q_offset=q_offset,
                               sm_scale=sm_scale, block_k=block_k,
                               unroll=unroll, mm_dtype=mm_dtype,
                               kv_valid=kv_valid)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        if policy is not None:
            return fa_ops.flash_attention_policy(
                q, k, v, causal=causal, window=window, sm_scale=sm_scale,
                policy=policy)
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      sm_scale=sm_scale)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     exp_impl="vexp", sm_scale=None, mm_dtype="f32",
                     layout="bshd", policy=None):
    """Single-token decode attention over a (possibly sequence-sharded) cache.

    q: (B, 1, H, D); caches: (B, S_max, Hkv, D); cache_len: scalar or (B,)
    number of valid positions (the new token's K/V must already be written).

    Written as pure max/sum reductions over the cache sequence axis so that a
    cache sharded along S lowers to partial (m, l, acc) per shard + a cheap
    all-reduce merge — the paper's partial-softmax algebra as SPMD collective.

    A policy with ``kernel_backend="pallas"`` routes *every* configuration
    — both cache layouts, sliding windows, scalar or per-slot (B,)
    ``cache_len`` — to the fused flash-decode kernel (the layout is
    resolved in the kernel's index maps, windows in its sweep bounds);
    only the other backends run this reference reduction.
    """
    if policy is not None:
        exp_impl = policy.exp_backend
        cl = jnp.asarray(cache_len)
        if policy.kernel_backend == "pallas" and cl.ndim <= 1:
            from repro.kernels.decode_attention import ops as dec_ops
            return dec_ops.decode_attention_policy(
                q, k_cache, v_cache, cache_len, window=window,
                sm_scale=sm_scale, layout=layout, policy=policy)
    exp_fn = _resolve(exp_impl)
    b, _, h, d = q.shape
    if layout == "bhsd":
        hkv, smax = k_cache.shape[1], k_cache.shape[2]
    else:
        smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    mdt = jnp.bfloat16 if mm_dtype == "bf16" else jnp.float32
    qg = (q.astype(jnp.float32) * scale).astype(mdt).reshape(b, hkv, g, d)
    # cache reads stay in their storage dtype under mm_dtype="bf16": no
    # materialized f32 copy of the cache (§Perf iter C1); the "bhsd"
    # layout feeds the einsum directly — no cache transpose (§Perf C3)
    eq_s = "bkgd,bktd->bkgt" if layout == "bhsd" else "bkgd,btkd->bkgt"
    s = jnp.einsum(eq_s, qg, k_cache.astype(mdt),
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(smax)
    cl = jnp.asarray(cache_len)
    keep = pos[None, :] < (cl.reshape(-1, 1) if cl.ndim else cl[None, None])
    if window is not None:
        start = (cl.reshape(-1, 1) if cl.ndim else cl[None, None]) - window
        keep = keep & (pos[None, :] >= start)
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = exp_fn(s - m)
    p = jnp.where(keep[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p * (1.0 / jnp.maximum(l, 1e-30))
    eq_o = "bkgt,bktd->bkgd" if layout == "bhsd" else "bkgt,btkd->bkgd"
    o = jnp.einsum(eq_o, p.astype(mdt), v_cache.astype(mdt),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)
