"""Softmax built on the VEXP exponential, plus online (partial) softmax algebra.

Implements the paper's optimized kernel structure (§IV-C):

  MAX  — row max (numerical stability),
  EXP  — vexp(x - max) with fused sum accumulation,
  NORM — one reciprocal per row, then pointwise multiply
         (never a per-element divide; Snitch's divider is unpipelined and the
         TPU VPU's divide is similarly much slower than multiply).

The *online* variants maintain FlashAttention-style running statistics
(m = running max, l = running sum of exponentials) and a merge rule that is
associative and commutative — the same algebra the paper uses for partial
softmax on SPM tiles, and that we additionally exploit for sequence-parallel
(KV-sharded) decode where each shard computes partial (m, l, acc) and the
merge happens through an all-reduce.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .vexp import get_exp_fn


def softmax(x: jax.Array, axis: int = -1, *, exp_impl: str | Callable = "vexp",
            where=None, policy=None) -> jax.Array:
    """Numerically-stable softmax with a pluggable exp backend.

    exp_impl: "vexp" (paper's approximation), "exact" (transcendental),
    "vexp_hw" (bit-exact hardware model), or a callable.

    An ``ExecPolicy`` overrides exp_impl and, for ``kernel_backend=
    "pallas"`` (unmasked case), routes to the fused Pallas row-softmax via
    kernels.dispatch — one switch flips the whole execution.
    """
    if policy is not None:
        if policy.kernel_backend == "pallas" and where is None:
            from repro.kernels.dispatch import dispatch
            return dispatch("softmax", policy)(x, axis=axis, policy=policy)
        exp_impl = policy.exp_backend
    exp_fn = exp_impl if callable(exp_impl) else get_exp_fn(exp_impl)
    if where is not None:
        x = jnp.where(where, x, -jnp.inf)
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # all-masked rows
    e = exp_fn(x - m)
    if where is not None:
        e = jnp.where(where, e, 0.0)
    s = jnp.sum(e, axis=axis, keepdims=True)
    # NORM: reciprocal once, multiply everywhere. Guarded like the kernels'
    # finalize: a fully-masked row (all where=False — e.g. a padded serving
    # slot) has s == 0, and an unguarded divide would emit inf * 0 = NaN;
    # with the guard its e is all-zero, so the row comes out zeros.
    return e * (1.0 / jnp.maximum(s, 1e-30))


def log_softmax(x: jax.Array, axis: int = -1, *,
                exp_impl: str | Callable = "vexp") -> jax.Array:
    """log softmax; the log itself stays exact (only exp is approximated)."""
    exp_fn = exp_impl if callable(exp_impl) else get_exp_fn(exp_impl)
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    shifted = x - m
    s = jnp.sum(exp_fn(shifted), axis=axis, keepdims=True)
    return shifted - jnp.log(s)


class SoftmaxStats(NamedTuple):
    """Online softmax running statistics for a row (or batch of rows)."""
    m: jax.Array    # running max
    l: jax.Array    # running sum of exp(x - m)


def stats_init(shape, dtype=jnp.float32) -> SoftmaxStats:
    return SoftmaxStats(m=jnp.full(shape, -jnp.inf, dtype),
                        l=jnp.zeros(shape, dtype))


def stats_update(stats: SoftmaxStats, x_blk: jax.Array, axis: int = -1, *,
                 exp_fn: Callable) -> tuple[SoftmaxStats, jax.Array, jax.Array]:
    """Absorb one block of scores; returns (new_stats, p_blk, alpha).

    p_blk = exp(x_blk - m_new) and alpha = exp(m_old - m_new) is the
    correction factor the caller applies to any accumulator keyed on m_old
    (the FlashAttention-2 rescale).
    """
    m_blk = jnp.max(x_blk, axis=axis)
    m_new = jnp.maximum(stats.m, m_blk)
    # Guard -inf - -inf = nan for fully-masked blocks.
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = exp_fn(jnp.where(jnp.isfinite(stats.m), stats.m - safe_m, -jnp.inf))
    alpha = jnp.where(jnp.isfinite(stats.m), alpha, 0.0)
    p_blk = exp_fn(x_blk - jnp.expand_dims(safe_m, axis))
    p_blk = jnp.where(jnp.isfinite(x_blk), p_blk, 0.0)
    l_new = stats.l * alpha + jnp.sum(p_blk, axis=axis)
    return SoftmaxStats(m=m_new, l=l_new), p_blk, alpha


def stats_merge(a: SoftmaxStats, b: SoftmaxStats, *,
                exp_fn: Callable) -> tuple[SoftmaxStats, jax.Array, jax.Array]:
    """Merge two partial softmaxes; returns (merged, alpha_a, alpha_b).

    alpha_* rescale accumulators built against each partial max. Associative
    + commutative, so it is safe inside tree reductions / all-reduces
    (sequence-parallel decode) exactly like the paper's tile merge.
    """
    m = jnp.maximum(a.m, b.m)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)

    def _alpha(mm):
        al = exp_fn(jnp.where(jnp.isfinite(mm), mm - safe_m, -jnp.inf))
        return jnp.where(jnp.isfinite(mm), al, 0.0)

    aa, ab = _alpha(a.m), _alpha(b.m)
    return SoftmaxStats(m=m, l=a.l * aa + b.l * ab), aa, ab


# Finite "empty" sentinel used by the Pallas kernels instead of -inf (keeps
# the vexp bit-twiddle NaN-free). Anything at or below half of it is treated
# as "this shard saw no valid key".
KERNEL_NEG_INF = -1e30


def stats_merge_collective(stats: SoftmaxStats, acc: jax.Array,
                           axis_name: str, *,
                           exp_fn: Callable) -> tuple[SoftmaxStats, jax.Array]:
    """``stats_merge`` as an SPMD collective over a ``shard_map`` mesh axis.

    Each shard holds partial (m, l) statistics plus an un-normalized
    accumulator ``acc`` (trailing dims broadcast against l's). Because the
    merge rule is associative and commutative, folding it over all shards
    is exactly one ``pmax`` (global m) followed by one ``psum`` of the
    alpha-rescaled (l, acc) — the all-reduce form of the paper's partial
    softmax tile merge, applied to sequence-parallel flash decode.

    Shards whose slice contained no valid key carry the identity element
    (m <= KERNEL_NEG_INF, l = 0, acc = 0) or (m = -inf); both are guarded
    so they contribute exactly nothing (never NaN via -inf - -inf).

    This is the *split* (three-collective: pmax + 2 psum) merge strategy;
    ``stats_merge_collective_packed`` is the single-collective form over a
    packed (acc | m | l) tile. Both compute the exact same algebra.
    """
    m_g = jax.lax.pmax(stats.m, axis_name)
    empty = (stats.m <= 0.5 * KERNEL_NEG_INF) | ~jnp.isfinite(stats.m)
    safe_g = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
    alpha = jnp.where(empty, 0.0, exp_fn(stats.m - safe_g))
    l_g = jax.lax.psum(stats.l * alpha, axis_name)
    acc_g = jax.lax.psum(acc * alpha, axis_name)
    return SoftmaxStats(m=m_g, l=l_g), acc_g


def stats_merge_collective_packed(packed: jax.Array, axis_name: str, *,
                                  exp_fn: Callable
                                  ) -> tuple[SoftmaxStats, jax.Array]:
    """Single-collective partial-softmax merge over a packed stats tile.

    ``packed`` is each shard's contiguous ``(..., d + 2)`` tile laid out
    as ``[acc (d lanes) | m (1) | l (1)]`` — emitted directly by the
    flash-decode kernel's packed mode, so there is no per-shard
    concatenate before the collective. One ``all_gather`` over
    ``axis_name`` moves every shard's tile in a single collective, and
    the alpha-rescaled fold of ``stats_merge`` then runs shard-locally
    over the gathered leading axis.

    The global max is taken *before* any exponentiation, so ``m - m_g``
    is always <= 0 and the merge cannot overflow no matter how far the
    per-shard maxima are spread (the overflow-guard test pins this).
    Empty shards (m <= KERNEL_NEG_INF / non-finite) contribute exactly
    nothing, as in the split form.

    Returns the same (SoftmaxStats, acc) pair as
    ``stats_merge_collective``; callers normalize with
    ``acc / max(l, tiny)``.
    """
    d = packed.shape[-1] - 2
    tiles = jax.lax.all_gather(packed, axis_name)    # (n_shards, ..., d+2)
    m_sh = tiles[..., d:d + 1]
    l_sh = tiles[..., d + 1:d + 2]
    m_g = jnp.max(m_sh, axis=0)
    empty = (m_sh <= 0.5 * KERNEL_NEG_INF) | ~jnp.isfinite(m_sh)
    safe_g = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
    alpha = jnp.where(empty, 0.0, exp_fn(m_sh - safe_g))
    l_g = jnp.sum(l_sh * alpha, axis=0)
    acc_g = jnp.sum(tiles[..., :d] * alpha, axis=0)
    return SoftmaxStats(m=m_g, l=l_g), acc_g
