"""VEXP: fast exponential approximation (Schraudolph + Belano polynomial).

This is the paper's core contribution, adapted for TPU. Two implementations:

``vexp_f32``
    The *deployable* TPU path. Schraudolph's method computed in f32 on the
    VPU: ``x' = x*log2(e)``, split into integer/fraction, two-branch quadratic
    mantissa correction P(frac) (paper Eq. 2), and the result ``2^i * (1+P)``
    reconstructed with integer bit manipulation (no transcendental unit).
    Ops used: mul, floor, cmp/select, int shift/and/add, bitcast — all cheap
    single-issue VPU ops.

``vexp_bf16_fixedpoint``
    A bit-level model of the paper's hardware datapath (Fig. 3c-e): BF16
    decomposition, Q-format fixed-point multiply by log2(e), shift/round to a
    Q?.15 fixed-point x', fixed-point P(x) with ``not()`` complements standing
    in for ``1-x``, and round-to-nearest-7-bit mantissa reconstruction.
    Used for accuracy studies ("what would the silicon produce").

Both satisfy the paper's accuracy envelope (~0.14% mean / ~0.78% max relative
error vs. the true exponential; see benchmarks/exp_accuracy.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Paper §III-D constants (Belano et al., Monte-Carlo optimized).
ALPHA = 0.21875        # = 7/32,  exact in binary
BETA = 0.4375          # = 7/16,  exact in binary
GAMMA1 = 3.296875      # = 211/64, exact in binary
GAMMA2 = 2.171875      # = 139/64, exact in binary
LOG2E = 1.4426950408889634

# Fixed-point constants (hardware model). Fraction is Q0.15 as in the paper's
# "first 15 bits of the shifted mantissa".
_F = 15                      # fraction bits of x'
_LOG2E_Q15 = 47274           # round(log2(e) * 2**15)
_ALPHA_Q15 = 7168            # 0.21875  * 2**15 (exact)
_BETA_Q15 = 14336            # 0.4375   * 2**15 (exact)
_GAMMA1_Q15 = 108032         # 3.296875 * 2**15 (exact)
_GAMMA2_Q15 = 71168          # 2.171875 * 2**15 (exact)


def _pcorr_f32(f: jax.Array) -> jax.Array:
    """Two-branch mantissa-correction polynomial P(f), f in [0, 1) (Eq. 2).

    Approximates 2**f - 1. Branch selected by f's MSB (f >= 0.5 in hardware);
    ``not(x)`` in the paper is the fixed-point complement of x, i.e. 1-x up to
    one ULP — here modeled exactly as 1-x in float.
    """
    lo = ALPHA * f * (f + GAMMA1)
    hi = 1.0 - BETA * (1.0 - f) * (f + GAMMA2)
    return jnp.where(f < 0.5, lo, hi)


@jax.custom_jvp
def vexp_f32(x: jax.Array) -> jax.Array:
    """Schraudolph+P(x) exponential on f32 (TPU-deployable path).

    Accepts any float dtype; computes in f32 and returns the input dtype.
    Handles overflow (+inf), underflow/subnormal flush (0.0) and NaN
    propagation per the paper's BF16 simplifications.

    Differentiation: the value is reconstructed through an integer
    bitcast, which XLA treats as non-differentiable (silent zero grads —
    it would freeze every softmax/attention weight during training). The
    mathematically correct surrogate is exp' = exp: the custom JVP reuses
    the approximation itself, so training with vexp works end to end.
    """
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    # Clip so int conversion below stays in range; true saturation handled
    # explicitly from the unclipped input afterwards.
    xp = jnp.clip(xf, -200.0, 200.0) * jnp.float32(LOG2E)
    i = jnp.floor(xp)
    f = xp - i
    m = 1.0 + _pcorr_f32(f)                      # in [1, 2)
    # Clamp to the representable exponent window so (ii << 23) + mbits stays
    # inside int32; the boundary values exactly trigger the saturation
    # selects below.
    ii = jnp.clip(i.astype(jnp.int32), -127, 128)
    # Reconstruct 2**i * m: add i to m's biased exponent field.
    mbits = jax.lax.bitcast_convert_type(m, jnp.int32)
    out = jax.lax.bitcast_convert_type(mbits + (ii << 23), jnp.float32)
    # Saturation: i <= -127 would produce a subnormal/zero exponent -> flush;
    # i >= 128 overflows -> +inf. (m's own exponent is 127 so field = 127+i.)
    out = jnp.where(ii <= -127, 0.0, out)
    out = jnp.where(ii >= 128, jnp.inf, out)
    out = jnp.where(xf <= -126.0 * 0.6931471805599453, 0.0, out)
    out = jnp.where(xf >= 128.0 * 0.6931471805599453, jnp.inf, out)
    out = jnp.where(jnp.isnan(xf), jnp.nan, out)
    return out.astype(orig_dtype)


@vexp_f32.defjvp
def _vexp_f32_jvp(primals, tangents):
    (x,), (xdot,) = primals, tangents
    y = vexp_f32(x)
    # d/dx exp(x) = exp(x); guard inf*0 at the saturated tails.
    ydot = jnp.where(jnp.isfinite(y), y, 0.0).astype(x.dtype) * xdot
    return y, ydot


def vexp_bf16(x: jax.Array) -> jax.Array:
    """BF16-in/BF16-out convenience wrapper over the f32 datapath."""
    return vexp_f32(x.astype(jnp.bfloat16)).astype(jnp.bfloat16)


def _round_shift_right(v: jax.Array, k: jax.Array) -> jax.Array:
    """Arithmetic right shift with round-to-nearest (ties away from zero).

    k is clamped to [0, 30]; callers guarantee v >= 0.
    """
    k = jnp.clip(k, 0, 30)
    bias = jnp.where(k > 0, (1 << jnp.maximum(k - 1, 0)), 0)
    return jax.lax.shift_right_arithmetic(v + bias, k)


def _pcorr_q15(f: jax.Array) -> jax.Array:
    """Fixed-point P(f): f is Q0.15 in [0, 2**15). Returns Q0.15.

    Mirrors the RTL: branch on the MSB of the fraction; ``not(x)`` is the
    bitwise complement (= 1 - x - 2**-15 in Q0.15), as in the paper.
    """
    # Clamp each branch's operand into its own domain so the int32 products
    # cannot overflow (jnp.where evaluates both branches).
    fl = jnp.minimum(f, (1 << 14) - 1)            # [0, 0.5)
    fh = jnp.maximum(f, 1 << 14)                  # [0.5, 1)
    # Branch [0, 0.5): alpha * f * (f + gamma1)
    t1 = jax.lax.shift_right_logical(fl * (fl + _GAMMA1_Q15), 15)  # Q?.15
    lo = jax.lax.shift_right_logical(_ALPHA_Q15 * t1, 15)
    # Branch [0.5, 1): not(beta * not(f) * (f + gamma2))
    nf = 0x7FFF - fh                                               # not(f)
    t2 = jax.lax.shift_right_logical(nf * (fh + _GAMMA2_Q15), 15)
    hi = 0x7FFF - jax.lax.shift_right_logical(_BETA_Q15 * t2, 15)
    return jnp.where(f < (1 << 14), lo, hi)


def vexp_bf16_fixedpoint(x: jax.Array) -> jax.Array:
    """Bit-level model of the paper's EXP arithmetic block (Fig. 3c-e).

    Input/output BF16. All arithmetic is int32 fixed point, mirroring the
    two cascaded stages exps(x) (Schraudolph in hardware) and P(x) (mantissa
    correction), including subnormal flush-to-zero and overflow detection.
    """
    assert x.dtype == jnp.bfloat16, "hardware model is BF16-only"
    bits = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)
    sign = jax.lax.shift_right_logical(bits, 15) & 1
    e = jax.lax.shift_right_logical(bits, 7) & 0xFF
    mant = (bits & 0x7F) | 0x80                        # Q1.7 in [1, 2)

    # |x'| = |x| * log2(e) = mant * LOG2E_Q15 * 2**(e - 127 - 7 - 15)
    # As Q(_F)=Q.15 fixed point: xq = prod * 2**(e - 134), e <= 134 here
    # (e >= 135 means |x| >= 256 -> guaranteed overflow/underflow).
    prod = mant * _LOG2E_Q15                           # <= 2**23.6
    k = 134 - jnp.minimum(e, 134)
    xq = _round_shift_right(prod, k)                   # Q0.15 magnitude of x'
    xq = jnp.where(sign == 1, -xq, xq)
    i = jax.lax.shift_right_arithmetic(xq, _F)         # floor(x')
    f = xq & 0x7FFF                                    # frac(x') in Q0.15

    p = _pcorr_q15(f)                                  # Q0.15, approximates 2**f - 1
    # Round the corrected mantissa to BF16's 7 bits (round-to-nearest).
    m7 = jax.lax.shift_right_logical(p + (1 << 7), 8)  # could be 128 (carry)
    carry = jax.lax.shift_right_logical(m7, 7)         # 0 or 1
    m7 = jnp.where(carry == 1, 0, m7)
    new_e = i + 127 + carry

    out_bits = jax.lax.shift_left(new_e, 7) | m7
    # Saturation & specials.
    pos_over = (sign == 0) & ((e >= 135) | (new_e >= 255))
    under = (sign == 1) & ((e >= 135) | (new_e <= 0))
    under = under | ((sign == 0) & (new_e <= 0))       # cannot happen, safety
    out_bits = jnp.where(pos_over, 0x7F80, out_bits)   # +inf
    out_bits = jnp.where(under, 0, out_bits)           # flush to zero
    is_nan = (e == 255) & ((bits & 0x7F) != 0)
    neg_inf = (e == 255) & ((bits & 0x7F) == 0) & (sign == 1)
    pos_inf = (e == 255) & ((bits & 0x7F) == 0) & (sign == 0)
    out_bits = jnp.where(is_nan, 0x7FC0, out_bits)     # qNaN
    out_bits = jnp.where(neg_inf, 0, out_bits)
    out_bits = jnp.where(pos_inf, 0x7F80, out_bits)
    # exp(0) == 1 exactly (xq == 0 path already yields e=127, m=0 -> 1.0).
    return jax.lax.bitcast_convert_type(
        out_bits.astype(jnp.uint16), jnp.bfloat16)


def vexp_hw(x: jax.Array) -> jax.Array:
    """Dtype-safe entry to the bit-exact hardware model.

    ``vexp_bf16_fixedpoint`` asserts BF16 input (it models the BF16-only
    silicon datapath). Softmax/attention call the registry on f32 arrays, so
    this wrapper routes any float dtype through BF16 — exactly what feeding
    the hardware would do — and returns the caller's dtype.
    """
    if x.dtype == jnp.bfloat16:
        return vexp_bf16_fixedpoint(x)
    return vexp_bf16_fixedpoint(x.astype(jnp.bfloat16)).astype(x.dtype)


def exact_exp(x: jax.Array) -> jax.Array:
    """The baseline transcendental exp (XLA's polynomial), for comparison."""
    return jnp.exp(x)


# Registry used by softmax/attention/model layers to select the exp backend.
EXP_FNS = {
    "exact": exact_exp,
    "vexp": vexp_f32,
    "vexp_hw": vexp_hw,
}


def get_exp_fn(name: str):
    try:
        return EXP_FNS[name]
    except KeyError:
        raise ValueError(f"unknown exp impl {name!r}; one of {list(EXP_FNS)}")
