"""Core: the paper's contribution — VEXP exponential, softmax, attention.

Function exports avoid shadowing the ``softmax`` / ``attention`` submodules:
use ``repro.core.softmax.softmax(...)`` / ``repro.core.attention.attention``
or the aliases ``vexp_softmax`` / ``vexp_attention`` below.
"""

from . import vexp, softmax, attention
from .vexp import (vexp_f32, vexp_bf16, vexp_bf16_fixedpoint, vexp_hw,
                   exact_exp,
                   get_exp_fn, EXP_FNS, ALPHA, BETA, GAMMA1, GAMMA2)
from .softmax import (log_softmax, SoftmaxStats, stats_init,
                      stats_update, stats_merge)
from .softmax import softmax as vexp_softmax
from .attention import (attention_xla, attention_flash, decode_attention)
from .attention import attention as vexp_attention
