"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern is 1 local-attention layer per ``cfg.attn_period`` (= 3 for
recurrentgemma: rec, rec, attn), scanned over whole periods with the tail
(n_layers % period, recurrent) handled explicitly.

Arch-applicability (DESIGN.md §4): the paper's softmax kernel applies to the
local-attention layers and final logits; the RG-LRU gates are
sigmoid/softplus — also exponential-family, computed via the same VEXP
primitive:  a_t = exp(c · r_t · log a)  is literally a vexp call on a
non-positive argument (vexp's best-accuracy range).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import exp_callable
from .layers import (dense_init, embed_init, norm_init, norm_apply,
                     vexp_sigmoid, gelu, mlp_init, mlp_apply, cross_entropy,
                     mask_padded_logits)
from .state_spec import LeafAxes
from .transformer import (attn_init, attn_apply, attn_decode, _qkv,
                          _rope_pos, _write_token_kv, _write_chunk_kv,
                          _write_chunk_kv_paged, PARKED_POS)

RG_LRU_C = 8.0     # Griffin's fixed exponent scale


# ------------------------------------------------------------ RG-LRU block

def rec_layer_init(key, cfg, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    # Lambda init so that a = sigmoid(lam) in [0.9, 0.999] (Griffin app. A)
    u = jax.random.uniform(ks[6], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** 2 / (1 - u ** 2))
    return {
        "ln": norm_init(d, cfg.norm),
        "wx": dense_init(ks[0], d, w, dtype),          # recurrent branch
        "wy": dense_init(ks[1], d, w, dtype),          # gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_input_gate": dense_init(ks[3], w, w, dtype),
        "w_rec_gate": dense_init(ks[4], w, w, dtype),
        "lam": lam,
        "w_out": dense_init(ks[5], w, d, dtype),
        "ln_mlp": norm_init(d, cfg.norm),
        "mlp": mlp_init(ks[7], d, cfg.d_ff, cfg.act, cfg.use_bias, dtype),
    }


def _rg_lru(xw, p, cfg, h0=None, last_idx=None, policy=None):
    """RG-LRU over a sequence. xw: (B, S, W). Returns (y, h_last).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    log a_t = -c * r_t * softplus(-lam)  (= c*r_t*log sigmoid(lam) <= 0).
    Parallelized with an associative scan in the log-decay domain.

    ``last_idx`` (B,) gathers each row's state at that position instead of
    the sequence end (ragged right-padded prefill: the state at the last
    *real* token — a prefix-scan element depends only on positions <= it,
    so no masking of the padded tail is needed).
    """
    exp_fn = exp_callable(policy, cfg.exp_impl)
    xf = xw.astype(jnp.float32)
    r = vexp_sigmoid(xf @ p["w_rec_gate"].astype(jnp.float32), exp_fn)
    i = vexp_sigmoid(xf @ p["w_input_gate"].astype(jnp.float32), exp_fn)
    log_a_base = -jnp.logaddexp(0.0, -p["lam"])       # log sigmoid(lam) <= 0
    log_a = RG_LRU_C * r * log_a_base                 # (B, S, W)
    a = exp_fn(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - exp_fn(2.0 * log_a), 0.0)) * (i * xf)

    # associative scan over seq: elements (log_a, b); an initial state h0
    # contributes prod(a_{1..t}) * h0, added after the scan.
    def combine(e1, e2):
        la1, b1 = e1
        la2, b2 = e2
        return la1 + la2, exp_fn(la2) * b1 + b2

    la_acc, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    if h0 is not None:
        h = h + exp_fn(la_acc) * h0[:, None, :]
    if last_idx is None:
        h_last = h[:, -1]
    else:
        h_last = jnp.take_along_axis(
            h, jnp.asarray(last_idx, jnp.int32).reshape(-1, 1, 1), axis=1
        )[:, 0]
    return h.astype(xw.dtype), h_last


def rec_layer_apply(x, p, cfg, h0=None, conv_state=None, last_idx=None,
                    valid_len=None, policy=None):
    """Full-sequence recurrent block. Returns (y, (h_last, conv_state)).

    ``last_idx``/``valid_len`` (both (B,), = prompt_len - 1 / prompt_len)
    take each row's recurrent and conv state at its last real token."""
    hin = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    u = hin @ p["wx"]
    # temporal conv (depthwise, causal)
    from .ssm import _causal_conv
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state,
                                 valid_len=valid_len)
    y, h_last = _rg_lru(u, p, cfg, h0, last_idx=last_idx, policy=policy)
    gate = gelu(hin @ p["wy"])
    out = (y * gate) @ p["w_out"]
    x = x + out
    h2 = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(h2, p["mlp"], cfg.act, cfg.exp_impl)
    return x, (h_last, conv_state)


def rec_layer_decode(x, p, cfg, state, policy=None):
    """Single-token decode. state: {"h": (B, W), "conv": (B, W-1, W)}."""
    exp_fn = exp_callable(policy, cfg.exp_impl)
    hin = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    u = hin @ p["wx"]
    from .ssm import _causal_conv
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    uf = u[:, 0].astype(jnp.float32)
    r = vexp_sigmoid(uf @ p["w_rec_gate"].astype(jnp.float32), exp_fn)
    i = vexp_sigmoid(uf @ p["w_input_gate"].astype(jnp.float32), exp_fn)
    log_a_base = -jnp.logaddexp(0.0, -p["lam"])
    log_a = RG_LRU_C * r * log_a_base
    a = exp_fn(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - exp_fn(2 * log_a), 0.0)) * (i * uf)
    h = a * state["h"] + bterm
    gate = gelu(hin[:, 0] @ p["wy"])
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None, :]
    x = x + out
    h2 = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(h2, p["mlp"], cfg.act, cfg.exp_impl)
    return x, {"h": h, "conv": new_conv}


# ----------------------------------------------------- attention sub-block

def attn_layer_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {"ln": norm_init(cfg.d_model, cfg.norm),
            "attn": attn_init(ks[0], cfg, dtype),
            "ln_mlp": norm_init(cfg.d_model, cfg.norm),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.use_bias, dtype)}


def attn_layer_apply(x, p, cfg, pos, kv_valid=None, policy=None):
    h = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    a, kv = attn_apply(h, p["attn"], cfg, pos, window=cfg.sliding_window,
                       kv_valid=kv_valid, policy=policy)
    x = x + a
    h2 = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(h2, p["mlp"], cfg.act, cfg.exp_impl)
    return x, kv


def attn_layer_decode(x, p, cfg, ck, cv, pos, wpos, policy=None,
                      oob_drop=False):
    """Single-token local-attention decode. ``pos`` (and the ring-buffer
    write cursor ``wpos``) may be a scalar or a per-slot (B,) vector — the
    continuous-batching engine's slots each advance at their own
    position; the scatter write and the per-row cache_len mask keep them
    independent. ``oob_drop`` lets parked rows (wpos == PARKED_POS) skip
    their cache write entirely."""
    from repro.core.attention import decode_attention
    b = x.shape[0]
    h = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    q, k, v = _qkv(h, p["attn"], cfg, _rope_pos(b, pos))
    ck = _write_token_kv(ck, k, wpos, "bshd", oob_drop=oob_drop)
    cv = _write_token_kv(cv, v, wpos, "bshd", oob_drop=oob_drop)
    w = cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    valid = jnp.minimum(pos + 1, w) if w else pos + 1
    o = decode_attention(q, ck, cv, cache_len=valid, exp_impl=cfg.exp_impl,
                         mm_dtype=cfg.attn_mm_dtype, policy=policy)
    x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"]
    h2 = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(h2, p["mlp"], cfg.act, cfg.exp_impl)
    return x, ck, cv


# ---------------------------------------------------------------- full model

def _period_counts(cfg):
    period = cfg.attn_period
    n_per = cfg.n_layers // period            # scanned periods
    tail = cfg.n_layers % period              # trailing recurrent layers
    return period, n_per, tail


def init_params(cfg, key):
    period, n_per, tail = _period_counts(cfg)
    n_rec_per = period - 1
    ks = jax.random.split(key, n_per + tail + 3)
    periods = []
    for i in range(n_per):
        sub = jax.random.split(ks[i], period)
        periods.append({
            "recs": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[rec_layer_init(sub[j], cfg) for j in range(n_rec_per)]),
            "attn": attn_layer_init(sub[-1], cfg),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)
    p = {"periods": stacked,
         "ln_f": norm_init(cfg.d_model, cfg.norm),
         "embed": embed_init(ks[-1], cfg.vocab_padded, cfg.d_model),
         "unembed": dense_init(ks[-2], cfg.d_model, cfg.vocab_padded)}
    if tail:
        p["tail"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[rec_layer_init(ks[n_per + j], cfg) for j in range(tail)])
    return p


def _cast(layer_p, dt):
    return jax.tree.map(lambda a: a.astype(dt)
                        if a.dtype == jnp.float32 and a.ndim > 1 else a,
                        layer_p)


def forward(params, cfg, tokens, *, policy=None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    b, s = tokens.shape
    pos = jnp.arange(s)[None, :].astype(jnp.int32)
    period, n_per, tail = _period_counts(cfg)

    def body(x, period_p):
        period_p = _cast(period_p, dt)

        def rec_body(x, rec_p):
            y, _ = rec_layer_apply(x, rec_p, cfg, policy=policy)
            return y, None

        x, _ = jax.lax.scan(rec_body, x, period_p["recs"],
                            unroll=cfg.unroll_scans)
        x, _ = attn_layer_apply(x, period_p["attn"], cfg, pos,
                                policy=policy)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n_per = cfg.n_layers // cfg.attn_period
    x, _ = jax.lax.scan(body, x, params["periods"],
                        unroll=n_per if cfg.unroll_scans else 1)
    if tail:
        def tail_body(x, rec_p):
            y, _ = rec_layer_apply(x, rec_p, cfg, policy=policy)
            return y, None
        x, _ = jax.lax.scan(tail_body, x, _cast(params["tail"], dt),
                            unroll=cfg.unroll_scans)
    return norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)


def loss_fn(params, cfg, batch, *, policy=None):
    x = forward(params, cfg, batch["tokens"], policy=policy)
    return cross_entropy(x, params["unembed"], batch["labels"],
                         chunk=cfg.loss_chunk, exp_impl=cfg.exp_impl,
                         mask=batch.get("mask"), unroll=cfg.unroll_scans)


def init_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    period, n_per, tail = _period_counts(cfg)
    w = cfg.lru_width or cfg.d_model
    win = min(seq_len, cfg.sliding_window or seq_len)
    cache = {"periods": {
        "rec_h": jnp.zeros((n_per, period - 1, batch, w), jnp.float32),
        "rec_conv": jnp.zeros((n_per, period - 1, batch,
                               cfg.conv_width - 1, w), jnp.float32),
        "k": jnp.zeros((n_per, batch, win, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((n_per, batch, win, cfg.n_kv_heads, cfg.hd), dtype),
    }}
    if tail:
        cache["tail"] = {
            "h": jnp.zeros((tail, batch, w), jnp.float32),
            "conv": jnp.zeros((tail, batch, cfg.conv_width - 1, w),
                              jnp.float32)}
    return cache


def cache_axes(cfg):
    """DecodeState leaf metadata for the mixed per-period state: the
    recurrent snapshots carry only a slot axis; the local-attention KV
    leaves additionally have a sequence axis (ring-buffer window)."""
    period, n_per, tail = _period_counts(cfg)
    axes = {"periods": {"rec_h": LeafAxes(2), "rec_conv": LeafAxes(2),
                        "k": LeafAxes(1, 2), "v": LeafAxes(1, 2)}}
    if tail:
        axes["tail"] = {"h": LeafAxes(1), "conv": LeafAxes(1)}
    return axes


def prefill(params, cfg, tokens, *, prompt_len=None, policy=None):
    """Prompt forward -> (last_logits, cache).

    ``prompt_len`` (B,) marks ragged right-padded prompts: padding is
    masked out of the local attention (and its pad K/V rows zeroed), each
    recurrent layer's (h, conv) state is gathered at the row's last real
    token, and so are the returned logits. Ragged batches must fit the
    sliding window (the ring-buffer roll is batch-uniform)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    b, s = tokens.shape
    pos = jnp.arange(s)[None, :].astype(jnp.int32)
    period, n_per, tail = _period_counts(cfg)
    win = min(s, cfg.sliding_window or s)
    plen = kv_valid = last_idx = None
    if prompt_len is not None:
        if cfg.sliding_window and s > cfg.sliding_window:
            raise ValueError(
                f"ragged prefill of {s} tokens exceeds the sliding window "
                f"({cfg.sliding_window}): the ring-buffer roll is batch-"
                f"uniform; prefill ragged windowed batches at <= window")
        plen = jnp.asarray(prompt_len, jnp.int32).reshape(-1)
        kv_valid = jnp.arange(s)[None, :] < plen[:, None]        # (B, S)
        last_idx = jnp.clip(plen - 1, 0, s - 1)

    def body(x, period_p):
        period_p = _cast(period_p, dt)

        def rec_body(x, rec_p):
            y, (h, conv) = rec_layer_apply(x, rec_p, cfg, last_idx=last_idx,
                                           valid_len=plen, policy=policy)
            return y, (h, conv.astype(jnp.float32))

        x, (hs, convs) = jax.lax.scan(rec_body, x, period_p["recs"],
                                      unroll=cfg.unroll_scans)
        x, (k, v) = attn_layer_apply(x, period_p["attn"], cfg, pos,
                                     kv_valid=kv_valid, policy=policy)
        if kv_valid is not None:
            # pad rows must not reach the decode cache (freed-slot hygiene)
            k = jnp.where(kv_valid[:, :, None, None], k, 0)
            v = jnp.where(kv_valid[:, :, None, None], v, 0)
        k, v = k[:, -win:], v[:, -win:]
        if cfg.sliding_window and s > cfg.sliding_window:
            # ring-buffer layout: slot = absolute position % window
            k = jnp.roll(k, s % cfg.sliding_window, axis=1)
            v = jnp.roll(v, s % cfg.sliding_window, axis=1)
        return x, {"rec_h": hs, "rec_conv": convs,
                   "k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n_per = cfg.n_layers // cfg.attn_period
    x, pcache = jax.lax.scan(body, x, params["periods"],
                             unroll=n_per if cfg.unroll_scans else 1)
    cache = {"periods": pcache}
    if tail:
        def tail_body(x, rec_p):
            y, (h, conv) = rec_layer_apply(x, rec_p, cfg, last_idx=last_idx,
                                           valid_len=plen, policy=policy)
            return y, {"h": h, "conv": conv.astype(jnp.float32)}
        x, tcache = jax.lax.scan(tail_body, x, _cast(params["tail"], dt),
                                 unroll=cfg.unroll_scans)
        cache["tail"] = tcache
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    if prompt_len is None:
        xl = x[:, -1:]
    else:
        idx = last_idx[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), cache


def _attn_chunk(h, p, cfg, ck, cv, off, clens, policy=None):
    """Chunk-prefill local attention: scatter the chunk's K/V into the
    ring cache at per-row cursor offsets, then attend the Q-chunk
    causally (window-masked) over the updated cache. Prefill positions
    never wrap the ring — prompts fit the window (the same invariant the
    monolithic ragged path enforces) — so cache slot == absolute
    position throughout prefill. Returns (attn_out, ck, cv)."""
    from repro.core.attention import attention
    b, c, _ = h.shape
    s = ck.shape[1]
    pos = off[:, None] + jnp.arange(c)[None, :]            # (B, C)
    q, k, v = _qkv(h, p, cfg, pos)
    lane = jnp.arange(c)[None, :] < clens[:, None]
    k = jnp.where(lane[:, :, None, None], k, 0)            # pad hygiene
    v = jnp.where(lane[:, :, None, None], v, 0)
    rows = jnp.where(lane, pos, s)                         # invalid -> drop
    ck = _write_chunk_kv(ck, k, rows, "bshd")
    cv = _write_chunk_kv(cv, v, rows, "bshd")
    kv_valid = jnp.arange(s)[None, :] < (off + clens)[:, None]
    o = attention(q, ck, cv, causal=True, window=cfg.sliding_window,
                  q_offset=off, exp_impl=cfg.exp_impl,
                  impl=cfg.attention_impl, unroll=cfg.unroll_scans,
                  block_k=cfg.attn_block_k, mm_dtype=cfg.attn_mm_dtype,
                  kv_valid=kv_valid, policy=policy)
    return o.reshape(b, c, -1) @ p["wo"], ck, cv


def _chunk_last_logits(params, cfg, x, last_idx):
    b = x.shape[0]
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    idx = last_idx[:, None, None]
    xl = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab)


def _prefill_chunk_impl(params, cfg, tokens, cache, off, clens, policy,
                        attn_fn):
    """Shared chunked-prefill driver: recurrent layers continue from the
    carried (h, conv) snapshots; the per-period attention layer is
    supplied by ``attn_fn(x_normed, period_attn_p, kv_leaves) ->
    (attn_out, new_kv_leaves)``. Rows with ``clens == 0`` are inert: the
    RG-LRU keeps its carried state explicitly (``last_idx`` would clamp
    to 0 and take one real recurrence step otherwise), the conv state
    gathers back its own left context, and KV writes park."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    b, c = tokens.shape
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)
    period, n_per, tail = _period_counts(cfg)
    last_idx = jnp.clip(clens - 1, 0, c - 1)
    alive = clens > 0

    def rec_chunk(x, rec_p, h, conv):
        y, (h_last, new_conv) = rec_layer_apply(
            x, rec_p, cfg, h0=h, conv_state=conv, last_idx=last_idx,
            valid_len=clens, policy=policy)
        h_last = jnp.where(alive[:, None], h_last, h)
        return y, (h_last, new_conv.astype(jnp.float32))

    def body(x, inp):
        period_p, pc = inp
        period_p = _cast(period_p, dt)

        def rec_body(x, rec_inp):
            rec_p, h, conv = rec_inp
            return rec_chunk(x, rec_p, h, conv)

        x, (hs, convs) = jax.lax.scan(
            rec_body, x, (period_p["recs"], pc["rec_h"], pc["rec_conv"]),
            unroll=cfg.unroll_scans)
        ap = period_p["attn"]
        h = norm_apply(x, ap["ln"], cfg.norm, cfg.norm_eps)
        a, kv = attn_fn(h, ap["attn"], {"k": pc["k"], "v": pc["v"]})
        x = x + a
        h2 = norm_apply(x, ap["ln_mlp"], cfg.norm, cfg.norm_eps)
        x = x + mlp_apply(h2, ap["mlp"], cfg.act, cfg.exp_impl)
        return x, {"rec_h": hs, "rec_conv": convs,
                   "k": kv["k"], "v": kv["v"]}

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, pcache = jax.lax.scan(body, x, (params["periods"], cache["periods"]),
                             unroll=n_per if cfg.unroll_scans else 1)
    new_cache = {"periods": pcache}
    if tail:
        def tail_body(x, rec_inp):
            rec_p, h, conv = rec_inp
            y, (h_last, conv2) = rec_chunk(x, rec_p, h, conv)
            return y, {"h": h_last, "conv": conv2}
        x, tcache = jax.lax.scan(
            tail_body, x, (_cast(params["tail"], dt), cache["tail"]["h"],
                           cache["tail"]["conv"]), unroll=cfg.unroll_scans)
        new_cache["tail"] = tcache
    return _chunk_last_logits(params, cfg, x, last_idx), new_cache


def prefill_chunk(params, cfg, tokens, cache, off, clens, *, policy=None):
    """Resumable chunked prefill over the contiguous hybrid cache: each
    recurrent layer continues from its carried (h, conv) snapshot and the
    local-attention layers write/attend the ring KV at per-row cursors.
    The RG-LRU combine tree depends on the scan length, so chunk widths
    must be pinned (scan-length-invariant) for run-to-run determinism;
    chunked output is token-identical — not bitwise — to one-shot prefill
    (whose combine tree spans the full padded width). Arguments and
    semantics as ``transformer.prefill_chunk``."""
    off = jnp.asarray(off, jnp.int32).reshape(-1)
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)

    def attn_fn(h, ap, kv):
        a, ck, cv = _attn_chunk(h, ap, cfg, kv["k"], kv["v"], off, clens,
                                policy=policy)
        return a, {"k": ck, "v": cv}

    return _prefill_chunk_impl(params, cfg, tokens, cache, off, clens,
                               policy, attn_fn)


def prefill_chunk_paged(params, cfg, tokens, cache, tables, off, clens, *,
                        policy=None):
    """Chunked prefill over a paged hybrid cache: recurrent snapshots as
    in ``prefill_chunk``; the chunk's K/V scatter into each slot's ring
    pages at its cursor and the Q-chunk attends the gathered pages.
    Prefill positions never wrap the ring (prompts fit the window), so
    ``tables[b, pos // page]`` is cursor-monotonic during prefill."""
    from repro.kernels.decode_attention.ops import paged_gather
    from repro.core.attention import attention
    b, c = tokens.shape
    off = jnp.asarray(off, jnp.int32).reshape(-1)
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)
    page = cache["periods"]["k"].shape[2]      # (n_per, N, page, Hkv, hd)
    n = cache["periods"]["k"].shape[1]
    ns = tables.shape[1]
    pos = off[:, None] + jnp.arange(c)[None, :]
    lane = jnp.arange(c)[None, :] < clens[:, None]
    cols = jnp.clip(pos // page, 0, ns - 1)
    gids = jnp.where(lane, tables[jnp.arange(b)[:, None], cols], n)
    inpage = jnp.where(lane, pos % page, 0)
    kv_valid = jnp.arange(ns * page)[None, :] < (off + clens)[:, None]

    def attn_fn(h, ap, kv):
        q, k, v = _qkv(h, ap, cfg, pos)
        k = jnp.where(lane[:, :, None, None], k, 0)
        v = jnp.where(lane[:, :, None, None], v, 0)
        pk = _write_chunk_kv_paged(kv["k"], k, gids, inpage, "bshd")
        pv = _write_chunk_kv_paged(kv["v"], v, gids, inpage, "bshd")
        kk = paged_gather(pk, tables, "bshd")
        vv = paged_gather(pv, tables, "bshd")
        o = attention(q, kk, vv, causal=True, window=cfg.sliding_window,
                      q_offset=off, exp_impl=cfg.exp_impl,
                      impl=cfg.attention_impl, unroll=cfg.unroll_scans,
                      block_k=cfg.attn_block_k, mm_dtype=cfg.attn_mm_dtype,
                      kv_valid=kv_valid, policy=policy)
        return o.reshape(h.shape[0], c, -1) @ ap["wo"], {"k": pk, "v": pv}

    return _prefill_chunk_impl(params, cfg, tokens, cache, off, clens,
                               policy, attn_fn)


def init_paged_cache(cfg, batch, n_pages, page, dtype=jnp.bfloat16):
    """Paged hybrid state: the recurrent leaves keep their slot axis
    (O(1) per slot — nothing to page), the local-attention KV leaves
    become slotless page pools (n_per, N, page, Hkv, hd), always "bshd".
    Every period indexes the same per-slot ring block table: each slot
    owns a fixed W/page pages for the life of its request and the write
    column wraps at the window — paging changes where the ring lives,
    not its semantics."""
    period, n_per, tail = _period_counts(cfg)
    w = cfg.lru_width or cfg.d_model
    cache = {"periods": {
        "rec_h": jnp.zeros((n_per, period - 1, batch, w), jnp.float32),
        "rec_conv": jnp.zeros((n_per, period - 1, batch,
                               cfg.conv_width - 1, w), jnp.float32),
        "k": jnp.zeros((n_per, n_pages, page, cfg.n_kv_heads, cfg.hd),
                       dtype),
        "v": jnp.zeros((n_per, n_pages, page, cfg.n_kv_heads, cfg.hd),
                       dtype),
    }}
    if tail:
        cache["tail"] = {
            "h": jnp.zeros((tail, batch, w), jnp.float32),
            "conv": jnp.zeros((tail, batch, cfg.conv_width - 1, w),
                              jnp.float32)}
    return cache


def attn_layer_decode_paged(x, p, cfg, pk, pv, tables, pos, wpos,
                            policy=None, live=None):
    """``attn_layer_decode`` against a page pool: the ring write lands in
    page ``tables[b, wpos // page]`` at offset ``wpos % page``; validity
    stays by-length (the ring holds exactly the window). ``live`` parks
    dead rows' writes at gid == N (droppable) — the write position can't
    be parked directly, a parked ``wpos // page`` would clamp back into
    the table."""
    from .transformer import _paged_attn, _write_token_kv_paged
    b = x.shape[0]
    page = pk.shape[1]
    h = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    q, k, v = _qkv(h, p["attn"], cfg, _rope_pos(b, pos))
    gids = tables[jnp.arange(b), wpos // page]
    drop = live is not None
    if drop:
        gids = jnp.where(jnp.asarray(live).reshape(-1) > 0, gids,
                         pk.shape[0])
    pk = _write_token_kv_paged(pk, k, gids, wpos % page, "bshd",
                               oob_drop=drop)
    pv = _write_token_kv_paged(pv, v, gids, wpos % page, "bshd",
                               oob_drop=drop)
    w = cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    valid = jnp.minimum(pos + 1, w) if w else pos + 1
    o = _paged_attn(q, pk, pv, tables, valid, cfg, policy, lay="bshd")
    x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"]
    h2 = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    x = x + mlp_apply(h2, p["mlp"], cfg.act, cfg.exp_impl)
    return x, pk, pv


def decode_step_paged(params, cfg, token, cache, tables, pos, *, policy=None,
                      live=None):
    """One decode step over a paged hybrid cache (see init_paged_cache).
    ``tables`` (B, W/page) int32 ring block table shared by every period;
    ``pos`` per-slot (B,) int32. ``live`` (B,) masks dead rows' state
    updates (recurrent snapshots kept, KV writes parked at gid == N)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dt)
    b = x.shape[0]
    period, n_per, tail = _period_counts(cfg)
    w = cfg.sliding_window
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    wpos = pos % w if w else pos
    keep = None if live is None else jnp.asarray(live).reshape(-1) > 0

    def body(x, inp):
        period_p, pc = inp
        period_p = _cast(period_p, dt)

        def rec_body(x, rec_inp):
            rec_p, h, conv = rec_inp
            y, new = rec_layer_decode(x, rec_p, cfg, {"h": h, "conv": conv},
                                      policy=policy)
            hnew, cnew = new["h"], new["conv"].astype(jnp.float32)
            if keep is not None:
                hnew = jnp.where(keep[:, None], hnew, h)
                cnew = jnp.where(keep[:, None, None], cnew, conv)
            return y, (hnew, cnew)

        x, (hs, convs) = jax.lax.scan(
            rec_body, x, (period_p["recs"], pc["rec_h"], pc["rec_conv"]),
            unroll=cfg.unroll_scans)
        x, pk, pv = attn_layer_decode_paged(x, period_p["attn"], cfg,
                                            pc["k"], pc["v"], tables, pos,
                                            wpos, policy=policy, live=live)
        return x, {"rec_h": hs, "rec_conv": convs, "k": pk, "v": pv}

    n_per = cfg.n_layers // cfg.attn_period
    x, pcache = jax.lax.scan(body, x, (params["periods"], cache["periods"]),
                             unroll=n_per if cfg.unroll_scans else 1)
    new_cache = {"periods": pcache}
    if tail:
        def tail_body(x, inp):
            rec_p, h, conv = inp
            y, new = rec_layer_decode(x, rec_p, cfg,
                                      {"h": h, "conv": conv}, policy=policy)
            hnew, cnew = new["h"], new["conv"].astype(jnp.float32)
            if keep is not None:
                hnew = jnp.where(keep[:, None], hnew, h)
                cnew = jnp.where(keep[:, None, None], cnew, conv)
            return y, {"h": hnew, "conv": cnew}
        x, tcache = jax.lax.scan(
            tail_body, x, (_cast(params["tail"], dt), cache["tail"]["h"],
                           cache["tail"]["conv"]), unroll=cfg.unroll_scans)
        new_cache["tail"] = tcache
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), new_cache


def decode_step(params, cfg, token, cache, pos, *, policy=None, live=None):
    """One decode step. ``pos`` is a scalar (whole batch at one position)
    or a per-slot (B,) vector — the continuous-batching engine's slots
    each advance independently through their own ring-buffer cursor.
    ``live`` (B,) masks dead rows' state updates: recurrent snapshots
    pass through bit-untouched and ring writes park at PARKED_POS."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dt)
    b = x.shape[0]
    period, n_per, tail = _period_counts(cfg)
    w = cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    wpos = pos % w if w else pos
    keep = None if live is None else jnp.asarray(live).reshape(-1) > 0
    drop = live is not None
    if drop:
        # Park AFTER the ring wrap — a post-modulo position is always in
        # range, so masking before the wrap would alias into the ring.
        wpos = jnp.where(keep,
                         jnp.broadcast_to(
                             jnp.asarray(wpos, jnp.int32).reshape(-1), (b,)),
                         PARKED_POS)

    def body(x, inp):
        period_p, pc = inp
        period_p = _cast(period_p, dt)

        def rec_body(x, rec_inp):
            rec_p, h, conv = rec_inp
            y, new = rec_layer_decode(x, rec_p, cfg, {"h": h, "conv": conv},
                                      policy=policy)
            hnew, cnew = new["h"], new["conv"].astype(jnp.float32)
            if keep is not None:
                hnew = jnp.where(keep[:, None], hnew, h)
                cnew = jnp.where(keep[:, None, None], cnew, conv)
            return y, (hnew, cnew)

        x, (hs, convs) = jax.lax.scan(
            rec_body, x, (period_p["recs"], pc["rec_h"], pc["rec_conv"]),
            unroll=cfg.unroll_scans)
        x, ck, cv = attn_layer_decode(x, period_p["attn"], cfg,
                                      pc["k"], pc["v"], pos, wpos,
                                      policy=policy, oob_drop=drop)
        return x, {"rec_h": hs, "rec_conv": convs, "k": ck, "v": cv}

    n_per = cfg.n_layers // cfg.attn_period
    x, pcache = jax.lax.scan(body, x, (params["periods"], cache["periods"]),
                             unroll=n_per if cfg.unroll_scans else 1)
    new_cache = {"periods": pcache}
    if tail:
        def tail_body(x, inp):
            rec_p, h, conv = inp
            y, new = rec_layer_decode(x, rec_p, cfg,
                                      {"h": h, "conv": conv}, policy=policy)
            hnew, cnew = new["h"], new["conv"].astype(jnp.float32)
            if keep is not None:
                hnew = jnp.where(keep[:, None], hnew, h)
                cnew = jnp.where(keep[:, None, None], cnew, conv)
            return y, {"h": hnew, "conv": cnew}
        x, tcache = jax.lax.scan(
            tail_body, x, (_cast(params["tail"], dt), cache["tail"]["h"],
                           cache["tail"]["conv"]), unroll=cfg.unroll_scans)
        new_cache["tail"] = tcache
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), new_cache
