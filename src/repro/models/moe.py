"""Mixture-of-Experts FFN (grok-1 / dbrx style) with vexp router softmax.

Routing uses a sort-free, per-row capacity dispatch designed for GSPMD:

  * router logits -> vexp softmax -> top-k experts per token,
  * each batch row independently buckets its tokens into (E, C) expert slots
    (C = seq * top_k / E * capacity_factor) via a rank-within-expert
    computed from cumulative sums — gathers stay *inside* the data shard,
  * expert FFN runs as batched einsum with the expert axis sharded on the
    `model` mesh axis (expert parallelism), or replicated with the hidden
    dim sharded (TP-in-expert) — selected by the sharding rules, not here,
  * results scatter back with the routing weights; dropped tokens (capacity
    overflow) fall back to a zero update (standard token-dropping MoE).

FLOP cost of dispatch/combine is O(T·E·C) bookkeeping integers + gathers —
negligible next to the expert matmuls, so the compiled roofline reflects
top-k active compute (verified in tests/test_moe.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.vexp import get_exp_fn
from repro.core.softmax import softmax as vexp_softmax
from .layers import dense_init, mlp_init, mlp_apply


def moe_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, cfg.n_experts + 1)
    experts = [mlp_init(ks[i], cfg.d_model, cfg.d_ff, cfg.act, dtype=dtype)
               for i in range(cfg.n_experts)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *experts)
    return {"router": dense_init(ks[-1], cfg.d_model, cfg.n_experts, dtype),
            "experts": stacked}


def _capacity(seq: int, cfg) -> int:
    c = int(math.ceil(seq * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)


def moe_apply(x, p, cfg):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)
    exp_fn = get_exp_fn(cfg.exp_impl)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = vexp_softmax(logits, axis=-1, exp_impl=exp_fn)        # (B,S,E)
    weights, experts_idx = jax.lax.top_k(probs, k)                # (B,S,k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style) + router z-loss.
    me = probs.mean(axis=(0, 1))                                  # (E,)
    ce_frac = jnp.zeros((e,)).at[experts_idx.reshape(-1)].add(
        jnp.ones(experts_idx.size)) / (b * s * k)
    aux = e * jnp.sum(me * ce_frac) * cfg.router_aux_coef
    lmax = logits.max(-1)
    zloss = 1e-3 * jnp.mean(
        (jnp.log(jnp.sum(exp_fn(logits - lmax[..., None]), -1)) + lmax) ** 2)

    # ---- per-row capacity dispatch (all indices local to a batch row) ----
    # flatten the k choices per token: (B, S*k)
    flat_expert = experts_idx.reshape(b, s * k)
    flat_weight = weights.reshape(b, s * k)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)      # (B,S*k,E)
    rank = jnp.cumsum(onehot, axis=1) - onehot                    # slot index
    rank = jnp.sum(rank * onehot, axis=-1)                        # (B, S*k)
    keep = rank < cap
    slot = flat_expert * cap + jnp.minimum(rank, cap - 1)         # (B, S*k)

    # gather tokens into (B, E*C, D) buckets via scatter of source indices
    src_token = jnp.tile(jnp.arange(s * k) // k, (b, 1))          # (B, S*k)
    bucket_src = jnp.full((b, e * cap), s, jnp.int32)             # s = dummy
    bucket_src = jax.vmap(
        lambda bs, sl, st, kp: bs.at[jnp.where(kp, sl, e * cap)].set(
            st, mode="drop"))(bucket_src, slot, src_token, keep)
    x_pad = jnp.concatenate(
        [x, jnp.zeros((b, 1, d), x.dtype)], axis=1)               # dummy row
    xe = jnp.take_along_axis(
        x_pad, bucket_src[..., None], axis=1)                     # (B,E*C,D)
    xe = xe.reshape(b, e, cap, d)

    # ---- expert FFN: batched over the (sharded) expert axis ----
    ye = _expert_mlp(xe, p["experts"], cfg)                       # (B,E,C,D)

    # ---- combine: scatter back with routing weights ----
    ye_flat = ye.reshape(b, e * cap, d)
    gathered = jnp.take_along_axis(
        ye_flat, jnp.where(keep, slot, 0)[..., None], axis=1)     # (B,S*k,D)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    out = (gathered.astype(jnp.float32)
           * flat_weight[..., None]).reshape(b, s, k, d).sum(2)
    return out.astype(x.dtype), {"moe_aux": aux, "moe_z": zloss}


def _expert_mlp(xe, experts, cfg):
    """xe: (B, E, C, D); experts: stacked pytree with leading E axis."""
    if cfg.act == "swiglu":
        exp_fn = get_exp_fn(cfg.exp_impl)
        from .layers import vexp_silu
        g = jnp.einsum("becd,edf->becf", xe, experts["wg"].astype(xe.dtype))
        u = jnp.einsum("becd,edf->becf", xe, experts["wu"].astype(xe.dtype))
        h = vexp_silu(g, exp_fn) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("becd,edf->becf", xe, experts["wu"].astype(xe.dtype)))
    return jnp.einsum("becf,efd->becd", h, experts["wd"].astype(xe.dtype))
