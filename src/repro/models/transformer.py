"""Decoder / encoder transformer covering the dense, moe, vlm and audio
families (command-r, danube3, phi3, stablelm, grok-1, dbrx, internvl2,
hubert) with GQA, RoPE, SwiGLU, sliding windows, parallel blocks, MoE FFNs,
modality-stub inputs, KV caches — all softmax/exp paths through VEXP.

Layers are stacked along a leading axis and executed with jax.lax.scan
(compile-time and HLO-size critical at 40-64 layers); each layer body is
optionally rematerialized.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.registry import hot_path
from repro.core.attention import attention, decode_attention
from .layers import (dense_init, embed_init, norm_init, norm_apply,
                     apply_rope, mlp_init, mlp_apply, cross_entropy,
                     mask_padded_logits)
from .moe import moe_init, moe_apply


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


# ------------------------------------------------------------------ attention

def attn_init(key, cfg, dtype=jnp.float32):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, hkv * hd, dtype),
         "wv": dense_init(ks[2], d, hkv * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype)}
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _qkv(x, p, cfg, pos):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if cfg.rope_pct > 0:
        q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.rope_pct)
    return q, k, v


def attn_apply(x, p, cfg, pos, *, window=None, causal=None, kv_valid=None,
               policy=None):
    """Full-sequence attention (train / prefill). Returns y, (k, v).

    ``policy`` (an ExecPolicy) selects exp backend + kernel backend +
    blocks; when None the cfg's legacy fields apply unchanged.
    ``kv_valid`` (B, S) masks padded prompt positions out of the keys.
    """
    causal = cfg.causal if causal is None else causal
    q, k, v = _qkv(x, p, cfg, pos)
    o = attention(q, k, v, causal=causal, window=window,
                  exp_impl=cfg.exp_impl, impl=cfg.attention_impl,
                  unroll=cfg.unroll_scans, block_k=cfg.attn_block_k,
                  mm_dtype=cfg.attn_mm_dtype, kv_valid=kv_valid,
                  policy=policy)
    return o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"], (k, v)


def cache_seq_axis(layout: str, stacked: bool = True) -> int:
    """Index of the sequence axis in a KV cache of the given layout.

    Stacked caches are (L, B, S, Hkv, hd) for "bshd" and (L, B, Hkv, S, hd)
    for "bhsd"; per-layer caches drop the leading L. Resolving the axis
    here (instead of hardcoding -3, which is only correct for "bshd")
    keeps every cache pad/insert site layout-correct.
    """
    if layout not in ("bshd", "bhsd"):
        raise ValueError(f"unknown kv cache layout {layout!r}")
    base = 1 if layout == "bshd" else 2
    return base + (1 if stacked else 0)


def cache_axes(cfg):
    """DecodeState leaf metadata: slot axis + layout-resolved sequence
    axis of each stacked KV-cache leaf (the slot engine's scatter spec)."""
    from .state_spec import LeafAxes
    ax = cache_seq_axis(cfg.kv_cache_layout)
    return {"k": LeafAxes(1, ax), "v": LeafAxes(1, ax)}


def _rope_pos(b, pos):
    """(B, 1) rope positions from a scalar or per-row (B,) position."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        return pos[:, None]
    return jnp.full((b, 1), pos, jnp.int32)


def _write_token_kv(cache, kv, pos, layout, *, oob_drop=False):
    """Write one token's K (or V) into the cache at ``pos``.

    kv: (B, 1, Hkv, hd) for "bshd" / (B, Hkv, 1, hd) for "bhsd".
    ``pos`` scalar writes one slice (dynamic_update_slice); a per-slot
    (B,) vector scatters each row at its own position, so ragged slots in
    a continuous batch never touch each other's cache rows.

    ``oob_drop`` makes out-of-range rows drop instead of clamp — the
    sequence-sharded decode path hands every shard the same write with
    *local* positions, and only the shard whose slice contains the token
    may land it (vector ``pos`` only). ``mode="drop"`` alone is not
    enough: scatter indices in ``[-S, 0)`` would *wrap* numpy-style
    before the drop logic sees them, so shards below the owner would
    land spurious rows — remap every out-of-slice position to S (a
    genuinely droppable index) first.
    """
    kv = kv.astype(cache.dtype)
    if jnp.ndim(pos) == 0:
        assert not oob_drop, "oob_drop needs a per-row position vector"
        ax = 2 if layout == "bhsd" else 1
        return jax.lax.dynamic_update_slice_in_dim(cache, kv, pos, axis=ax)
    kw = {}
    if oob_drop:
        s = cache.shape[2 if layout == "bhsd" else 1]
        pos = jnp.where((pos >= 0) & (pos < s), pos, s)
        kw = {"mode": "drop"}
    b = cache.shape[0]
    if layout == "bhsd":
        hkv = cache.shape[1]
        return cache.at[jnp.arange(b)[:, None],
                        jnp.arange(hkv)[None, :],
                        pos[:, None]].set(kv[:, :, 0], **kw)
    return cache.at[jnp.arange(b), pos].set(kv[:, 0], **kw)


def attn_decode(x, p, cfg, cache_k, cache_v, pos, *, window=None,
                policy=None, write_pos=None, oob_drop=False):
    """Single-token decode. cache_[kv]: (B, Smax, Hkv, hd) for "bshd"
    layout, (B, Hkv, Smax, hd) for "bhsd"; pos: scalar int or per-slot
    (B,) vector of current positions. Returns y, (new_k_cache,
    new_v_cache).

    ``write_pos`` (with ``oob_drop``) splits the write coordinate from the
    attention position: the serving engine parks dead / mid-chunk-prefill
    slots at a droppable sentinel so the step never mutates their cache
    rows while still computing (discarded) attention for them."""
    b = x.shape[0]
    lay = cfg.kv_cache_layout
    q, k, v = _qkv(x, p, cfg, _rope_pos(b, pos))
    if lay == "bhsd":
        k = k.transpose(0, 2, 1, 3)          # (B, Hkv, 1, hd) — tiny
        v = v.transpose(0, 2, 1, 3)
    wp = pos if write_pos is None else write_pos
    ck = _write_token_kv(cache_k, k, wp, lay, oob_drop=oob_drop)
    cv = _write_token_kv(cache_v, v, wp, lay, oob_drop=oob_drop)
    o = decode_attention(q, ck, cv, cache_len=pos + 1, window=window,
                         exp_impl=cfg.exp_impl, mm_dtype=cfg.attn_mm_dtype,
                         layout=lay, policy=policy)
    return o.reshape(b, 1, -1) @ p["wo"], (ck, cv)


# Droppable write sentinel for dead / mid-chunk-prefill slots: far above
# any cache extent, so an oob_drop scatter (which remaps >= S to the
# droppable index) never lands it. Must be applied AFTER any ring-buffer
# wrap — a post-modulo position is always in range.
PARKED_POS = jnp.int32(1 << 30)


def attn_decode_sharded(x, p, cfg, cache_k, cache_v, pos, *, seq_axis,
                        policy, write_pos=None):
    """Single-token decode over a sequence-sharded KV cache (call INSIDE
    ``shard_map``). ``cache_[kv]`` are each shard's *local* S-slice; every
    shard computes the token's K/V (tiny, replicated work), lands it with
    an out-of-bounds-dropping scatter at its local position — so exactly
    the shard whose slice contains ``pos`` writes — and sweeps its slice
    in partial-statistics mode; the shards fold through the policy's
    merge strategy (one packed all_gather, or pmax + 2×psum). The only
    collective of the whole step is that merge."""
    b = x.shape[0]
    lay = cfg.kv_cache_layout
    q, k, v = _qkv(x, p, cfg, _rope_pos(b, pos))
    if lay == "bhsd":
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
    s_ax = cache_seq_axis(lay, stacked=False)
    local_s = cache_k.shape[s_ax]
    off = jax.lax.axis_index(seq_axis) * local_s
    gpos = jnp.asarray(pos, jnp.int32)
    gw = gpos if write_pos is None else jnp.asarray(write_pos, jnp.int32)
    lpos = jnp.broadcast_to(gw.reshape(-1), (b,)) - off
    ck = _write_token_kv(cache_k, k, lpos, lay, oob_drop=True)
    cv = _write_token_kv(cache_v, v, lpos, lay, oob_drop=True)
    from repro.kernels.decode_attention.ops import \
        decode_attention_partial_merged
    o = decode_attention_partial_merged(
        q, ck, cv, gpos + 1, off, seq_axis=seq_axis, layout=lay,
        policy=policy)
    return o.reshape(b, 1, -1) @ p["wo"], (ck, cv)


def _attn_apply_hist(x, p, cfg, pos, hk, hv, *, suffix_valid=None,
                     policy=None):
    """Suffix attention against a prepended KV history (paged prefix-cache
    hot path): queries are the suffix tokens at absolute positions ``pos``
    (already offset by the history length), keys/values are
    ``[history | suffix]``. ``hk``/``hv`` (B, h, Hkv, hd) hold the shared
    prefix's already-roped KV gathered from the pool — always "bshd"
    regardless of ``cfg.kv_cache_layout``. Returns y and the *suffix-only*
    (k, v) (the prefix pages already exist; only the suffix is scattered
    back). The ``q_offset``/``kv_valid`` path demotes pallas to the flash
    scan inside ``attention`` — prefix-hot prefill is rare and short."""
    b, s, _ = x.shape
    h = hk.shape[1]
    q, k, v = _qkv(x, p, cfg, pos)
    kcat = jnp.concatenate([hk.astype(k.dtype), k], axis=1)
    vcat = jnp.concatenate([hv.astype(v.dtype), v], axis=1)
    kv_valid = None
    if suffix_valid is not None:
        kv_valid = jnp.concatenate(
            [jnp.ones((b, h), bool), suffix_valid], axis=1)
    o = attention(q, kcat, vcat, causal=True, window=None, q_offset=h,
                  exp_impl=cfg.exp_impl, impl=cfg.attention_impl,
                  unroll=cfg.unroll_scans, block_k=cfg.attn_block_k,
                  mm_dtype=cfg.attn_mm_dtype, kv_valid=kv_valid,
                  policy=policy)
    return o.reshape(b, s, -1) @ p["wo"], (k, v)


# --------------------------------------------------------------------- block

def block_init(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {"ln_attn": norm_init(cfg.d_model, cfg.norm),
         "attn": attn_init(ks[0], cfg, dtype)}
    if not cfg.parallel_block:
        p["ln_mlp"] = norm_init(cfg.d_model, cfg.norm)
    if cfg.n_experts:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                            cfg.use_bias, dtype)
    return p


def block_apply(x, p, cfg, pos, *, kv_valid=None, policy=None):
    """Returns (y, kv, aux)."""
    aux = {}
    h = norm_apply(x, p["ln_attn"], cfg.norm, cfg.norm_eps)
    a, kv = attn_apply(h, p["attn"], cfg, pos, window=cfg.sliding_window,
                       kv_valid=kv_valid, policy=policy)
    if cfg.parallel_block:
        # command-r: attention and FFN read the same normed input.
        if cfg.n_experts:
            m, aux = moe_apply(h, p["moe"], cfg)
        else:
            m = mlp_apply(h, p["mlp"], cfg.act, cfg.exp_impl, policy=policy)
        return x + a + m, kv, aux
    x = x + a
    h = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    if cfg.n_experts:
        m, aux = moe_apply(h, p["moe"], cfg)
    else:
        m = mlp_apply(h, p["mlp"], cfg.act, cfg.exp_impl, policy=policy)
    return x + m, kv, aux


def block_apply_hist(x, p, cfg, pos, hk, hv, *, suffix_valid=None,
                     policy=None):
    """``block_apply`` with a prepended KV history (see _attn_apply_hist).
    Returns (y, suffix_kv)."""
    h = norm_apply(x, p["ln_attn"], cfg.norm, cfg.norm_eps)
    a, kv = _attn_apply_hist(h, p["attn"], cfg, pos, hk, hv,
                             suffix_valid=suffix_valid, policy=policy)
    return _finish_block(x, h, a, p, cfg, policy=policy), kv


def block_decode(x, p, cfg, cache_k, cache_v, pos, *, policy=None):
    h = norm_apply(x, p["ln_attn"], cfg.norm, cfg.norm_eps)
    a, kv = attn_decode(h, p["attn"], cfg, cache_k, cache_v, pos,
                        window=cfg.sliding_window, policy=policy)
    if cfg.parallel_block:
        if cfg.n_experts:
            m, _ = moe_apply(h, p["moe"], cfg)
        else:
            m = mlp_apply(h, p["mlp"], cfg.act, cfg.exp_impl, policy=policy)
        return x + a + m, kv
    x = x + a
    h = norm_apply(x, p["ln_mlp"], cfg.norm, cfg.norm_eps)
    if cfg.n_experts:
        m, _ = moe_apply(h, p["moe"], cfg)
    else:
        m = mlp_apply(h, p["mlp"], cfg.act, cfg.exp_impl, policy=policy)
    return x + m, kv


# ---------------------------------------------------------------- full model

def init_params(cfg, key):
    ks = jax.random.split(key, cfg.n_layers + 4)
    layers = [block_init(ks[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    p = {"layers": stacked,
         "ln_f": norm_init(cfg.d_model, cfg.norm)}
    if cfg.family == "audio":
        # HuBERT's conv feature extractor and conv-relative positional
        # embedding are stubbed (precomputed frames + sinusoidal positions,
        # length-agnostic for the 32k-frame prefill shape).
        p["in_proj"] = dense_init(ks[-1], cfg.frame_input_dim, cfg.d_model)
        p["unembed"] = dense_init(ks[-3], cfg.d_model, cfg.vocab_padded)
        return p
    p["embed"] = embed_init(ks[-1], cfg.vocab_padded, cfg.d_model)
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[-2], cfg.d_model, cfg.vocab_padded)
    if cfg.family == "vlm":
        p["vis_proj"] = dense_init(ks[-3], cfg.vision_embed_dim, cfg.d_model)
    return p


def unembed_matrix(params, cfg):
    return (params["embed"].T if cfg.tie_embeddings
            else params["unembed"])


def embed_inputs(params, cfg, tokens, extra=None):
    """tokens (B, S_txt) int32; extra: vlm vision embeds (B, Nv, Dv) or
    audio frames (B, S, F). Returns (B, S, D) in compute dtype."""
    dt = _cdtype(cfg)
    if cfg.family == "audio":
        x = extra.astype(dt) @ params["in_proj"].astype(dt)
        s, d = x.shape[1], x.shape[2]
        pos = jnp.arange(s, dtype=jnp.float32)[:, None]
        inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, jnp.float32) / d))
        pe = jnp.concatenate([jnp.sin(pos * inv), jnp.cos(pos * inv)], -1)
        return x + pe.astype(dt)[None]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.family == "vlm" and extra is not None:
        vis = extra.astype(dt) @ params["vis_proj"].astype(dt)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward(params, cfg, tokens, extra=None, pos=None, *, policy=None):
    """Full-sequence forward to final hidden states (B, S, D) + aux."""
    x = embed_inputs(params, cfg, tokens, extra)
    b, s, _ = x.shape
    if pos is None:
        pos = jnp.arange(s)[None, :].astype(jnp.int32)
    dt = _cdtype(cfg)

    def body(carry, layer_p):
        x, aux_acc = carry
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        y, _, aux = block_apply(x, layer_p, cfg, pos, policy=policy)
        if aux:
            aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()}
        return (y, aux_acc), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    aux0 = ({"moe_aux": jnp.float32(0), "moe_z": jnp.float32(0)}
            if cfg.n_experts else {})
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"],
                               unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    return x, aux


def loss_fn(params, cfg, batch, *, policy=None):
    """Training loss. batch: {"tokens", "labels", optional "extra"}."""
    x, aux = forward(params, cfg, batch["tokens"], batch.get("extra"),
                     policy=policy)
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.family == "vlm" and batch.get("extra") is not None:
        x = x[:, batch["extra"].shape[1]:]       # loss on text positions only
    w = unembed_matrix(params, cfg)
    loss = cross_entropy(x, w, labels, chunk=cfg.loss_chunk,
                         exp_impl=cfg.exp_impl,
                         logit_softcap=cfg.logit_softcap, mask=mask,
                         unroll=cfg.unroll_scans, policy=policy)
    for v in (aux or {}).values():
        loss = loss + v / cfg.n_layers
    return loss


def init_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    """Stacked KV cache: (L, B, S, Hkv, hd) ("bshd") or (L, B, Hkv, S, hd)
    ("bhsd") ×2. Windowed archs allocate only the window (ring-buffer
    semantics handled by position clamping)."""
    s = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    if cfg.kv_cache_layout == "bhsd":
        shape = (cfg.n_layers, batch, cfg.n_kv_heads, s, cfg.hd)
    else:
        shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, cfg, tokens, extra=None, *, prompt_len=None, policy=None,
            hist=None):
    """Forward over the prompt; returns (last_logits, cache).

    ``prompt_len`` (B,) enables ragged right-padded batches: tokens beyond
    each row's length are padding — they are masked out of attention (no
    real token attends a pad, no pad pollutes the softmax normalizer),
    their K/V cache rows are zeroed, and the returned logits are each
    row's *last real* position (not the padded tail). Without it, every
    row is assumed full-length (the previous behaviour, unchanged).

    ``hist`` enables *suffix* prefill against a shared-prefix KV history
    (the paged engine's prefix-cache hot path): a stacked
    {"k": (L, B, h, Hkv, hd), "v": ...} of already-computed history KV
    (always "bshd", bf16). ``tokens`` are then only each row's suffix,
    attending causally over ``[history | suffix]`` at absolute positions
    ``h + i``; ``prompt_len`` counts *suffix* tokens; the returned cache
    and logits cover the suffix only. Linear caches only — a windowed
    arch's ring roll has no meaningful history split.
    """
    if prompt_len is not None and extra is not None:
        raise ValueError("prompt_len is only supported for token-only "
                         "prefill (no vlm/audio extra inputs)")
    if hist is not None and (extra is not None or cfg.sliding_window):
        raise ValueError("history-conditioned prefill requires a token-only "
                         "arch with a linear (non-windowed) cache")
    x = embed_inputs(params, cfg, tokens, extra)
    b, s, _ = x.shape
    if (prompt_len is not None and cfg.sliding_window
            and s > cfg.sliding_window):
        raise ValueError(
            f"ragged prefill of {s} tokens exceeds the sliding window "
            f"({cfg.sliding_window}): the ring-buffer roll is batch-"
            f"uniform; prefill ragged windowed batches at <= window")
    hlen = 0 if hist is None else hist["k"].shape[2]
    pos = (jnp.arange(s) + hlen)[None, :].astype(jnp.int32)
    kv_valid = None
    if prompt_len is not None:
        plen = jnp.asarray(prompt_len, jnp.int32).reshape(-1)
        kv_valid = jnp.arange(s)[None, :] < plen[:, None]        # (B, S)
    dt = _cdtype(cfg)

    def body(x, inp):
        layer_p = inp if hist is None else inp[0]
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        if hist is None:
            y, kv, _ = block_apply(x, layer_p, cfg, pos, kv_valid=kv_valid,
                                   policy=policy)
        else:
            y, kv = block_apply_hist(x, layer_p, cfg, pos, inp[1], inp[2],
                                     suffix_valid=kv_valid, policy=policy)
        k, v = kv
        if kv_valid is not None:
            # pad rows must not reach the decode cache: decode masks by
            # cache_len, but zeroing keeps freed/reused slots hygienic.
            k = jnp.where(kv_valid[:, :, None, None], k, 0)
            v = jnp.where(kv_valid[:, :, None, None], v, 0)
        if cfg.sliding_window and s > cfg.sliding_window:
            w = cfg.sliding_window
            # ring-buffer layout: absolute position p lives at slot p % w,
            # matching decode_step's write cursor.
            k = jnp.roll(k[:, -w:], s % w, axis=1)
            v = jnp.roll(v[:, -w:], s % w, axis=1)
        if cfg.kv_cache_layout == "bhsd":
            k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
        return y, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (params["layers"] if hist is None
          else (params["layers"], hist["k"], hist["v"]))
    x, cache = jax.lax.scan(body, x, xs,
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    if prompt_len is None:
        xl = x[:, -1:]
    else:
        idx = jnp.clip(plen - 1, 0, s - 1)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        unembed_matrix(params, cfg).astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), cache


# ------------------------------------------------------------ chunked prefill

def _write_chunk_kv(cache, kv, rows, layout):
    """Scatter a C-token chunk into per-row cache positions.

    kv: (B, C, Hkv, hd); rows: (B, C) absolute cache positions with
    invalid lanes pre-remapped to S (a droppable index); cache is one
    layer's slot pool row block — (B, S, Hkv, hd) "bshd" / (B, Hkv, S, hd)
    "bhsd"."""
    kv = kv.astype(cache.dtype)
    b, c = rows.shape
    if layout == "bhsd":
        hkv = cache.shape[1]
        return cache.at[jnp.arange(b)[:, None, None],
                        jnp.arange(hkv)[None, :, None],
                        rows[:, None, :]].set(kv.transpose(0, 2, 1, 3),
                                              mode="drop")
    return cache.at[jnp.arange(b)[:, None], rows].set(kv, mode="drop")


def _attn_chunk(x, p, cfg, ck, cv, off, clens, *, policy=None):
    """Chunk-prefill attention: write the chunk's K/V into the slot cache
    at per-row cursor offsets, then attend the Q-chunk causally over the
    *updated* cache — already-cached prefix and intra-chunk keys in one
    sweep, masked by per-row ``q_offset``/``kv_valid`` (the flash path;
    no new kernel). Returns y, (ck, cv)."""
    b, c, _ = x.shape
    lay = cfg.kv_cache_layout
    s = ck.shape[cache_seq_axis(lay, stacked=False)]
    pos = off[:, None] + jnp.arange(c)[None, :]            # (B, C)
    q, k, v = _qkv(x, p, cfg, pos)
    lane = jnp.arange(c)[None, :] < clens[:, None]         # (B, C)
    k = jnp.where(lane[:, :, None, None], k, 0)            # pad hygiene
    v = jnp.where(lane[:, :, None, None], v, 0)
    rows = jnp.where(lane, pos, s)                         # invalid -> drop
    ck = _write_chunk_kv(ck, k, rows, lay)
    cv = _write_chunk_kv(cv, v, rows, lay)
    kk, vv = ((ck, cv) if lay == "bshd"
              else (ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3)))
    # stale rows of a reused slot (and rows beyond this row's progress)
    # are masked out of both weights and normalizer.
    kv_valid = jnp.arange(s)[None, :] < (off + clens)[:, None]
    o = attention(q, kk, vv, causal=True, window=cfg.sliding_window,
                  q_offset=off, exp_impl=cfg.exp_impl,
                  impl=cfg.attention_impl, unroll=cfg.unroll_scans,
                  block_k=cfg.attn_block_k, mm_dtype=cfg.attn_mm_dtype,
                  kv_valid=kv_valid, policy=policy)
    return o.reshape(b, c, -1) @ p["wo"], (ck, cv)


def _chunk_logits(params, cfg, x, clens):
    """Last-valid-lane logits of a chunk program: (B, 1, V)."""
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    b, c, d = x.shape
    idx = jnp.clip(clens - 1, 0, c - 1)[:, None, None]
    xl = jnp.take_along_axis(x, jnp.broadcast_to(idx, (b, 1, d)), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        unembed_matrix(params, cfg).astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab)


def _chunk_all_logits(params, cfg, x):
    """Every-lane logits of a chunk program: (B, C, V). The batched
    speculative verify scores all k+1 candidate tokens from one chunk
    pass; lanes at or past a row's ``clens`` carry garbage the caller
    masks out of acceptance."""
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ldt),
                        unembed_matrix(params, cfg).astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab)


def prefill_chunk(params, cfg, tokens, cache, off, clens, *, policy=None,
                  all_lanes=False):
    """Resumable prefill: advance every prefilling slot by one fixed-size
    chunk, writing chunk KV directly into the slot-pool cache carry.

    tokens (B, C) int32; cache: the *pool* stacked KV (all slots); off
    (B,) per-slot progress cursors (tokens already cached); clens (B,)
    valid tokens in this chunk — 0 marks rows not prefilling this tick
    (decoding / free slots), which pass through bit-untouched. Returns
    (logits, cache): logits are each row's last-valid-lane next-token
    distribution, meaningful only for rows whose prompt completes with
    this chunk (off + clens == prompt_len). ``all_lanes=True``
    (speculative verify) returns (B, C, V) logits for every lane instead
    — lanes >= clens are garbage the caller masks."""
    x = embed_inputs(params, cfg, tokens)
    off = jnp.asarray(off, jnp.int32).reshape(-1)
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)
    dt = _cdtype(cfg)

    def body(x, inp):
        layer_p, ck, cv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        a, (ck, cv) = _attn_chunk(h, layer_p["attn"], cfg, ck, cv, off,
                                  clens, policy=policy)
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": ck, "v": cv}

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    if all_lanes:
        return _chunk_all_logits(params, cfg, x), cache
    return _chunk_logits(params, cfg, x, clens), cache


@hot_path
def decode_step(params, cfg, token, cache, pos, *, policy=None, live=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 or per-slot
    (B,) int32 (position of each row's token — the serving engine's slots
    advance independently); cache: stacked KV. Returns (logits,
    new_cache).

    ``live`` (B,) int32, serving only: rows with ``live == 0`` (free slots
    and slots mid-chunk-prefill) must not mutate their cache rows — their
    write position is parked at a droppable sentinel. Their (garbage)
    logits are discarded by the engine as before."""
    x = embed_inputs(params, cfg, token)
    dt = _cdtype(cfg)
    # Windowed caches are sized `window`; write position wraps.
    wpos = (pos % cfg.sliding_window) if cfg.sliding_window else pos
    drop = live is not None
    if drop:
        # Park AFTER the ring wrap: a post-modulo position is always in
        # range, so masking before the wrap would alias back into the ring.
        b = token.shape[0]
        wpos = jnp.where(jnp.asarray(live).reshape(-1) > 0,
                         jnp.broadcast_to(
                             jnp.asarray(wpos, jnp.int32).reshape(-1), (b,)),
                         PARKED_POS)

    def body(x, inp):
        layer_p, ck, cv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        if cfg.sliding_window:
            # ring buffer: write at wpos; effective length = min(pos+1, W).
            k, v, q = _qkv_single(x, layer_p, cfg, pos)
            if cfg.kv_cache_layout == "bhsd":
                k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
            ck = _write_token_kv(ck, k, wpos, cfg.kv_cache_layout,
                                 oob_drop=drop)
            cv = _write_token_kv(cv, v, wpos, cfg.kv_cache_layout,
                                 oob_drop=drop)
            h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
            y, _ = _decode_windowed(h, layer_p, cfg, ck, cv, pos, wpos,
                                    policy=policy)
            x = _finish_block(x, h, y, layer_p, cfg, policy=policy)
            return x, {"k": ck, "v": cv}
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        a, (ck, cv) = attn_decode(h, layer_p["attn"], cfg, ck, cv, pos,
                                  policy=policy, write_pos=wpos,
                                  oob_drop=drop)
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": ck, "v": cv}

    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    return _final_logits(params, cfg, x), cache


def _final_logits(params, cfg, x):
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ldt),
                        unembed_matrix(params, cfg).astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab)


@hot_path
def decode_step_sharded(params, cfg, token, cache, pos, *, policy, seq_axis,
                        live=None):
    """One decode step over a sequence-sharded KV cache — the body the
    serving engine wraps in ``shard_map`` (params/token/pos replicated,
    cache sharded along its S axis over ``seq_axis``).

    Per layer: the token's K/V land on exactly the shard owning position
    ``pos`` (drop-mode scatter at local coordinates), each shard sweeps
    its slice in partial-statistics mode, and the statistics fold through
    ``policy.merge_strategy`` — with "packed" that is ONE collective per
    layer; everything outside attention is replicated compute. Windowed
    (ring-buffer) archs keep the GSPMD path: the wrap-around write
    straddles shard boundaries.
    """
    if cfg.sliding_window:
        raise NotImplementedError(
            "sequence-sharded decode covers linear caches; windowed "
            "ring-buffer caches decode through the GSPMD path")
    x = embed_inputs(params, cfg, token)
    dt = _cdtype(cfg)
    wpos = None
    if live is not None:
        # Dead / mid-chunk-prefill rows: every shard sees a parked global
        # position, localizes it out of its slice, and drops the write.
        b = token.shape[0]
        wpos = jnp.where(jnp.asarray(live).reshape(-1) > 0,
                         jnp.broadcast_to(
                             jnp.asarray(pos, jnp.int32).reshape(-1), (b,)),
                         PARKED_POS)

    def body(x, inp):
        layer_p, ck, cv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        a, (ck, cv) = attn_decode_sharded(h, layer_p["attn"], cfg, ck, cv,
                                          pos, seq_axis=seq_axis,
                                          policy=policy, write_pos=wpos)
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": ck, "v": cv}

    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    return _final_logits(params, cfg, x), cache


def _qkv_single(x, layer_p, cfg, pos):
    h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
    b = x.shape[0]
    q, k, v = _qkv(h, layer_p["attn"], cfg, _rope_pos(b, pos))
    return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16), q


def _decode_windowed(h, layer_p, cfg, ck, cv, pos, wpos, *, policy=None):
    """Windowed ring-buffer decode: all cache slots valid once pos >= W."""
    b = h.shape[0]
    q, _, _ = _qkv(h, layer_p["attn"], cfg, _rope_pos(b, pos))
    w = cfg.sliding_window
    valid = jnp.minimum(pos + 1, w)
    o = decode_attention(q, ck, cv, cache_len=valid, exp_impl=cfg.exp_impl,
                         mm_dtype=cfg.attn_mm_dtype,
                         layout=cfg.kv_cache_layout, policy=policy)
    return o.reshape(b, 1, -1) @ layer_p["attn"]["wo"], None


# ------------------------------------------------------------- paged decode

def init_paged_cache(cfg, n_pages, page, dtype=jnp.bfloat16):
    """Paged KV pool: (L, N, page, Hkv, hd) ("bshd") / (L, N, Hkv, page, hd)
    ("bhsd") ×2. Unlike ``init_cache`` there is no slot axis — physical
    pages are handed to slots by the host-side ``BlockAllocator`` through
    per-slot block tables; page 0 is the reserved scratch page every
    unassigned table entry points at."""
    if cfg.kv_cache_layout == "bhsd":
        shape = (cfg.n_layers, n_pages, cfg.n_kv_heads, page, cfg.hd)
    else:
        shape = (cfg.n_layers, n_pages, page, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _write_token_kv_paged(pool, kv, gids, offs, layout, *, oob_drop=False):
    """Scatter one token's K (or V) per slot into its physical page.

    pool: (N, page, Hkv, hd) "bshd" / (N, Hkv, page, hd) "bhsd"; kv as in
    ``_write_token_kv``; ``gids``/``offs`` (B,) physical page id and
    in-page offset per slot. Dead slots point at the reserved scratch
    page — their writes collide there harmlessly (scratch is never part
    of any live sweep's masked-in range). ``oob_drop``: the sharded path
    remaps non-owned rows to gid == N, a genuinely droppable index.

    The (page, offset) coordinates are flattened to one row index into a
    reshaped pool: a single-index-array scatter vectorizes on CPU/XLA
    where the equivalent multi-array advanced-index scatter scalarizes
    (~2x the decode-step overhead of the whole indirection)."""
    kv = kv.astype(pool.dtype)
    kw = {"mode": "drop"} if oob_drop else {}
    if layout == "bhsd":
        n, hkv, page, hd = pool.shape
        idx = (gids[:, None] * hkv + jnp.arange(hkv)[None, :]) * page \
            + offs[:, None]
        flat = pool.reshape(n * hkv * page, hd)
        return flat.at[idx].set(kv[:, :, 0], **kw).reshape(pool.shape)
    n, page = pool.shape[0], pool.shape[1]
    flat = pool.reshape((n * page,) + pool.shape[2:])
    return flat.at[gids * page + offs].set(kv[:, 0], **kw).reshape(pool.shape)


def _paged_attn(q, pool_k, pool_v, tab, cache_len, cfg, policy, lay=None):
    """Policy-routed paged sweep: pallas drives the page DMA from the
    table inside the kernel; reference/xla (and the policy-less legacy
    path) gather the table into a contiguous cache first — identical
    semantics, the oracle the kernel is tested against. ``lay`` overrides
    ``cfg.kv_cache_layout`` (the hybrid family's pools are always
    "bshd")."""
    lay = lay or cfg.kv_cache_layout
    if policy is not None:
        from repro.kernels.dispatch import dispatch as k_dispatch
        return k_dispatch("decode_attention_paged", policy)(
            q, pool_k, pool_v, tab, cache_len, window=None, sm_scale=None,
            layout=lay, policy=policy)
    from repro.kernels.decode_attention.ops import paged_gather
    k = paged_gather(pool_k, tab, lay)
    v = paged_gather(pool_v, tab, lay)
    return decode_attention(q, k, v, cache_len=cache_len,
                            exp_impl=cfg.exp_impl,
                            mm_dtype=cfg.attn_mm_dtype, layout=lay)


@hot_path
def decode_step_paged(params, cfg, token, cache, tables, pos, *, policy=None,
                      live=None):
    """One decode step over a paged KV pool. token: (B, 1) int32; cache:
    stacked pools from ``init_paged_cache``; ``tables`` (B, nS) int32
    block table shared by every layer (each layer's pool is indexed by
    the same logical->physical map); pos: per-slot (B,) int32. Returns
    (logits, new_cache) — tables are read-only here; the host allocator
    updates them only at scheduling events.

    Windowed archs run ring-buffer paging: each slot owns a fixed table
    of W/page pages, the write column wraps at W and validity is by
    length only — same semantics as ``decode_step``'s ring cache."""
    x = embed_inputs(params, cfg, token)
    b = x.shape[0]
    dt = _cdtype(cfg)
    lay = cfg.kv_cache_layout
    page = cache["k"].shape[3 if lay == "bhsd" else 2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    if cfg.sliding_window:
        w = cfg.sliding_window
        wpos, clen = pos % w, jnp.minimum(pos + 1, w)
    else:
        wpos, clen = pos, pos + 1
    gids = tables[jnp.arange(b), wpos // page]
    offs = wpos % page
    drop = live is not None
    if drop:
        # Dead / mid-chunk-prefill rows write to gid == N — droppable.
        gids = jnp.where(jnp.asarray(live).reshape(-1) > 0, gids,
                         cache["k"].shape[1])

    def body(x, inp):
        layer_p, pk, pv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(h, layer_p["attn"], cfg, _rope_pos(b, pos))
        if lay == "bhsd":
            k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
        pk = _write_token_kv_paged(pk, k, gids, offs, lay, oob_drop=drop)
        pv = _write_token_kv_paged(pv, v, gids, offs, lay, oob_drop=drop)
        o = _paged_attn(q, pk, pv, tables, clen, cfg, policy)
        a = o.reshape(b, 1, -1) @ layer_p["attn"]["wo"]
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": pk, "v": pv}

    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    return _final_logits(params, cfg, x), cache


@hot_path
def decode_step_paged_sharded(params, cfg, token, cache, tables, pos, *,
                              policy, seq_axis, live=None):
    """Paged decode over a sequence-sharded pool — the body the serving
    engine wraps in ``shard_map``. The pool's page axis is sharded over
    ``seq_axis``; ``tables`` is each shard's (B, nS_local) slice holding
    *local* page ids (logical page column j lives on shard j // nS_local
    by the allocator's partitioning). The token's K/V land on exactly the
    owning shard (drop-mode page scatter), each shard sweeps its local
    pages in partial-statistics mode and the statistics fold through
    ``policy.merge_strategy`` — one collective per layer when packed."""
    if cfg.sliding_window:
        raise NotImplementedError(
            "sequence-sharded paged decode covers linear caches; windowed "
            "ring tables decode through the unsharded paged path")
    x = embed_inputs(params, cfg, token)
    b = x.shape[0]
    dt = _cdtype(cfg)
    lay = cfg.kv_cache_layout
    page = cache["k"].shape[3 if lay == "bhsd" else 2]
    n_local = cache["k"].shape[1]
    ns_local = tables.shape[1]
    s_local = ns_local * page
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    off = jax.lax.axis_index(seq_axis) * s_local
    lp = pos - off
    own = (lp >= 0) & (lp < s_local)
    if live is not None:
        own &= jnp.asarray(live).reshape(-1) > 0
    lpc = jnp.clip(lp, 0, s_local - 1)
    gids = jnp.where(own, tables[jnp.arange(b), lpc // page], n_local)
    offs = jnp.where(own, lpc % page, 0)
    from repro.kernels.decode_attention.ops import \
        decode_attention_paged_partial_merged

    def body(x, inp):
        layer_p, pk, pv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(h, layer_p["attn"], cfg, _rope_pos(b, pos))
        if lay == "bhsd":
            k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
        pk = _write_token_kv_paged(pk, k, gids, offs, lay, oob_drop=True)
        pv = _write_token_kv_paged(pv, v, gids, offs, lay, oob_drop=True)
        o = decode_attention_paged_partial_merged(
            q, pk, pv, tables, pos + 1, off, seq_axis=seq_axis, layout=lay,
            policy=policy)
        a = o.reshape(b, 1, -1) @ layer_p["attn"]["wo"]
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": pk, "v": pv}

    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    return _final_logits(params, cfg, x), cache


def _write_chunk_kv_paged(pool, kv, gids, inpage, layout):
    """Scatter a C-token chunk into physical pages.

    kv: (B, C, Hkv, hd); gids/inpage: (B, C) physical page id and in-page
    offset per token, with invalid lanes pre-remapped to gid == N
    (droppable). Same flattened single-index scatter as the decode-step
    write."""
    kv = kv.astype(pool.dtype)
    kw = {"mode": "drop"}
    if layout == "bhsd":
        n, hkv, page, hd = pool.shape
        idx = (gids[:, :, None] * hkv
               + jnp.arange(hkv)[None, None, :]) * page + inpage[:, :, None]
        flat = pool.reshape(n * hkv * page, hd)
        return flat.at[idx].set(kv, **kw).reshape(pool.shape)
    n, page = pool.shape[0], pool.shape[1]
    flat = pool.reshape((n * page,) + pool.shape[2:])
    return flat.at[gids * page + inpage].set(kv, **kw).reshape(pool.shape)


def prefill_chunk_paged(params, cfg, tokens, cache, tables, off, clens, *,
                        policy=None, all_lanes=False):
    """Resumable prefill over a paged KV pool: the chunk's K/V scatter
    into each slot's reserved pages at its cursor, then the Q-chunk
    attends causally over the slot's gathered pages — shared-prefix pages
    (attached read-only at admission; the cursor starts past them) and
    intra-chunk keys included. Linear caches only; windowed ring tables
    admit monolithically. Arguments as ``prefill_chunk`` plus ``tables``
    (B, nS) physical page tables. Returns (logits, cache);
    ``all_lanes=True`` (speculative verify) returns every lane's
    logits."""
    from repro.kernels.decode_attention.ops import paged_gather
    x = embed_inputs(params, cfg, tokens)
    b, c, _ = x.shape
    off = jnp.asarray(off, jnp.int32).reshape(-1)
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)
    dt = _cdtype(cfg)
    lay = cfg.kv_cache_layout
    page = cache["k"].shape[3 if lay == "bhsd" else 2]
    n = cache["k"].shape[1]
    ns = tables.shape[1]
    pos = off[:, None] + jnp.arange(c)[None, :]            # (B, C)
    lane = jnp.arange(c)[None, :] < clens[:, None]
    cols = jnp.clip(pos // page, 0, ns - 1)
    gids = jnp.where(lane, tables[jnp.arange(b)[:, None], cols], n)
    inpage = jnp.where(lane, pos % page, 0)
    kv_valid = (jnp.arange(ns * page)[None, :]
                < (off + clens)[:, None])                  # (B, nS*page)

    def body(x, inp):
        layer_p, pk, pv = inp
        layer_p = jax.tree.map(lambda a: a.astype(dt)
                               if a.dtype == jnp.float32 and a.ndim > 1
                               else a, layer_p)
        h = norm_apply(x, layer_p["ln_attn"], cfg.norm, cfg.norm_eps)
        q, k, v = _qkv(h, layer_p["attn"], cfg, pos)
        k = jnp.where(lane[:, :, None, None], k, 0)
        v = jnp.where(lane[:, :, None, None], v, 0)
        pk = _write_chunk_kv_paged(pk, k, gids, inpage, lay)
        pv = _write_chunk_kv_paged(pv, v, gids, inpage, lay)
        kk = paged_gather(pk, tables, lay)
        vv = paged_gather(pv, tables, lay)
        if lay == "bhsd":
            kk, vv = kk.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3)
        o = attention(q, kk, vv, causal=True, window=None, q_offset=off,
                      exp_impl=cfg.exp_impl, impl=cfg.attention_impl,
                      unroll=cfg.unroll_scans, block_k=cfg.attn_block_k,
                      mm_dtype=cfg.attn_mm_dtype, kv_valid=kv_valid,
                      policy=policy)
        a = o.reshape(b, c, -1) @ layer_p["attn"]["wo"]
        x = _finish_block(x, h, a, layer_p, cfg, policy=policy)
        return x, {"k": pk, "v": pv}

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, cache = jax.lax.scan(body, x, (params["layers"],
                                      cache["k"], cache["v"]),
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    if all_lanes:
        return _chunk_all_logits(params, cfg, x), cache
    return _chunk_logits(params, cfg, x, clens), cache


def _finish_block(x, h, a, layer_p, cfg, *, policy=None):
    if cfg.parallel_block:
        if cfg.n_experts:
            m, _ = moe_apply(h, layer_p["moe"], cfg)
        else:
            m = mlp_apply(h, layer_p["mlp"], cfg.act, cfg.exp_impl,
                          policy=policy)
        return x + a + m
    x = x + a
    h2 = norm_apply(x, layer_p["ln_mlp"], cfg.norm, cfg.norm_eps)
    if cfg.n_experts:
        m, _ = moe_apply(h2, layer_p["moe"], cfg)
    else:
        m = mlp_apply(h2, layer_p["mlp"], cfg.act, cfg.exp_impl,
                      policy=policy)
    return x + m
