"""Per-leaf axis metadata for decode-state pytrees (DecodeState protocol).

Every family's decode state — a transformer KV cache, an SSM's per-layer
``(h, conv)`` snapshots, a hybrid's mixed periods — is a pytree of arrays
in which each leaf has one *slot* (batch) axis and at most one *sequence*
axis. That is all the slot engine needs to know to scatter admitted rows
into a pool, pad a full-pool prefill out to capacity, or zero a freed
slot; the per-family ``cache_axes()/state_axes()`` functions next to each
family's ``init_cache`` return a pytree of ``LeafAxes`` matching the
state's structure, and ``models.decode_state`` drives the generic ops.

``LeafAxes`` is deliberately *not* registered as a pytree node so it
survives ``jax.tree.map`` as a leaf (a plain tuple would be flattened).
"""

from __future__ import annotations

from typing import Optional


class LeafAxes:
    """Axis roles of one decode-state leaf.

    batch  index of the slot (pool/batch) axis.
    seq    index of the sequence axis, or None for per-slot snapshots
           (recurrent ``h``/``conv`` state has no sequence extent).
    """

    __slots__ = ("batch", "seq")

    def __init__(self, batch: int, seq: Optional[int] = None):
        self.batch = batch
        self.seq = seq

    def __repr__(self):
        return f"LeafAxes(batch={self.batch}, seq={self.seq})"
