"""Refcounted free-list page allocator + shared-prefix page cache.

The paged DecodeState stores KV in fixed-size physical *pages* behind a
per-slot block table (``kernels.decode_attention`` drives the page DMA
from the table). This module is the host-side bookkeeping for that pool:

``BlockAllocator``
    Free lists + refcounts over the pool's physical page ids. The
    authoritative state is host-side and is mutated only at *scheduling
    events* (admission, finish, cache eviction) — exactly like the
    serving engine's ``lens``/``ntok`` mirrors — so the decode hot loop
    stays zero-host-sync: the device only ever sees the (B, nS) int32
    tables the state scatters at admission, and nothing is ever read
    back. Pages are refcounted so several slots (and the prefix cache)
    can reference one physical page; a page returns to the free list
    when its last reference drops.

    Sequence-sharded pools partition the page ids: logical page column
    ``j`` must be served by partition ``j // cols_per_part`` (the shard
    owning that slice of the table), so each partition keeps its own
    free list. An unsharded pool is the 1-partition special case.

    Page id 0 of every partition is RESERVED (never allocated): block
    tables must always point at a *valid* page — the kernel's index map
    fetches unconditionally and masks compute by ``cache_len`` — so
    unassigned table entries and dead-slot writes all land on the
    partition's scratch page.

``PrefixCache``
    Content-addressed sharing of *full* prompt pages: a hash chain over
    page-sized token runs (h_i = H(h_{i-1}, tokens[i*page:(i+1)*page]))
    keyed to the physical page holding that run's KV. A request whose
    prompt prefix hashes onto cached pages attaches to them (refcount++,
    zero prefill compute/storage for the shared prefix); pages are
    shared at page granularity, so a slot can never write a shared page
    — decode writes only at positions >= its prompt length, which lie in
    pages past every full (hashable) page. True divergence *within* a
    page is a hash miss, i.e. a private copy from the start — the
    copy-on-write discipline degenerates to copy-on-admission, and
    ``BlockAllocator.cow`` covers the remaining defensive case (a writer
    holding a page whose refcount > 1 must clone before writing).

    The cache holds one reference of its own per cached page, so cached
    prefixes survive the slot that created them. Under allocation
    pressure the allocator asks the cache to evict: least-recently-used
    chains release their cache reference deepest-page-first (a page is
    only unreachable once its descendants are), which frees the page
    immediately if no live slot still holds it — live state is never
    evicted, only the cache's claim on it.
"""

from __future__ import annotations

import hashlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class BlockPoolError(RuntimeError):
    pass


class OutOfBlocks(BlockPoolError):
    """Allocation failed even after cache eviction."""


class BlockAllocator:
    """Host-authoritative refcounted page allocator over global page ids
    ``[0, n_pages)``; partition ``p`` owns ids
    ``[p * per_part, (p+1) * per_part)`` with local id 0 reserved."""

    def __init__(self, n_pages: int, *, n_partitions: int = 1,
                 cols_per_part: Optional[int] = None):
        if n_pages % n_partitions:
            raise ValueError(f"n_pages={n_pages} not divisible by "
                             f"n_partitions={n_partitions}")
        self.n_pages = n_pages
        self.n_partitions = n_partitions
        self.per_part = n_pages // n_partitions
        if self.per_part < 2:
            raise ValueError("each partition needs >= 2 pages (one is the "
                             "reserved scratch page)")
        # table column -> partition (sharded tables slice columns evenly)
        self.cols_per_part = cols_per_part
        self.refs = np.zeros(n_pages, np.int64)
        # lowest-id-first free lists keep allocation deterministic
        self._free: List[List[int]] = [
            sorted(range(p * self.per_part + 1, (p + 1) * self.per_part),
                   reverse=True)
            for p in range(n_partitions)]
        # eviction hook wired by PrefixCache: evict_cb(partition, n) must
        # try to release >= n pages of that partition; returns #released.
        self._evict_cb: Optional[Callable[[int, int], int]] = None
        # chaos harness (ft.inject): when set, _alloc_one consults it for
        # forced OutOfBlocks — mid-alloc_cols, so every rollback path
        # upstream (all-or-nothing release, attach decref, wave requeue)
        # is exercised, not just the clean "pool actually full" case.
        self.injector = None

    # ------------------------------------------------------------ queries

    def part_of_col(self, col: int) -> int:
        """Partition owning logical table column ``col``."""
        if self.cols_per_part is None:
            return 0
        return col // self.cols_per_part

    def part_of(self, gid: int) -> int:
        return gid // self.per_part

    def local_id(self, gid: int) -> int:
        """Partition-local id (what a sharded table stores)."""
        return gid % self.per_part

    def scratch_id(self, part: int = 0) -> int:
        return part * self.per_part

    def free_counts(self) -> np.ndarray:
        return np.array([len(f) for f in self._free], np.int64)

    def n_free(self) -> int:
        return int(sum(len(f) for f in self._free))

    def n_used(self) -> int:
        """Allocated (ref > 0) pages, excluding the reserved scratch."""
        return int((self.refs > 0).sum())

    def refcount(self, gid: int) -> int:
        return int(self.refs[gid])

    # -------------------------------------------------------- alloc / free

    def _alloc_one(self, part: int) -> int:
        if self.injector is not None and \
                self.injector.fire("alloc.out_of_blocks"):
            raise OutOfBlocks(f"partition {part}: injected allocation fault")
        if not self._free[part]:
            if self._evict_cb is not None:
                self._evict_cb(part, 1)
            if not self._free[part]:
                raise OutOfBlocks(
                    f"partition {part}: no free pages "
                    f"({self.per_part - 1} allocatable)")
        gid = self._free[part].pop()
        self.refs[gid] = 1
        return gid

    def alloc_cols(self, cols) -> List[int]:
        """Allocate one fresh page per logical table column (ref = 1).
        All-or-nothing: on failure every page of this call is released."""
        got: List[int] = []
        try:
            for c in cols:
                got.append(self._alloc_one(self.part_of_col(int(c))))
        except OutOfBlocks:
            for gid in got:
                self.decref(gid)
            raise
        return got

    def can_alloc_cols(self, cols) -> bool:
        need = np.zeros(self.n_partitions, np.int64)
        for c in cols:
            need[self.part_of_col(int(c))] += 1
        return bool((need <= self.free_counts()).all())

    def incref(self, gid: int) -> None:
        if self.refs[gid] <= 0:
            raise BlockPoolError(f"incref of unallocated page {gid}")
        self.refs[gid] += 1

    def decref(self, gid: int) -> None:
        if gid % self.per_part == 0:
            raise BlockPoolError(f"page {gid} is the reserved scratch page")
        if self.refs[gid] <= 0:
            raise BlockPoolError(f"double free of page {gid}")
        self.refs[gid] -= 1
        if self.refs[gid] == 0:
            self._free[self.part_of(gid)].append(gid)

    def cow(self, gid: int) -> int:
        """Copy-on-write: called by a writer about to mutate ``gid``.
        Refcount 1 means exclusive ownership — write in place (returns
        ``gid``). Otherwise allocate a fresh page in the same partition,
        drop one reference on the shared page and return the new id; the
        caller must copy the page's contents device-side before writing."""
        if self.refs[gid] <= 0:
            raise BlockPoolError(f"cow of unallocated page {gid}")
        if self.refs[gid] == 1:
            return gid
        new = self._alloc_one(self.part_of(gid))
        # decref, not a raw decrement: _alloc_one may have run the
        # eviction hook, which can drop the cache's reference on ``gid``
        # mid-call — the release here may then be the LAST reference and
        # the page must return to the free list.
        self.decref(gid)
        return new

    def check(self) -> None:
        """Internal-consistency invariants (property tests)."""
        free = sorted(g for f in self._free for g in f)
        assert all(self.refs[g] == 0 for g in free), "free page with refs"
        assert len(set(free)) == len(free), "page double-listed as free"
        live = [g for g in range(self.n_pages)
                if self.refs[g] > 0 or g % self.per_part == 0]
        assert len(free) + len(live) == self.n_pages, "page leaked"


class PrefixCache:
    """Content-addressed full-page prompt sharing over a BlockAllocator."""

    def __init__(self, alloc: BlockAllocator, page: int):
        self.alloc = alloc
        self.page = page
        # chain hash -> (gid, depth, parent_hash)
        self._entries: Dict[bytes, Tuple[int, int, Optional[bytes]]] = {}
        self._children: Dict[bytes, int] = {}    # hash -> #cached children
        self._last_use: Dict[bytes, int] = {}
        self._clock = 0
        self.hits = self.misses = self.hit_tokens = self.evictions = 0
        alloc._evict_cb = self._evict_for

    # ------------------------------------------------------------- hashing

    def chain(self, tokens: np.ndarray) -> List[bytes]:
        """Hash chain over the prompt's *full* pages (len // page of them):
        h_i commits to every token in pages 0..i, so equal hashes mean an
        identical prefix through page i."""
        toks = np.asarray(tokens, np.int32)
        n_full = len(toks) // self.page
        out, h = [], b""
        for i in range(n_full):
            blk = toks[i * self.page:(i + 1) * self.page]
            h = hashlib.blake2b(h + blk.tobytes(), digest_size=16).digest()
            out.append(h)
        return out

    # -------------------------------------------------------------- lookup

    def probe(self, tokens: np.ndarray) -> int:
        """Longest cached prefix, in pages. No side effects."""
        n = 0
        for h in self.chain(tokens):
            if h not in self._entries:
                break
            n += 1
        return n

    def hit_gids(self, tokens: np.ndarray,
                 max_pages: Optional[int] = None) -> List[int]:
        """Gids of the longest cached prefix's pages (in page order,
        capped at ``max_pages``). No references taken and no LRU stamp —
        the read-only companion of ``attach`` for admission accounting."""
        gids: List[int] = []
        hashes = self.chain(tokens)
        if max_pages is not None:
            hashes = hashes[:max_pages]
        for h in hashes:
            ent = self._entries.get(h)
            if ent is None:
                break
            gids.append(ent[0])
        return gids

    def attach(self, tokens: np.ndarray,
               max_pages: Optional[int] = None) -> List[int]:
        """Attach to the longest cached prefix (capped at ``max_pages`` —
        an admission wave's shared history depth is the min over its
        rows): increfs every hit page on the caller's behalf and returns
        their gids in page order."""
        gids: List[int] = []
        hashes = self.chain(tokens)
        if max_pages is not None:
            hashes = hashes[:max_pages]
        try:
            for h in hashes:
                ent = self._entries.get(h)
                if ent is None:
                    break
                self._clock += 1
                self._last_use[h] = self._clock
                self.alloc.incref(ent[0])
                gids.append(ent[0])
        except BaseException:
            # exception-safety: release every reference this call took
            # (incref raises before mutating, so gids is exact)
            for gid in gids:
                self.alloc.decref(gid)
            raise
        self.hits += len(gids)
        self.misses += len(hashes) - len(gids)
        self.hit_tokens += len(gids) * self.page
        return gids

    # -------------------------------------------------------------- insert

    def insert(self, tokens: np.ndarray, page_idx: int, gid: int) -> bool:
        """Cache prompt page ``page_idx`` (a *full* page) as ``gid``. The
        cache takes its own reference. Returns False (no ref taken) when
        the chain position is already cached — two identical cold prompts
        admitted in one wave each prefilled privately; first in wins."""
        hashes = self.chain(tokens)
        h = hashes[page_idx]
        if h in self._entries:
            return False
        parent = hashes[page_idx - 1] if page_idx else None
        if parent is not None and parent not in self._entries:
            return False       # ancestor evicted mid-wave: orphan, skip
        self.alloc.incref(gid)
        self._entries[h] = (gid, page_idx, parent)
        if parent is not None:
            self._children[parent] = self._children.get(parent, 0) + 1
        self._clock += 1
        self._last_use[h] = self._clock
        return True

    # ------------------------------------------------------------ eviction

    def _evict_one(self, h: bytes) -> None:
        gid, _, parent = self._entries.pop(h)
        self._last_use.pop(h, None)
        self._children.pop(h, None)
        if parent is not None:
            self._children[parent] -= 1
            if not self._children[parent]:
                del self._children[parent]
        self.alloc.decref(gid)        # frees now iff no slot references it
        self.evictions += 1

    def _evict_for(self, part: int, n: int) -> int:
        """Allocator pressure hook: release cache references until >= ``n``
        pages of ``part`` hit the free list (or nothing that can relieve
        ``part`` is left). Only *leaf* entries (no cached children) are
        evictable — an interior page must outlive its descendants so
        chains stay walkable; evicting LRU leaves peels chains from the
        tail. On a partitioned pool a chain's page for column ``c`` lives
        in partition ``part_of_col(c)``, so exposing a page of ``part``
        may require peeling deeper leaves in LATER partitions first —
        but a chain that never reaches ``part`` cannot relieve it, and
        its leaves are left alone (draining them would strip the whole
        cache without freeing a single page where it is needed)."""
        freed = 0
        while freed < n:
            leaves = [h for h in self._entries if h not in self._children]
            in_part = [h for h in leaves
                       if self.alloc.part_of(self._entries[h][0]) == part]
            if not in_part:
                # fall back only to leaves whose chain passes through the
                # starved partition (chains start at column 0, so a leaf
                # deeper than ``part``'s column range has cached ancestors
                # inside it): peeling such a leaf exposes an ancestor
                # strictly closer to — eventually inside — ``part``.
                in_part = [h for h in leaves
                           if self.alloc.part_of_col(self._entries[h][1])
                           > part]
                if not in_part:
                    break
            pick = min(in_part, key=lambda h: self._last_use[h])
            gid = self._entries[pick][0]
            was = self.alloc.refcount(gid)
            self._evict_one(pick)
            if was == 1 and self.alloc.part_of(gid) == part:
                freed += 1
        return freed

    def invalidate(self, n: Optional[int] = None, rng=None) -> int:
        """Drop ``n`` cached entries (all of them when ``n`` is None),
        leaf-first so chains stay walkable, releasing the cache's own
        reference on each page. This is the recovery action for detected
        prefix corruption — a suspect entry is dropped, never served —
        and the chaos harness's ``prefix.corrupt`` fault. Live slots are
        untouched: only the cache's claim is released, and the cache is
        transparent to serving semantics (a dropped entry costs a future
        re-prefill, never a wrong token). ``rng`` (numpy Generator)
        picks victims; None peels deterministically."""
        want = len(self._entries) if n is None else min(int(n),
                                                       len(self._entries))
        dropped = 0
        while dropped < want and self._entries:
            leaves = [h for h in self._entries if h not in self._children]
            pick = leaves[int(rng.integers(len(leaves)))] \
                if rng is not None else leaves[0]
            self._evict_one(pick)
            dropped += 1
        return dropped

    # ------------------------------------------------------------ teardown

    def drop_all(self) -> None:
        """Release every cache reference (tests/teardown)."""
        while self._entries:
            leaves = [h for h in self._entries if h not in self._children]
            for h in leaves:
                self._evict_one(h)

    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {"pages": len(self._entries), "hits": self.hits,
                "misses": self.misses, "hit_tokens": self.hit_tokens,
                "evictions": self.evictions,
                "hit_rate": self.hits / lookups if lookups else 0.0}
