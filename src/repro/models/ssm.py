"""Mamba-2 (SSD, state-space duality) — attention-free family.

Arch-applicability note (per DESIGN.md §4): there is no attention softmax
here, so the paper's *softmax kernel* does not apply; however the SSD scan is
exponential-heavy — per-step decays ``a_t = exp(Δt·A)``, ``softplus(Δt)``
and the SiLU gates — and all of those route through the same VEXP primitive.

Chunked SSD (chunk = cfg.ssm_chunk):
  * decays kept in log domain (log a = Δt·A ≤ 0 — vexp's best-accuracy range),
  * intra-chunk: masked quadratic "attention" score (C_i·B_j)·exp(L_i−L_j)·Δt_j,
  * inter-chunk: (B, nh, hd, ds) state carried by a lax.scan over chunks.

Decode is a single state update: h ← a·h + Δt·(B ⊗ x); y = C·h + D·x.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import exp_callable
from .layers import (dense_init, norm_init, norm_apply, embed_init,
                     vexp_softplus, vexp_silu, cross_entropy,
                     mask_padded_logits)
from .state_spec import LeafAxes


def ssm_dims(cfg):
    di = cfg.d_inner
    nh = cfg.ssm_nheads
    ds = cfg.ssm_state
    ng = cfg.ssm_ngroups
    conv_dim = di + 2 * ng * ds
    return di, nh, ds, ng, conv_dim


def ssm_layer_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, ds, ng, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": norm_init(d, cfg.norm),
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ng * ds + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(zxbcdt, cfg):
    di, nh, ds, ng, _ = ssm_dims(cfg)
    z, x, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ng * ds, 2 * di + 2 * ng * ds], axis=-1)
    return z, x, Bc, Cc, dt


def _causal_conv(u, w, b, state=None, valid_len=None):
    """Depthwise causal conv along seq. u: (B, S, C); w: (W, C).
    state: optional (B, W-1, C) left context (decode).

    ``valid_len`` selects where the returned left-context state ends: by
    default it is the last W-1 inputs; a per-row (B,) count gathers the
    window ending at each row's last *real* token (ragged right-padded
    prefill), and a static int slices at that position (chunk-padded
    uniform prefill). Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    y = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(width)) + b
    if valid_len is None:
        new_state = full[:, full.shape[1] - (width - 1):]
    elif isinstance(valid_len, int):
        new_state = full[:, valid_len:valid_len + width - 1]
    else:
        idx = (jnp.asarray(valid_len, jnp.int32).reshape(-1, 1)
               + jnp.arange(width - 1)[None, :])
        new_state = jnp.take_along_axis(full, idx[..., None], axis=1)
    return y, new_state


def ssm_layer_apply(x, p, cfg, return_state=False, prompt_len=None,
                    policy=None, h0=None, conv_state=None):
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D) [, final state].

    ``h0`` (B, nh, hd, ds) and ``conv_state`` (B, W-1, C) resume the
    recurrence from a carried state (chunked prefill): the inter-chunk
    scan starts at ``h0`` instead of zeros and the causal conv reads its
    left context from ``conv_state``. When the chunk boundary falls on a
    ``cfg.ssm_chunk`` multiple the per-block decomposition — and so the
    fp summation order — is identical to a one-shot pass, making chunked
    prefill bitwise equal to monolithic prefill.

    Arbitrary sequence lengths are supported: the sequence is padded to
    the next ``cfg.ssm_chunk`` multiple and the pad steps are masked by
    zeroing their ``dt`` — a zero step size makes the decay
    ``a = exp(0·A) = 1`` and the update contribution exactly 0.0, so the
    padded tail neither moves the state nor perturbs any real position
    (bitwise — which is also why a row produces identical values at any
    right-padded batch width). ``prompt_len`` (B,) extends the same mask
    to ragged right-padded prompts; with ``return_state`` each row's
    ``(h, conv)`` is the state at its *last real token*, not the padded
    end. The chunk size is always ``cfg.ssm_chunk`` (never shrunk to a
    short sequence) so a row's chunk decomposition — and therefore its fp
    summation order — is independent of how far its batch was padded.
    """
    exp_fn = exp_callable(policy, cfg.exp_impl)
    b, s, d = x.shape
    di, nh, ds, ng, conv_dim = ssm_dims(cfg)
    hd = cfg.ssm_headdim
    q = cfg.ssm_chunk
    pad = (-s) % q
    sp = s + pad
    nc = sp // q
    valid = None
    if prompt_len is not None:
        plen = jnp.asarray(prompt_len, jnp.int32).reshape(-1)
        valid = jnp.arange(sp)[None, :] < plen[:, None]          # (B, Sp)
    elif pad:
        valid = jnp.broadcast_to(jnp.arange(sp)[None, :] < s, (b, sp))
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))

    h = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    z, xin, Bc, Cc, dt = _split_proj(h @ p["in_proj"], cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    state_at = None
    if return_state:
        state_at = plen if prompt_len is not None else s
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        state=conv_state,
                                        valid_len=state_at)
    conv_out = vexp_silu(conv_out, exp_fn)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + ng * ds], axis=-1)

    dt = vexp_softplus(dt.astype(jnp.float32) + p["dt_bias"], exp_fn)  # (B,S,nh)
    if valid is not None:
        # pad/ragged steps: dt = 0 -> decay 1, update 0 (state untouched).
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = -exp_fn(p["A_log"])                                            # (nh,)
    la = dt * A                                                        # log a_t <= 0

    xh = xin.astype(jnp.float32).reshape(b, sp, nh, hd)
    Bh = Bc.astype(jnp.float32).reshape(b, sp, ng, ds)
    Ch = Cc.astype(jnp.float32).reshape(b, sp, ng, ds)
    gph = nh // ng                                  # heads per group
    # chunked views: (B, nc, Q, ...)
    xc = xh.reshape(b, nc, q, nh, hd)
    Bb = Bh.reshape(b, nc, q, ng, ds)
    Cb = Ch.reshape(b, nc, q, ng, ds)
    lac = la.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)

    L = jnp.cumsum(lac, axis=2)                     # within-chunk cumulative
    Ltot = L[:, :, -1]                              # (B, nc, nh)

    # ---- intra-chunk (masked quadratic) ----
    # scores[i,j] = (C_i . B_j) * exp(L_i - L_j) * dt_j   for j <= i
    # Grouped formulation: heads are viewed as (ng, gph) so the shared
    # B/C projections are never materialized per head (§Perf iteration B1
    # — the repeat-based version wrote (B,nc,Q,nh,ds) copies to HBM).
    mdt = jnp.bfloat16 if cfg.attn_mm_dtype == "bf16" else jnp.float32
    cb = jnp.einsum("bnigd,bnjgd->bngij", Cb.astype(mdt), Bb.astype(mdt),
                    preferred_element_type=jnp.float32)  # (B,nc,ng,Q,Q)
    Li = L.transpose(0, 1, 3, 2)                    # (B,nc,nh,Q)
    diff = Li[..., :, None] - Li[..., None, :]      # (B,nc,nh,Q,Q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask, exp_fn(jnp.minimum(diff, 0.0)), 0.0)
    dtj = dtc.transpose(0, 1, 3, 2)                 # (B,nc,nh,Q)
    wh = (decay * dtj[..., None, :]).reshape(
        b, nc, ng, gph, q, q)                       # head-decay (grouped)
    xg = xc.reshape(b, nc, q, ng, gph, hd)
    # B3: the big O(S*Q) streams (scores, decays, x) move in mm dtype;
    # accumulation stays f32 via preferred_element_type.
    y_intra = jnp.einsum("bngij,bngpij,bnjgpd->bnigpd",
                         cb.astype(mdt), wh.astype(mdt), xg.astype(mdt),
                         preferred_element_type=jnp.float32)
    y_intra = y_intra.reshape(b, nc, q, nh, hd)

    # ---- chunk states ----
    # state_c = sum_j exp(Ltot - L_j) * dt_j * B_j (x) x_j  -> (B,nc,nh,hd,ds)
    sdecay = exp_fn(Ltot[:, :, None, :] - L) * dtc  # (B,nc,Q,nh)
    sg = sdecay.reshape(b, nc, q, ng, gph)
    states = jnp.einsum("bnjgp,bnjgpd,bnjgs->bngpds",
                        sg.astype(mdt), xg.astype(mdt), Bb.astype(mdt),
                        preferred_element_type=jnp.float32)
    states = states.reshape(b, nc, nh, hd, ds)

    # ---- inter-chunk recurrence over nc ----
    def scan_body(hprev, inp):
        st, ltot = inp                              # (B,nh,hd,ds), (B,nh)
        hnew = hprev * exp_fn(ltot)[..., None, None] + st
        return hnew, hprev

    hstart = (jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_final, hprevs = jax.lax.scan(
        scan_body, hstart,
        (states.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)),
        unroll=cfg.unroll_scans)
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)        # (B,nc,nh,hd,ds)

    # y_inter_i = C_i . (exp(L_i) * H_prev)   (grouped: no C repeat)
    edec = jnp.transpose(exp_fn(Li), (0, 1, 3, 2))  # (B,nc,Q,nh)
    eg = edec.reshape(b, nc, q, ng, gph)
    hg = hprevs.reshape(b, nc, ng, gph, hd, ds)
    y_inter = jnp.einsum("bnigs,bnigp,bngpds->bnigpd",
                         Cb.astype(mdt), eg.astype(mdt), hg.astype(mdt),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter.reshape(b, nc, q, nh, hd)

    y = (y_intra + y_inter).reshape(b, sp, nh, hd)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, sp, di).astype(x.dtype)
    y = y * vexp_silu(z, exp_fn)
    out = (x + y @ p["out_proj"])[:, :s]
    if return_state:
        return out, {"h": h_final, "conv": conv_state.astype(jnp.float32)}
    return out


def ssm_layer_decode(x, p, cfg, state, policy=None):
    """Single-token decode. state: {"h": (B,nh,hd,ds), "conv": (B,W-1,C)}."""
    exp_fn = exp_callable(policy, cfg.exp_impl)
    b = x.shape[0]
    di, nh, ds, ng, conv_dim = ssm_dims(cfg)
    hd = cfg.ssm_headdim
    gph = nh // ng

    hin = norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps)
    z, xin, Bc, Cc, dt = _split_proj(hin @ p["in_proj"], cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)      # (B,1,C)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      state["conv"])
    conv_out = vexp_silu(conv_out, exp_fn)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + ng * ds], axis=-1)

    dt = vexp_softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"], exp_fn)
    a = exp_fn(dt * (-exp_fn(p["A_log"])))                 # (B,nh)
    xh = xin[:, 0].astype(jnp.float32).reshape(b, nh, hd)
    Bh = jnp.repeat(Bc[:, 0].astype(jnp.float32).reshape(b, ng, ds),
                    gph, axis=1)                           # (B,nh,ds)
    Ch = jnp.repeat(Cc[:, 0].astype(jnp.float32).reshape(b, ng, ds),
                    gph, axis=1)

    hnew = (state["h"] * a[..., None, None]
            + (dt[..., None] * xh)[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhds,bhs->bhd", hnew, Ch) + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * vexp_silu(z, exp_fn)
    # conv state stays f32 like init_cache/prefill allocate it — the conv
    # window is computed in compute dtype, and returning it as bf16 would
    # silently flip the carried state's dtype after the first step (and
    # break the serving engine's donated in-place state update).
    return (x + y @ p["out_proj"],
            {"h": hnew, "conv": new_conv.astype(jnp.float32)})


# ------------------------------------------------------------- full model

def init_params(cfg, key):
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = [ssm_layer_init(ks[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"layers": stacked,
            "ln_f": norm_init(cfg.d_model, cfg.norm),
            "embed": embed_init(ks[-1], cfg.vocab_padded, cfg.d_model),
            "unembed": dense_init(ks[-2], cfg.d_model, cfg.vocab_padded)}


def forward(params, cfg, tokens, *, policy=None):
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)

    def body(x, layer_p):
        layer_p = jax.tree.map(
            lambda a: a.astype(dt)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, layer_p)
        return ssm_layer_apply(x, layer_p, cfg, policy=policy), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    return norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)


def loss_fn(params, cfg, batch, *, policy=None):
    x = forward(params, cfg, batch["tokens"], policy=policy)
    return cross_entropy(x, params["unembed"], batch["labels"],
                         chunk=cfg.loss_chunk, exp_impl=cfg.exp_impl,
                         mask=batch.get("mask"), unroll=cfg.unroll_scans)


def init_cache(cfg, batch, seq_len=None):
    """Decode state for ``batch`` rows (the family-uniform constructor).

    ``seq_len`` is accepted for signature parity with the KV families and
    deliberately unused: recurrent state is O(1) in sequence length —
    per layer one (B, nh, hd, ds) SSD state and one (B, W-1, C) conv
    left-context, regardless of how long the sequence was or will be.
    """
    di, nh, ds, ng, conv_dim = ssm_dims(cfg)
    shape_h = (cfg.n_layers, batch, nh, cfg.ssm_headdim, ds)
    shape_c = (cfg.n_layers, batch, cfg.conv_width - 1, conv_dim)
    return {"h": jnp.zeros(shape_h, jnp.float32),
            "conv": jnp.zeros(shape_c, jnp.float32)}


def init_state(cfg, batch):
    """Deprecated alias of ``init_cache`` (pre-DecodeState signature)."""
    warnings.warn("ssm.init_state(cfg, batch) is deprecated; use "
                  "ssm.init_cache(cfg, batch, seq_len) / models.api."
                  "init_cache — the family-uniform constructor",
                  DeprecationWarning, stacklevel=2)
    return init_cache(cfg, batch)


def state_axes(cfg):
    """DecodeState leaf metadata: slot axis per leaf, no sequence axis."""
    return {"h": LeafAxes(1), "conv": LeafAxes(1)}


def prefill(params, cfg, tokens, *, prompt_len=None, policy=None):
    """Returns (last_logits, state): one full-sequence SSD pass per layer,
    collecting each layer's final (h, conv) state for subsequent decode.

    ``prompt_len`` (B,) marks ragged right-padded prompts: pad steps are
    dt-masked out of the recurrence, each row's state is taken at its
    last *real* token, and so are the returned logits."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    b, s = tokens.shape

    def body(x, layer_p):
        layer_p = jax.tree.map(
            lambda a: a.astype(dt)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, layer_p)
        y, state = ssm_layer_apply(x, layer_p, cfg, return_state=True,
                                   prompt_len=prompt_len, policy=policy)
        return y, state

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, state = jax.lax.scan(body, x, params["layers"],
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    if prompt_len is None:
        xl = x[:, -1:]
    else:
        plen = jnp.asarray(prompt_len, jnp.int32).reshape(-1)
        idx = jnp.clip(plen - 1, 0, s - 1)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), state


def prefill_chunk(params, cfg, tokens, state, off, clens, *, policy=None):
    """Resumable chunked prefill: one SSD pass over a (B, C) token chunk,
    continuing each layer's recurrence from the carried ``state``.

    ``off`` is accepted for the family-uniform chunk signature and unused
    — the recurrence carries all positional information in its state.
    ``clens`` (B,) is the number of valid tokens per row in this chunk;
    rows with ``clens == 0`` are inert (dt-masked no-op recurrence, conv
    state gathered back from the carried left context), so their state
    passes through bit-untouched. Chunk widths must be a multiple of
    ``cfg.ssm_chunk`` so the per-block decomposition — and the fp
    summation order — matches a one-shot pass bitwise.

    Returns (last_logits, new_state) with logits taken at each row's
    last valid chunk token."""
    del off
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    b, s = tokens.shape
    clens = jnp.asarray(clens, jnp.int32).reshape(-1)

    def body(x, inp):
        layer_p, h, conv = inp
        layer_p = jax.tree.map(
            lambda a: a.astype(dt)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, layer_p)
        y, new = ssm_layer_apply(x, layer_p, cfg, return_state=True,
                                 prompt_len=clens, policy=policy,
                                 h0=h, conv_state=conv)
        return y, new

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_state = jax.lax.scan(
        body, x, (params["layers"], state["h"], state["conv"]),
        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    idx = jnp.clip(clens - 1, 0, s - 1)[:, None, None]
    xl = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", xl.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), new_state


def decode_step(params, cfg, token, state, pos, *, policy=None, live=None):
    """One decode step. ``pos`` (scalar or per-slot (B,)) is accepted for
    the family-uniform signature and unused — the recurrence carries all
    positional information in its state. ``live`` (B,) masks state
    updates for parked rows (e.g. slots mid-chunked-prefill): rows with
    ``live == 0`` keep their carried (h, conv) bit-untouched."""
    del pos
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], token, axis=0).astype(dt)
    keep = None if live is None else jnp.asarray(live).reshape(-1) > 0

    def body(x, inp):
        layer_p, h, conv = inp
        layer_p = jax.tree.map(
            lambda a: a.astype(dt)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, layer_p)
        y, new = ssm_layer_decode(x, layer_p, cfg, {"h": h, "conv": conv},
                                  policy=policy)
        if keep is not None:
            new = {"h": jnp.where(keep[:, None, None, None], new["h"], h),
                   "conv": jnp.where(keep[:, None, None], new["conv"], conv)}
        return y, new

    x, new_state = jax.lax.scan(
        body, x, (params["layers"], state["h"], state["conv"]),
        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = norm_apply(x, params["ln_f"], cfg.norm, cfg.norm_eps)
    ldt = jnp.bfloat16 if cfg.logits_mm_dtype == "bf16" else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ldt),
                        params["unembed"].astype(ldt),
                        preferred_element_type=jnp.float32)
    return mask_padded_logits(logits, cfg.vocab), new_state
