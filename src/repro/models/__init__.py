from . import api, layers, transformer, moe, ssm, hybrid
from .api import (init_params, loss_fn, forward, prefill, decode_step,
                  init_cache, input_specs)
