"""Shared neural-net building blocks (pure-pytree params, no flax).

All blocks take/return plain dicts of jnp arrays so they stack cleanly along
a leading layer axis for ``jax.lax.scan`` over layers (key for compile time
at 40-64 layers) and shard transparently under pjit.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.vexp import get_exp_fn


# ---------------------------------------------------------------- init utils

def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms

def rmsnorm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_apply(x, p, kind, eps):
    if kind == "layernorm":
        return layernorm(x, p["w"], p.get("b"), eps)
    return rmsnorm(x, p["w"], eps)


def norm_init(d, kind):
    p = {"w": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------- rope

def rope_freqs(hd_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, jnp.float32) / hd_rot))


def apply_rope(x, pos, theta=10000.0, rope_pct=1.0):
    """x: (B, S, H, D); pos: (B, S) or (S,) absolute positions."""
    d = x.shape[-1]
    d_rot = int(d * rope_pct) // 2 * 2
    if d_rot == 0:
        return x
    freqs = rope_freqs(d_rot, theta)                      # (d_rot/2,)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None].astype(jnp.float32) * freqs      # (B, S, d_rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ------------------------------------------------------------- activations

def vexp_sigmoid(x, exp_fn):
    """sigmoid(x) = 1 / (1 + exp(-x)) with the vexp exponential."""
    xf = x.astype(jnp.float32)
    e = exp_fn(-jnp.abs(xf))
    s = jnp.where(xf >= 0, 1.0 / (1.0 + e), e / (1.0 + e))
    return s.astype(x.dtype)


def vexp_softplus(x, exp_fn):
    """softplus(x) = log1p(exp(x)), stable, exp via vexp."""
    xf = x.astype(jnp.float32)
    return (jnp.maximum(xf, 0.0)
            + jnp.log1p(exp_fn(-jnp.abs(xf)))).astype(x.dtype)


def vexp_silu(x, exp_fn):
    return x * vexp_sigmoid(x, exp_fn)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ----------------------------------------------------------------------- mlp

def mlp_init(key, d, f, act, use_bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        p = {"wg": dense_init(ks[0], d, f, dtype),
             "wu": dense_init(ks[1], d, f, dtype),
             "wd": dense_init(ks[2], f, d, dtype)}
    else:
        p = {"wu": dense_init(ks[0], d, f, dtype),
             "wd": dense_init(ks[1], f, d, dtype)}
    if use_bias:
        p["bu"] = jnp.zeros((f,), dtype)
        p["bd"] = jnp.zeros((d,), dtype)
    return p


def mlp_apply(x, p, act, exp_impl="vexp", *, policy=None):
    exp_fn = get_exp_fn(policy.exp_backend if policy is not None
                        else exp_impl)
    if act == "swiglu":
        g = vexp_silu(x @ p["wg"], exp_fn)
        u = x @ p["wu"]
        h = g * u
    else:
        h = x @ p["wu"]
        if "bu" in p:
            h = h + p["bu"].astype(h.dtype)
        h = gelu(h)
    y = h @ p["wd"]
    if "bd" in p:
        y = y + p["bd"].astype(y.dtype)
    return y


def mask_padded_logits(logits, vocab: int):
    """Mask the padded tail of the vocab dim (serving boundary): embedding
    tables are padded to a shard-friendly multiple of 256; padded logits
    must not win an argmax."""
    if logits.shape[-1] == vocab:
        return logits
    keep = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(keep, logits, -1e30)


# --------------------------------------------------------- chunked CE loss

def cross_entropy(x_final, w_unembed, labels, *, chunk=512, exp_impl="vexp",
                  logit_softcap=0.0, mask=None, unroll=False, policy=None):
    """Chunked cross-entropy over the sequence axis.

    Avoids materializing the full (B, S, V) logits: scans seq chunks, each
    chunk computes logits, a vexp-based logsumexp, and the label logit via a
    gathered embedding row (cheap vs. one-hot). Returns mean nats/token.

    x_final: (B, S, D); w_unembed: (D, V) (possibly vocab-sharded);
    labels: (B, S) int32; mask: optional (B, S) bool of valid tokens.
    """
    exp_fn = get_exp_fn(policy.exp_backend if policy is not None
                        else exp_impl)
    b, s, d = x_final.shape
    chunk = min(chunk, s)
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        x_final = jnp.pad(x_final, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((b, s), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), bool)

    xc = x_final.reshape(b, nchunk, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nchunk, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nchunk, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        x, lab, m = inp
        logits = (x.astype(jnp.float32)
                  @ w_unembed.astype(jnp.float32))          # (B, C, V)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        mx = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
        lse = jnp.log(jnp.sum(exp_fn(logits - mx), -1)) + mx[..., 0]
        # label logit via row gather from the unembedding (D,V) -> (B,C,D)
        wrow = jnp.take(w_unembed.astype(jnp.float32).T, lab, axis=0)
        corr = jnp.sum(x.astype(jnp.float32) * wrow, -1)
        if logit_softcap:
            corr = logit_softcap * jnp.tanh(corr / logit_softcap)
        nll = (lse - corr) * m
        return (tot + nll.sum(), cnt + m.sum()), None

    # Remat the chunk body: without this, scan's backward saves every
    # chunk's (B, C, V) f32 logits — ~250 GB/device for a 256k vocab at
    # train_4k (found by the dry-run's memory analysis). Recomputing the
    # chunk logits in the backward costs ~+33% of CE FLOPs (~5% of step).
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (xc, lc, mc),
        unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)
