"""Family-agnostic per-slot serving state: the DecodeState protocol.

The slot engine (``launch.serve``) used to hardcode its per-slot state as
a ``(kv_cache, (B,) positions)`` pair — an assumption smeared across
admission, decode, freeing and donation that made recurrent families
(ssm's per-layer ``(h, conv)`` snapshots, hybrid's mixed
recurrent/attention periods) unservable. This module is the replacement
boundary: one ``DecodeState`` object per policy group owning

  * the pool state pytree (``data``) — whatever arrays the family carries
    between decode steps, allocated once at pool width;
  * the per-slot device-side position vector (``pos_dev``), threaded and
    donated through the decode program so positions advance device-side;
  * the jitted prefill/decode programs (family-dispatched through
    ``models.api``, so one program builder covers every family).

The engine talks only to the protocol:

  ``prefill_into(slots, toks, plens, full=, uniform=)``
      run the pool-width (ragged right-padded) prefill and write the
      admitted rows into freed slots; returns the first greedy tokens.
  ``step(last, live)``
      one donated decode step over the pool; returns the next tokens.
  ``reset_slots(idx)``
      park freed slots (zero positions; recurrent states also zero their
      rows — stale ``h``/``conv`` from a previous occupant is read
      unconditionally every step, unlike KV rows which are masked by
      ``cache_len``).
  ``max_len()`` / ``prefill_width(n)`` / ``supports_seq_sharding(cfg)``
      capacity, admission width and SPMD capability probes — the engine
      never branches on the model family, only on these.

The generic pool ops (scatter admitted rows, pad a full-pool prefill to
capacity, zero freed slots) are driven by each family's leaf-axis
metadata (``state_spec.LeafAxes`` from ``transformer.cache_axes`` /
``ssm.state_axes`` / ``hybrid.cache_axes``): every leaf has one slot axis
and at most one sequence axis, which is all those operations need.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import api


def _len_bucket(n: int, cap: int) -> int:
    """Pow2-rounded prefill length (>=8) so ragged admission shares a small
    set of prefill executables; capped at the cache's sequence capacity."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


# (repr(cfg), policy, decode_policy, kv_axis[, mesh]) -> (prefill_fn,
# prefill_plain_fn, decode_fn). jax.jit caches per function object, so the
# jitted closures must outlive any one Server — otherwise every server
# restart recompiles the programs. Greedy serving never reads logits on
# the host, so all programs return argmaxed (B, 1) token ids — one fused
# executable per step, no eager argmax dispatches.
#
# decode_fn(params, last, state, pos, live) -> (next, state, pos + live):
# the state pytree and the per-slot position vector are DONATED (their
# input buffers are reused for the outputs), so a decode step allocates no
# new state and the slot positions advance device-side — the hot loop
# performs zero host->device transfers and zero host syncs. The builder is
# family-generic: prefill/decode dispatch through models.api.
_PROGRAM_CACHE: dict = {}


def _programs(cfg, policy, mesh=None, kv_axis=None, decode_policy=None):
    # decode_policy: the (possibly merge-strategy-autotuned) policy the
    # decode program is built against; prefill keeps the group policy so
    # its in-jit autotune cache reads stay live.
    dpol = policy if decode_policy is None else decode_policy
    key = (repr(cfg), policy, dpol, kv_axis,
           mesh if kv_axis is not None else None)
    if key not in _PROGRAM_CACHE:
        pol = policy

        def prefill_fn(p, toks, plens):
            logits, state = api.prefill(
                p, cfg, {"tokens": toks, "prompt_len": plens}, policy=pol)
            return jnp.argmax(logits, -1).astype(jnp.int32), state

        def prefill_plain_fn(p, toks):
            # every row full-length: no padding mask to apply (the common
            # uniform-traffic admission; skips the ragged machinery)
            logits, state = api.prefill(p, cfg, {"tokens": toks},
                                        policy=pol)
            return jnp.argmax(logits, -1).astype(jnp.int32), state

        if kv_axis is None:
            def decode_fn(p, t, c, pos, live):
                logits, state = api.decode_step(p, cfg, t, c, pos,
                                                policy=dpol)
                return (jnp.argmax(logits, -1).astype(jnp.int32), state,
                        pos + live)

            decode = jax.jit(decode_fn, donate_argnums=(2, 3))
        else:
            # Sequence-sharded decode (a KVDecodeState-only capability —
            # probed via supports_seq_sharding, never via the family):
            # ONE shard_map program per policy group, built here at engine
            # startup — the fused partial-statistics path instead of GSPMD
            # lowering. The cache lives (and stays) sharded along its S
            # axis; each layer's shard statistics fold through the
            # policy's merge strategy ("packed": one collective per
            # layer).
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import shard_map
            from repro.distributed.sharding import serve_cache_sharding
            from .transformer import decode_step_sharded
            # one source of truth for the pool placement: the program's
            # in/out specs are the spec of the sharding the engine
            # allocates the pool under.
            cspec = {name: s.spec for name, s in
                     serve_cache_sharding(cfg, mesh, kv_axis).items()}

            def decode_local(p, t, c, pos, live):
                logits, c = decode_step_sharded(p, cfg, t, c, pos,
                                                policy=dpol,
                                                seq_axis=kv_axis)
                return (jnp.argmax(logits, -1).astype(jnp.int32), c,
                        pos + live)

            decode = jax.jit(
                shard_map(decode_local, mesh=mesh,
                          in_specs=(P(), P(), cspec, P(), P()),
                          out_specs=(P(), cspec, P())),
                donate_argnums=(2, 3))

        _PROGRAM_CACHE[key] = (jax.jit(prefill_fn),
                               jax.jit(prefill_plain_fn),
                               decode)
    return _PROGRAM_CACHE[key]


class DecodeState:
    """Base of the per-family serving-state implementations.

    Subclasses provide ``kind``, ``_state_axes(cfg)`` and (optionally)
    capability overrides; the pool algebra below is generic.
    """

    kind = "state"

    @classmethod
    def supports_seq_sharding(cls, cfg) -> bool:
        """Whether this state can decode over a sequence-sharded pool
        (the SPMD serve loop). Only linear KV caches can."""
        return False

    def __init__(self, cfg, params, policy, pool_width, cache_s, *,
                 mesh=None, kv_axis=None):
        self.cfg, self.params, self.policy = cfg, params, policy
        self.pool_width, self.cache_s = pool_width, cache_s
        self.mesh, self.kv_axis = mesh, kv_axis
        self.axes = self._state_axes(cfg)
        self.data = None                 # pool pytree; set on first admit
        self.pos_dev = jnp.zeros((pool_width,), jnp.int32)
        self.params_decode = params
        self._repl = None                # mesh-replicated sharding (SPMD)
        self._state_shard = None         # sharded pool placement (SPMD)
        self._setup_placement()
        if self._repl is not None:
            self.params_decode = jax.device_put(params, self._repl)
            self.pos_dev = jax.device_put(self.pos_dev, self._repl)
        decode_policy = self._autotune_warmup()
        (self._prefill, self._prefill_plain,
         self._decode) = _programs(cfg, policy, mesh, kv_axis,
                                   decode_policy)

    # ------------------------------------------------------- family hooks

    def _state_axes(self, cfg):
        raise NotImplementedError

    def _setup_placement(self):
        pass                             # single-device default

    def _autotune_warmup(self):
        return self.policy

    def max_len(self):
        """Length at which a slot must stop decoding (None = unbounded:
        recurrent state and ring-buffer windows never exhaust)."""
        return None

    def prefill_width(self, n: int) -> int:
        """Admission width for a wave whose longest prompt is ``n``."""
        return _len_bucket(n, self.cache_s)

    # --------------------------------------------------------- placement

    def place_tokens(self, x):
        """Place an engine-side array (tokens/liveness) next to the
        decode program's inputs (replicated on the mesh for SPMD)."""
        return x if self._repl is None else jax.device_put(x, self._repl)

    def _place_state(self, tree):
        if self._state_shard is None:
            return tree
        return jax.device_put(tree, self._state_shard)

    # ------------------------------------------------------- engine ops

    def prefill_into(self, slots, toks, plens, *, full, uniform=False):
        """One pool-width batched prefill; admitted rows land in freed
        slots. ``toks`` (pool_width, sp) right-padded prompts, ``plens``
        (pool_width,) real lengths (1 for rows without a request);
        ``full`` = the whole pool admitted at once (the prefill output
        *is* the pool, padded to capacity — no scatter); ``uniform`` =
        run the unmasked plain prefill (no padding exists). Returns the
        (pool_width, 1) first greedy tokens, placed for decode."""
        if uniform:
            first, pref = self._prefill_plain(self.params,
                                              jnp.asarray(toks))
        else:
            first, pref = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(plens))
        first = self.place_tokens(first)
        sp = toks.shape[1]
        if full:
            def pad(leaf, ax):
                if ax.seq is None or leaf.shape[ax.seq] == self.cache_s:
                    return leaf
                widths = [(0, 0)] * leaf.ndim
                widths[ax.seq] = (0, self.cache_s - leaf.shape[ax.seq])
                return jnp.pad(leaf, widths)

            self.data = self._place_state(
                jax.tree.map(pad, pref, self.axes))
        else:
            if self.data is None:
                self.data = self._place_state(
                    api.init_cache(self.cfg, self.pool_width,
                                   self.cache_s))
            sl = jnp.asarray(np.asarray(slots))

            def insert(pool, leaf, ax):
                rows_idx = [slice(None)] * leaf.ndim
                rows_idx[ax.batch] = sl
                rows = leaf[tuple(rows_idx)]
                if self._repl is not None:
                    rows = jax.device_put(rows, self._repl)
                idx = [slice(None)] * pool.ndim
                idx[ax.batch] = sl
                if ax.seq is not None:
                    idx[ax.seq] = slice(0, sp)
                return pool.at[tuple(idx)].set(rows)

            self.data = jax.tree.map(insert, self.data, pref, self.axes)
        sl = jnp.asarray(np.asarray(slots))
        self.pos_dev = self.pos_dev.at[sl].set(
            jnp.asarray(np.asarray(plens)[np.asarray(slots)], jnp.int32))
        return first

    def step(self, last, live):
        """One donated decode step over the pool; positions advance by
        ``live`` device-side. Returns the (pool_width, 1) next tokens."""
        nxt, self.data, self.pos_dev = self._decode(
            self.params_decode, last, self.data, self.pos_dev, live)
        return nxt

    def reset_slots(self, slots):
        """Park freed slots: zero their positions and (where
        ``_reset_leaf`` says so) state rows, so a stale occupant can
        never bleed into the next request admitted into the slot
        (recurrent ``h``/``conv`` is read unconditionally every step)."""
        sl = jnp.asarray(np.asarray(slots))
        self.pos_dev = self.pos_dev.at[sl].set(0)
        if self.data is not None:
            def zero(leaf, ax):
                if not self._reset_leaf(ax):
                    return leaf
                idx = [slice(None)] * leaf.ndim
                idx[ax.batch] = sl
                return leaf.at[tuple(idx)].set(0)

            self.data = jax.tree.map(zero, self.data, self.axes)

    def _reset_leaf(self, ax) -> bool:
        """Whether ``reset_slots`` must zero a leaf with these axes.
        Default: every leaf (recurrent snapshots are read
        unconditionally). KV-bearing states skip their sequence leaves —
        decode masks those rows by ``cache_len`` and admission prefill
        overwrites them, so zeroing (S, Hkv, hd) rows per finish would
        out-cost a decode step."""
        return True

    # ----------------------------------------------------------- shared

    def _linear_cap(self):
        # A pool smaller than the sliding window can never wrap its ring
        # buffer correctly (the write cursor is pos % window, which runs
        # past the pool's extent) — such a pool behaves like a linear
        # cache and must stop slots at capacity, exactly like a
        # window-less cache. Only a full-window pool decodes unbounded.
        w = self.cfg.sliding_window
        if w is None or self.cache_s < w:
            return self.cache_s
        return None


class KVDecodeState(DecodeState):
    """Transformer families (dense / moe / vlm): today's KV cache +
    per-slot positions, including the sequence-sharded SPMD path."""

    kind = "kv"

    @classmethod
    def supports_seq_sharding(cls, cfg) -> bool:
        # windowed archs keep the GSPMD path: the ring-buffer wrap write
        # straddles shard boundaries.
        return cfg.sliding_window is None

    def _state_axes(self, cfg):
        from .transformer import cache_axes
        return cache_axes(cfg)

    def max_len(self):
        # a linear cache is exhausted when the next write would fall past
        # the last slot; ring-buffer windows wrap instead.
        return self._linear_cap()

    def _setup_placement(self):
        if self.kv_axis is None:
            return
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import serve_cache_sharding
        # decode runs over the mesh; prefill stays on the default device
        # (its outputs are re-placed at admission).
        self._repl = NamedSharding(self.mesh, P())
        self._state_shard = serve_cache_sharding(self.cfg, self.mesh,
                                                 self.kv_axis)

    def _reset_leaf(self, ax) -> bool:
        return False      # pure KV: every leaf is cache_len-masked

    def _autotune_warmup(self):
        """Eagerly tune the decode-attention block size for this group's
        decode shape. Timing is meaningless inside the jitted decode
        program (tracers, not device work), so the tuner only ever
        *reads* its cache there — this one eager call at the real
        (pool_width, cache_s) shape times the candidates, memoizes the
        winner for the jit path to pick up, and persists it to disk so
        the next server start skips even this.

        On a sequence-sharded group it additionally times the two
        collective merge strategies (packed single-collective vs
        pmax+2×psum) at the group's exact decode shape and returns the
        policy with the winner baked in (the shard_map decode program
        takes the policy statically, so it must resolve before the
        program is built). Returns the — possibly tuned — policy.
        """
        cfg, policy = self.cfg, self.policy
        if not policy.autotune or policy.kernel_backend != "pallas":
            return policy
        from repro.kernels.dispatch import dispatch, autotune_policy
        lay = cfg.kv_cache_layout
        kv_shape = ((self.pool_width, cfg.n_kv_heads, self.cache_s, cfg.hd)
                    if lay == "bhsd" else
                    (self.pool_width, self.cache_s, cfg.n_kv_heads, cfg.hd))
        q = jnp.zeros((self.pool_width, 1, cfg.n_heads, cfg.hd),
                      jnp.dtype(cfg.compute_dtype))
        kv = jnp.zeros(kv_shape, jnp.bfloat16)      # init_cache's dtype
        clen = jnp.full((self.pool_width,), self.cache_s, jnp.int32)
        dispatch("decode_attention", policy)(q, kv, kv, clen, layout=lay,
                                             policy=policy)
        if self.kv_axis is None:
            return policy
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.kernels.decode_attention.ops import _sharded_program
        from .transformer import cache_seq_axis as _csa
        spec = [None] * 4
        spec[_csa(lay, stacked=False)] = self.kv_axis
        kvs = jax.device_put(kv, NamedSharding(self.mesh, P(*spec)))
        return autotune_policy(
            "decode_attention_sharded", policy,
            lambda p: _sharded_program(self.mesh, self.kv_axis, None, None,
                                       lay, p)(q, kvs, kvs, clen),
            q, kvs)


class RecurrentDecodeState(DecodeState):
    """ssm (mamba2/SSD): batched per-layer (h, conv) snapshots. No
    sequence axis anywhere — a slot's state is O(1) in its length, so
    there is no capacity cap and admission scatters whole slot rows."""

    kind = "recurrent"

    def _state_axes(self, cfg):
        from .ssm import state_axes
        return state_axes(cfg)


class HybridDecodeState(DecodeState):
    """hybrid (recurrentgemma/griffin): mixed per-period state — RG-LRU
    ``(h, conv)`` snapshots next to ring-buffer local-attention KV."""

    kind = "hybrid"

    def _state_axes(self, cfg):
        from .hybrid import cache_axes
        return cache_axes(cfg)

    def max_len(self):
        return self._linear_cap()

    def _reset_leaf(self, ax) -> bool:
        # zero only the recurrent snapshots; the ring-buffer KV leaves
        # are cache_len-masked and fully overwritten by the fixed-width
        # admission prefill, so zeroing them per finish is wasted work.
        return ax.seq is None

    def prefill_width(self, n: int) -> int:
        # Fixed admission width: the RG-LRU associative scan's combine
        # tree — and therefore its fp summation order — depends on the
        # scan *length*, so pow2 buckets would make a row's state drift
        # with the admission wave it rode in (vs. solo serving). A fixed
        # width keeps batched tokens bit-identical to solo tokens; it is
        # bounded by the sliding window, so the cost stays modest.
        return self.cache_s


def decode_state_for(cfg):
    """The DecodeState implementation serving ``cfg`` (the one family
    dispatch of the serving stack)."""
    if cfg.family == "ssm":
        return RecurrentDecodeState
    if cfg.family == "hybrid":
        return HybridDecodeState
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode state to serve")
    return KVDecodeState
