"""Family-agnostic per-slot serving state: the DecodeState protocol.

The slot engine (``launch.serve``) used to hardcode its per-slot state as
a ``(kv_cache, (B,) positions)`` pair — an assumption smeared across
admission, decode, freeing and donation that made recurrent families
(ssm's per-layer ``(h, conv)`` snapshots, hybrid's mixed
recurrent/attention periods) unservable. This module is the replacement
boundary: one ``DecodeState`` object per policy group owning

  * the pool state pytree (``data``) — whatever arrays the family carries
    between decode steps, allocated once at pool width;
  * the per-slot device-side position vector (``pos_dev``), threaded and
    donated through the decode program so positions advance device-side;
  * the jitted prefill/decode programs (family-dispatched through
    ``models.api``, so one program builder covers every family).

The engine talks only to the protocol:

  ``prefill_into(slots, toks, plens, full=, uniform=)``
      run the pool-width (ragged right-padded) prefill and write the
      admitted rows into freed slots; returns the first greedy tokens.
  ``step(last, live)``
      one donated decode step over the pool; returns the next tokens.
  ``reset_slots(idx)``
      park freed slots (zero positions; recurrent states also zero their
      rows — stale ``h``/``conv`` from a previous occupant is read
      unconditionally every step, unlike KV rows which are masked by
      ``cache_len``).
  ``max_len()`` / ``prefill_width(n)`` / ``supports_seq_sharding(cfg)``
      capacity, admission width and SPMD capability probes — the engine
      never branches on the model family, only on these.

The generic pool ops (scatter admitted rows, pad a full-pool prefill to
capacity, zero freed slots) are driven by each family's leaf-axis
metadata (``state_spec.LeafAxes`` from ``transformer.cache_axes`` /
``ssm.state_axes`` / ``hybrid.cache_axes``): every leaf has one slot axis
and at most one sequence axis, which is all those operations need.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import hot_path

from . import api
from .block_pool import OutOfBlocks


def _guard_tokens(logits, last=None):
    """Greedy next-token with the non-finite sentinel folded in: a row
    whose logits are not all finite emits token ``-1`` (never a valid
    vocab id) instead of whatever ``argmax`` makes of NaN/inf. Passing
    ``last`` (the decode carry's previous tokens) makes the sentinel
    *sticky* — one poisoned step marks the slot until the engine
    quarantines it at the next scheduling event, even if later logits
    look finite again. Elementwise + one lane reduction, fused into the
    surrounding program: no collectives, no host work, no new outputs —
    the device-side per-slot finite-logits flag IS the token stream."""
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    if last is not None:
        bad = bad | (last < 0)
    return jnp.where(bad, jnp.int32(-1), tok)


def _len_bucket(n: int, cap: int) -> int:
    """Pow2-rounded prefill length (>=8) so ragged admission shares a small
    set of prefill executables; capped at the cache's sequence capacity."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


# (repr(cfg), policy, decode_policy, kv_axis[, mesh]) -> (prefill_fn,
# prefill_plain_fn, decode_fn). jax.jit caches per function object, so the
# jitted closures must outlive any one Server — otherwise every server
# restart recompiles the programs. Greedy serving never reads logits on
# the host, so all programs return argmaxed (B, 1) token ids — one fused
# executable per step, no eager argmax dispatches.
#
# decode_fn(params, last, state, pos, live) -> (next, state, pos + live):
# the state pytree and the per-slot position vector are DONATED (their
# input buffers are reused for the outputs), so a decode step allocates no
# new state and the slot positions advance device-side — the hot loop
# performs zero host->device transfers and zero host syncs. The builder is
# family-generic: prefill/decode dispatch through models.api.
_PROGRAM_CACHE: dict = {}


def _programs(cfg, policy, mesh=None, kv_axis=None, decode_policy=None):
    # decode_policy: the (possibly merge-strategy-autotuned) policy the
    # decode program is built against; prefill keeps the group policy so
    # its in-jit autotune cache reads stay live.
    dpol = policy if decode_policy is None else decode_policy
    key = (repr(cfg), policy, dpol, kv_axis,
           mesh if kv_axis is not None else None)
    if key not in _PROGRAM_CACHE:
        pol = policy

        def prefill_fn(p, toks, plens):
            logits, state = api.prefill(
                p, cfg, {"tokens": toks, "prompt_len": plens}, policy=pol)
            return _guard_tokens(logits), state

        def prefill_plain_fn(p, toks):
            # every row full-length: no padding mask to apply (the common
            # uniform-traffic admission; skips the ragged machinery)
            logits, state = api.prefill(p, cfg, {"tokens": toks},
                                        policy=pol)
            return _guard_tokens(logits), state

        # chunk_fn(params, toks, state, off, clens) -> (next, state): one
        # fixed-shape resumable-prefill step over the whole pool. The
        # state is DONATED like the decode carry; rows with clens == 0
        # pass through bit-untouched, so decoding slots ride along free.
        def chunk_fn(p, toks, c, off, clens):
            logits, c = api.prefill_chunk(p, cfg, toks, c, off, clens,
                                          policy=pol)
            return _guard_tokens(logits), c

        if kv_axis is None:
            def decode_fn(p, t, c, pos, live):
                logits, state = api.decode_step(p, cfg, t, c, pos,
                                                policy=dpol, live=live)
                return _guard_tokens(logits, t), state, pos + live

            decode = jax.jit(decode_fn, donate_argnums=(2, 3))
            chunk = jax.jit(chunk_fn, donate_argnums=(2,))
        else:
            # Sequence-sharded decode (a KVDecodeState-only capability —
            # probed via supports_seq_sharding, never via the family):
            # ONE shard_map program per policy group, built here at engine
            # startup — the fused partial-statistics path instead of GSPMD
            # lowering. The cache lives (and stays) sharded along its S
            # axis; each layer's shard statistics fold through the
            # policy's merge strategy ("packed": one collective per
            # layer).
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import shard_map
            from repro.distributed.sharding import serve_cache_sharding
            from .transformer import decode_step_sharded
            # one source of truth for the pool placement: the program's
            # in/out specs are the spec of the sharding the engine
            # allocates the pool under.
            from jax.sharding import NamedSharding
            cshard = serve_cache_sharding(cfg, mesh, kv_axis)
            cspec = {name: s.spec for name, s in cshard.items()}

            def decode_local(p, t, c, pos, live):
                logits, c = decode_step_sharded(p, cfg, t, c, pos,
                                                policy=dpol,
                                                seq_axis=kv_axis,
                                                live=live)
                return _guard_tokens(logits, t), c, pos + live

            decode = jax.jit(
                shard_map(decode_local, mesh=mesh,
                          in_specs=(P(), P(), cspec, P(), P()),
                          out_specs=(P(), cspec, P())),
                donate_argnums=(2, 3))
            # Sharded chunk prefill: plain GSPMD with the carry pinned to
            # the pool placement on BOTH sides, so prefill compute lands
            # on the mesh and admitted rows are produced *under the pool
            # sharding* — no post-prefill re-placement device_put.
            repl = NamedSharding(mesh, P())
            chunk = jax.jit(chunk_fn,
                            in_shardings=(repl, repl, cshard, repl, repl),
                            out_shardings=(repl, cshard),
                            donate_argnums=(2,))

        _PROGRAM_CACHE[key] = (jax.jit(prefill_fn),
                               jax.jit(prefill_plain_fn),
                               decode, chunk)
    return _PROGRAM_CACHE[key]


# ---------------------------------------------------- speculative decoding

# Block-padding sentinel for token positions past a burst's accepted
# length. Distinct from the poison sentinel (-1): the engine filters PAD
# out of finished streams, while -1 still quarantines the slot.
SPEC_PAD = -2


def _spec_accept(toks, logits, clens, rem, live):
    """Device-side acceptance fold of one verify pass.

    ``toks`` (B, W) are the burst's candidates [t0, d1..dk] (t0 the
    pre-burst last token, d_i the draft proposals); ``logits`` (B, W, V)
    the exact-policy all-lane scores; ``clens`` (B,) the lanes actually
    scored (0 = dead/cap-full row); ``rem`` (B,) the per-slot remaining
    emission budget. Emits ``m = min(n_acc + 1, clens, rem)`` tokens per
    row: the longest draft prefix agreeing with the exact argmaxes plus
    the bonus token the exact pass proposes after it — so every emitted
    token is an exact-policy argmax and greedy output is identical to
    plain decode by construction. The non-finite poison sentinel is
    folded in lane-cumulatively (one bad lane poisons the rest of the
    burst) and stays sticky across bursts via t0 < 0. Elementwise + lane
    reductions only: no collectives, no host work."""
    b, w = toks.shape
    lanes = jnp.arange(w, dtype=jnp.int32)[None, :]
    e = jnp.argmax(logits, -1).astype(jnp.int32)                 # (B, W)
    badlane = ~jnp.all(jnp.isfinite(logits), axis=-1)
    bad = (jnp.cumsum(badlane.astype(jnp.int32), axis=1) > 0) \
        | (toks[:, :1] < 0)
    agree = (toks[:, 1:] == e[:, :-1]).astype(jnp.int32)         # (B, k)
    n_acc = jnp.sum(jnp.cumprod(agree, axis=1), axis=1)
    m = jnp.minimum(jnp.minimum(n_acc + 1, clens), rem)
    m = jnp.where(live > 0, jnp.maximum(m, 0), 0)
    tokv = jnp.where(bad, jnp.int32(-1), e)
    block = jnp.where(lanes < m[:, None], tokv, jnp.int32(SPEC_PAD))
    nlast = jnp.take_along_axis(tokv, jnp.clip(m - 1, 0, w - 1)[:, None], 1)
    nlast = jnp.where((m > 0)[:, None], nlast, toks[:, :1])
    return block, nlast, m


# (repr(cfg), policy, W, mode, cap[, page]) -> verify program. Same
# lifetime rationale as _PROGRAM_CACHE.
_SPEC_PROGRAM_CACHE: dict = {}


def _spec_programs(cfg, policy, w, mode, cap, page=None, impl="scan"):
    """ONE jitted exact-policy verify program for a W = spec_k + 1 draft
    burst, per (cfg, policy, W, pool flavor, impl).

    ``impl="scan"`` (default): the scoring pass is a ``lax.scan`` of W
    exact DECODE steps — the same per-token program math plain serving
    runs — fused with the acceptance fold into one dispatch. A
    chunk-shaped (all-lanes parallel) scoring pass was measured to
    differ from the decode-step path by ~1 bf16 ulp (different
    attention program shape, different XLA fusions), which flips argmax
    on near-tie logits and silently breaks the speculative == plain
    token-identity contract; the scan of decode steps makes identity
    hold by CONSTRUCTION, not by fp luck.

    ``impl="chunk"`` (KV modes only): scores all W lanes in ONE batched
    ``prefill_chunk`` pass — cache and weights are read once per burst
    instead of once per lane, which is the whole speculative speedup
    (a W-lane chunk costs about half of ONE decode step at serving
    sequence lengths). The price is the ~1-ulp divergence above: tokens
    remain exact-policy argmaxes of the chunk program, but near-tie
    logits may break ties differently than plain decode. Throughput
    mode; the identity contract holds only for "scan".

    Acceptance length ``m = min(n_agree + 1, clens, rem)`` is computed
    device-side and folded into the carry (positions advance by m,
    budgets shrink by m), so a burst costs zero host syncs. Modes:

      "kv"              single pass over the post-draft pool (donated):
                        the scan rewrites every burst row with exact
                        KV before any later step reads it, so the
                        cursor rewind IS the rollback — rows past the
                        new cursor are stale but cache_len-masked.
      "kv_paged"        same over a paged pool; tables are read-only
                        and NO page moves: full reservation means
                        rollback touches the allocator zero times.
      "recurrent"       two scans from the pre-burst snapshot c0
                        (recurrent state/ring KV has no rewindable
                        addressing): scan 1 scores all W lanes (state
                        discarded), scan 2 replays c0 through exactly
                        the accepted tokens with per-step live masking
                        — bit-identical to plain decode stopping at m.
                        c0 feeds both scans, so it is never donated.
      "recurrent_paged" the same two scans over the hybrid ring pools.

    ``cap`` is the linear cache capacity (lanes at positions >= cap are
    live-masked so the scan never writes past the pool) or None
    (recurrent state and ring buffers never exhaust)."""
    if impl not in ("scan", "chunk"):
        raise ValueError(f"unknown speculative verify impl {impl!r}")
    if impl == "chunk" and mode not in ("kv", "kv_paged"):
        raise ValueError(
            f"chunk verify needs a rewindable KV cache; mode {mode!r} "
            f"replays state step-exactly (use impl='scan')")
    key = (repr(cfg), policy, int(w), mode, cap, page, impl)
    if key not in _SPEC_PROGRAM_CACHE:
        pol = policy
        paged = mode.endswith("_paged")

        def _clens(pos0, live):
            room = (jnp.full_like(pos0, w) if cap is None
                    else jnp.int32(cap) - pos0)
            return jnp.where(live > 0, jnp.clip(room, 0, w), 0)

        def _lanes(toks):
            # scan inputs: ((W, B, 1) tokens, (W,) lane index)
            return (toks.T[:, :, None], jnp.arange(w, dtype=jnp.int32))

        def _scan(p, toks, c, tab, pos0, live, nlive, want_logits):
            # W decode steps fused into one program; step i runs with
            # live_i = live * (i < nlive), so masked lanes leave state
            # AND position bit-untouched — exactly a plain decode loop
            # that stopped after nlive steps.
            def body(carry, x):
                c, pos = carry
                ti, i = x
                lv = live * (i < nlive).astype(jnp.int32)
                if paged:
                    logits, c = api.decode_step_paged(
                        p, cfg, ti, c, tab, pos, policy=pol, live=lv)
                else:
                    logits, c = api.decode_step(p, cfg, ti, c, pos,
                                                policy=pol, live=lv)
                return (c, pos + lv), (logits[:, 0] if want_logits
                                       else jnp.zeros((), jnp.int32))
            (c, pos), ls = jax.lax.scan(body, (c, pos0), _lanes(toks))
            logits = (jnp.transpose(ls, (1, 0, 2)) if want_logits
                      else None)                              # (B, W, V)
            return logits, c, pos

        if mode in ("kv", "kv_paged") and impl == "chunk":
            def score_fn(p, toks, c, tab, pos0, rem, live):
                clens = _clens(pos0, live)
                if paged:
                    logits, c = api.prefill_chunk_paged(
                        p, cfg, toks, c, tab, pos0, clens, policy=pol,
                        all_lanes=True)
                else:
                    logits, c = api.prefill_chunk(
                        p, cfg, toks, c, pos0, clens, policy=pol,
                        all_lanes=True)
                block, nlast, m = _spec_accept(toks, logits, clens, rem,
                                               live)
                return block, nlast, c, pos0 + m, rem - m
        elif mode in ("kv", "kv_paged"):
            def score_fn(p, toks, c, tab, pos0, rem, live):
                clens = _clens(pos0, live)
                logits, c, _ = _scan(p, toks, c, tab, pos0, live, clens,
                                     True)
                block, nlast, m = _spec_accept(toks, logits, clens, rem,
                                               live)
                return block, nlast, c, pos0 + m, rem - m
        else:
            def score_fn(p, toks, c0, tab, pos0, rem, live):
                clens = _clens(pos0, live)
                logits, _, _ = _scan(p, toks, c0, tab, pos0, live, clens,
                                     True)
                block, nlast, m = _spec_accept(toks, logits, clens, rem,
                                               live)
                # the accepted tokens ARE toks[:, :m] (draft i agreed
                # with exact for i < m), so the replay feeds toks again
                c2, pos2 = _scan(p, toks, c0, tab, pos0, live, m,
                                 False)[1:]
                return block, nlast, c2, pos2, rem - m

        if mode == "kv":
            def verify_fn(p, toks, c, pos0, rem, live):
                return score_fn(p, toks, c, None, pos0, rem, live)

            verify = jax.jit(verify_fn, donate_argnums=(2, 3, 4))
        elif mode == "kv_paged":
            # XLA-CPU materializes the pool copy regardless; donation
            # would only add copies (mirrors _paged_programs).
            pool_d = () if jax.default_backend() == "cpu" else (2,)

            def verify_fn(p, toks, c, tab, pos0, rem, live):
                return score_fn(p, toks, c, tab, pos0, rem, live)

            verify = jax.jit(verify_fn, donate_argnums=pool_d + (4, 5))
        elif mode == "recurrent":
            def verify_fn(p, toks, c0, pos0, rem, live):
                return score_fn(p, toks, c0, None, pos0, rem, live)

            verify = jax.jit(verify_fn, donate_argnums=(3, 4))
        elif mode == "recurrent_paged":
            def verify_fn(p, toks, c0, tab, pos0, rem, live):
                return score_fn(p, toks, c0, tab, pos0, rem, live)

            verify = jax.jit(verify_fn, donate_argnums=(4, 5))
        else:
            raise ValueError(f"unknown speculative mode {mode!r}")

        _SPEC_PROGRAM_CACHE[key] = verify
    return _SPEC_PROGRAM_CACHE[key]


class DecodeState:
    """Base of the per-family serving-state implementations.

    Subclasses provide ``kind``, ``_state_axes(cfg)`` and (optionally)
    capability overrides; the pool algebra below is generic.
    """

    kind = "state"
    is_paged = False   # True for the block-pool states below

    @classmethod
    def supports_seq_sharding(cls, cfg) -> bool:
        """Whether this state can decode over a sequence-sharded pool
        (the SPMD serve loop). Only linear KV caches can."""
        return False

    def __init__(self, cfg, params, policy, pool_width, cache_s, *,
                 mesh=None, kv_axis=None):
        self.cfg, self.params, self.policy = cfg, params, policy
        self.pool_width, self.cache_s = pool_width, cache_s
        self.mesh, self.kv_axis = mesh, kv_axis
        self.axes = self._state_axes(cfg)
        self.data = None                 # pool pytree; set on first admit
        self.pos_dev = jnp.zeros((pool_width,), jnp.int32)
        self.params_decode = params
        self._repl = None                # mesh-replicated sharding (SPMD)
        self._state_shard = None         # sharded pool placement (SPMD)
        self._setup_placement()
        if self._repl is not None:
            self.params_decode = jax.device_put(params, self._repl)
            self.pos_dev = jax.device_put(self.pos_dev, self._repl)
        self.injector = None             # chaos harness (ft.inject)
        decode_policy = self._autotune_warmup()
        # remembered so set_policy can restore the EXACT original
        # programs (incl. the autotuned decode policy) after degradation
        self._policy0, self._dpol0 = policy, decode_policy
        self._dpol = decode_policy       # ACTIVE decode policy
        self._spec_k = 0                 # 0 = plain decode (no draft burst)
        (self._prefill, self._prefill_plain, self._decode,
         self._chunk) = _programs(cfg, policy, mesh, kv_axis,
                                  decode_policy)

    # ------------------------------------------------------- family hooks

    def _state_axes(self, cfg):
        raise NotImplementedError

    def _setup_placement(self):
        pass                             # single-device default

    def _autotune_warmup(self):
        return self.policy

    def max_len(self):
        """Length at which a slot must stop decoding (None = unbounded:
        recurrent state and ring-buffer windows never exhaust)."""
        return None

    def prefill_width(self, n: int) -> int:
        """Admission width for a wave whose longest prompt is ``n``."""
        return _len_bucket(n, self.cache_s)

    # --------------------------------------------------------- placement

    def place_tokens(self, x):
        """Place an engine-side array (tokens/liveness) next to the
        decode program's inputs (replicated on the mesh for SPMD)."""
        return x if self._repl is None else jax.device_put(x, self._repl)

    def _place_state(self, tree):
        if self._state_shard is None:
            return tree
        return jax.device_put(tree, self._state_shard)

    # ------------------------------------------------------- engine ops

    def prefill_into(self, slots, toks, plens, *, full, uniform=False):
        """One pool-width batched prefill; admitted rows land in freed
        slots. ``toks`` (pool_width, sp) right-padded prompts, ``plens``
        (pool_width,) real lengths (1 for rows without a request);
        ``full`` = the whole pool admitted at once (the prefill output
        *is* the pool, padded to capacity — no scatter); ``uniform`` =
        run the unmasked plain prefill (no padding exists). Returns the
        (pool_width, 1) first greedy tokens, placed for decode."""
        self._maybe_inject_admission_fault()
        if uniform:
            first, pref = self._prefill_plain(self.params,
                                              jnp.asarray(toks))
        else:
            first, pref = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(plens))
        first = self.place_tokens(first)
        sp = toks.shape[1]
        if full:
            def pad(leaf, ax):
                if ax.seq is None or leaf.shape[ax.seq] == self.cache_s:
                    return leaf
                widths = [(0, 0)] * leaf.ndim
                widths[ax.seq] = (0, self.cache_s - leaf.shape[ax.seq])
                return jnp.pad(leaf, widths)

            self.data = self._place_state(
                jax.tree.map(pad, pref, self.axes))
        else:
            if self.data is None:
                self.data = self._place_state(
                    api.init_cache(self.cfg, self.pool_width,
                                   self.cache_s))
            sl = jnp.asarray(np.asarray(slots))

            def insert(pool, leaf, ax):
                rows_idx = [slice(None)] * leaf.ndim
                rows_idx[ax.batch] = sl
                rows = leaf[tuple(rows_idx)]
                if self._repl is not None:
                    rows = jax.device_put(rows, self._repl)
                idx = [slice(None)] * pool.ndim
                idx[ax.batch] = sl
                if ax.seq is not None:
                    idx[ax.seq] = slice(0, sp)
                return pool.at[tuple(idx)].set(rows)

            self.data = jax.tree.map(insert, self.data, pref, self.axes)
        sl = jnp.asarray(np.asarray(slots))
        self.pos_dev = self.pos_dev.at[sl].set(
            jnp.asarray(np.asarray(plens)[np.asarray(slots)], jnp.int32))
        return first

    @hot_path
    def step(self, last, live):
        """One donated decode step over the pool; positions advance by
        ``live`` device-side. Returns the (pool_width, 1) next tokens."""
        nxt, self.data, self.pos_dev = self._decode(
            self.params_decode, last, self.data, self.pos_dev, live)
        return nxt

    def reset_slots(self, slots):
        """Park freed slots: zero their positions and (where
        ``_reset_leaf`` says so) state rows, so a stale occupant can
        never bleed into the next request admitted into the slot
        (recurrent ``h``/``conv`` is read unconditionally every step)."""
        sl = jnp.asarray(np.asarray(slots))
        self.pos_dev = self.pos_dev.at[sl].set(0)
        if self.data is not None:
            def zero(leaf, ax):
                if not self._reset_leaf(ax):
                    return leaf
                idx = [slice(None)] * leaf.ndim
                idx[ax.batch] = sl
                return leaf.at[tuple(idx)].set(0)

            self.data = jax.tree.map(zero, self.data, self.axes)

    def _reset_leaf(self, ax) -> bool:
        """Whether ``reset_slots`` must zero a leaf with these axes.
        Default: every leaf (recurrent snapshots are read
        unconditionally). KV-bearing states skip their sequence leaves —
        decode masks those rows by ``cache_len`` and admission prefill
        overwrites them, so zeroing (S, Hkv, hd) rows per finish would
        out-cost a decode step."""
        return True

    # ------------------------------------------------- chunked prefill

    def supports_chunked(self) -> bool:
        """Whether this pool admits prompts through the resumable chunk
        path (``begin_chunk`` / ``prefill_chunk_into`` /
        ``finish_chunk``). Contiguous pools always can: prefill positions
        never wrap a ring (prompts fit the allocated width — the same
        invariant monolithic admission relies on), so cache slot ==
        absolute position throughout prefill."""
        return True

    def chunk_width(self, c: int) -> int:
        """Resolve a requested chunk budget of ``c`` tokens to this
        family's program width. Families with chunk-decomposed
        recurrences round up so chunk boundaries stay on their native
        block size (admission-invariant fp summation order)."""
        return max(1, int(c))

    def begin_chunk(self, slot, prompt, plen) -> int:
        """Start chunked admission of a ``plen``-token prompt into
        ``slot``; returns the starting cursor (tokens already cached —
        nonzero when a paged pool attaches prefix-cache hit pages). The
        slot's position is pinned at ``plen`` now: decode steps in
        between see the row as dead (live == 0) and leave both the state
        row and the parked position untouched, so the completion tick
        flips the slot live with no extra device write."""
        del prompt
        self._maybe_inject_admission_fault()
        self.pos_dev = self.pos_dev.at[int(slot)].set(int(plen))
        return 0

    def finish_chunk(self, slot, prompt, plen):
        """Complete a chunked admission (paged pools publish the
        prompt's full pages to the prefix cache here)."""

    @hot_path
    def prefill_chunk_into(self, toks, offs, clens):
        """One fixed-shape chunk step over the whole pool: ``toks``
        (pool_width, C) chunk tokens, ``offs``/``clens`` (pool_width,)
        per-slot cursors and valid counts (0 = row not prefilling this
        tick; such rows pass through bit-untouched). Returns the
        (pool_width, 1) greedy tokens at each row's last valid lane —
        meaningful only for rows whose prompt completes this chunk."""
        if self.data is None:
            self.data = self._place_state(
                api.init_cache(self.cfg, self.pool_width, self.cache_s))
        first, self.data = self._chunk(
            self.params_decode, self.place_tokens(jnp.asarray(toks)),
            self.data, self.place_tokens(jnp.asarray(offs, jnp.int32)),
            self.place_tokens(jnp.asarray(clens, jnp.int32)))
        return first

    # ----------------------------------------------------------- shared

    def _linear_cap(self):
        # A pool smaller than the sliding window can never wrap its ring
        # buffer correctly (the write cursor is pos % window, which runs
        # past the pool's extent) — such a pool behaves like a linear
        # cache and must stop slots at capacity, exactly like a
        # window-less cache. Only a full-window pool decodes unbounded.
        w = self.cfg.sliding_window
        if w is None or self.cache_s < w:
            return self.cache_s
        return None

    # ----------------------------------------- fault tolerance / lifecycle

    def set_injector(self, inj):
        """Wire the chaos harness (``ft.inject.FaultInjector``) into this
        pool's scheduling-event paths. ``None`` (the default) disables
        injection; every guarded site then pays one attribute check."""
        self.injector = inj

    def _maybe_inject_admission_fault(self):
        if self.injector is not None and \
                self.injector.fire("admit.out_of_blocks"):
            raise OutOfBlocks("injected: admission rejected")

    def abort_chunk(self, slot):
        """Abandon a mid-chunk admission: release everything
        ``begin_chunk`` reserved for ``slot`` (pages, prefix refs, table
        row, pinned position) and park the slot. ``reset_slots`` already
        IS that release for every implementation — paged pools decref the
        slot's pages, drop its pending hit depth and zero its table row —
        so the protocol method is the documented alias; the engine calls
        ``abort_chunk`` so the intent (reservation rollback, not a
        finished request) reads at the call site."""
        self.reset_slots([int(slot)])

    def poison_slot(self, slot) -> bool:
        """Corrupt one slot's private state with NaNs (the
        ``decode.poison`` chaos fault). Returns False when there is
        nothing to poison yet (pool unallocated). The decode program's
        finite-logits guard must turn this into sentinel tokens — never
        into silently-wrong samples."""
        if self.data is None:
            return False
        j = int(slot)

        def nanify(leaf, ax):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[ax.batch] = j
            return leaf.at[tuple(idx)].set(jnp.nan)

        self.data = jax.tree.map(nanify, self.data, self.axes)
        return True

    def corrupt_prefix(self, injector) -> int:
        """Invalidate prefix-cache chains (the ``prefix.corrupt`` fault:
        detected corruption is handled by dropping the entry, never by
        serving it). Contiguous pools have no cache; paged KV overrides.
        Returns the number of entries invalidated."""
        return 0

    def scrub_slot(self, slot):
        """Quarantine release: zero EVERY floating leaf row of the slot
        — not just the rows ``reset_slots`` zeroes — then park it. A
        poisoned row's NaNs must not outlive its request: KV rows past a
        later occupant's ``cache_len`` still flow through additively-
        masked attention scores (NaN + -inf = NaN), so the plain reset
        (which skips cache_len-masked leaves by design) is not enough."""
        j = int(slot)
        if self.data is not None:
            def zero(leaf, ax):
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    return leaf
                idx = [slice(None)] * leaf.ndim
                idx[ax.batch] = j
                return leaf.at[tuple(idx)].set(0)

            self.data = jax.tree.map(zero, self.data, self.axes)
        self.reset_slots([j])

    def recover(self):
        """Rebuild the pool after a failed (donated) decode dispatch. A
        raised step must be presumed to have consumed the donated carry
        buffers, so the only safe move is to drop the pool and park every
        slot; the engine re-queues the victims through normal
        admission."""
        self.data = None
        self.pos_dev = jnp.zeros((self.pool_width,), jnp.int32)
        if self._repl is not None:
            self.pos_dev = jax.device_put(self.pos_dev, self._repl)

    def set_policy(self, policy):
        """Swap the group's execution policy in place (the degradation
        ladder's lever). Programs come from the module-level cache, so
        flipping to a previously-used policy — including back to the
        original — is a dict lookup, not a recompile. Returns the decode
        policy the programs were built against (the original autotuned
        one when restoring)."""
        dpol = self._dpol0 if policy == self._policy0 else policy
        self.policy = policy
        self._dpol = dpol
        (self._prefill, self._prefill_plain, self._decode,
         self._chunk) = _programs(self.cfg, policy, self.mesh,
                                  self.kv_axis, dpol)
        if self._spec_k:
            # degradation rebuilds the draft + verify programs against
            # the group's ACTIVE policy: "speculative == plain decode
            # under this policy" holds on every ladder rung.
            self._wire_spec()
        return dpol

    # ------------------------------------------------- speculative decoding

    def supports_speculative(self) -> bool:
        """Whether this pool can run draft bursts + batched verify (the
        self-speculative decode path). Gated per subclass on the chunk
        program's addressing model (linear, unsharded)."""
        return False

    def _spec_mode(self) -> str:
        raise NotImplementedError

    def _spec_copy_state(self) -> bool:
        """Whether a burst snapshot must copy the state pytree. False
        for positional (KV) pools — the verify chunk overwrites draft
        rows with exact rows and the cursor rewind IS the rollback;
        True for recurrent state, which has no positions to rewind."""
        return False

    def enable_speculative(self, spec_k: int) -> None:
        """Switch the pool to self-speculative decode: k-step draft
        bursts under the policy's ``draft_exp_backend`` verified by ONE
        batched exact-policy pass. Builds (cache-hits) the draft decode
        and verify programs; re-wired by ``set_policy`` so degradation
        keeps draft/verify consistent with the active rung."""
        if not self.supports_speculative():
            raise ValueError(
                f"{self.kind} state cannot run speculative decode")
        if not (isinstance(spec_k, int) and spec_k >= 2):
            raise ValueError(f"spec_k must be an int >= 2, got {spec_k!r}")
        self._spec_k = int(spec_k)
        self._wire_spec()

    def _draft_policy(self):
        # the ACTIVE decode policy with only its exp backend swapped:
        # autotuned fields and degradation state carry over, so draft
        # and exact programs differ in exactly one execution choice.
        return self._dpol.replace(exp_backend=self.policy.draft_exp_backend)

    def _spec_impl(self) -> str:
        # recurrent replays must be step-exact; KV modes honor the
        # policy's scan/chunk verify choice.
        mode = self._spec_mode()
        return (self.policy.spec_verify if mode in ("kv", "kv_paged")
                else "scan")

    def _wire_spec(self):
        self._draft_decode = _programs(self.cfg, self.policy, self.mesh,
                                       self.kv_axis,
                                       self._draft_policy())[2]
        self._verify = _spec_programs(self.cfg, self.policy,
                                      self._spec_k + 1, self._spec_mode(),
                                      self.max_len(),
                                      impl=self._spec_impl())

    def spec_snapshot(self):
        """Pre-burst snapshot: a FRESH positions buffer (draft steps
        donate ``pos_dev``) plus, for recurrent families, a copy of the
        state the burst will advance. Cheap where rollback is cheap: KV
        pools snapshot positions only."""
        pos0 = self.pos_dev + 0
        state0 = (jax.tree.map(jnp.copy, self.data)
                  if self._spec_copy_state() else None)
        return (pos0, state0)

    def spec_restore(self, snap):
        """Roll every slot back to a snapshot (bitwise). ``verify_step``
        is the normal consumer of a snapshot — acceptance folds the
        rewind into the verify program — so the explicit restore is the
        abort/fault path and the protocol's testable rollback contract.
        On KV pools the cursor rewind is the whole rollback (stale draft
        rows past the cursor are cache_len-masked and overwritten by the
        next burst); paged pools additionally touch the allocator ZERO
        times — full reservation means every page is already held and
        no accepted-prefix page is ever freed."""
        pos0, state0 = snap
        self.pos_dev = pos0 + 0
        if state0 is not None:
            self.data = jax.tree.map(jnp.copy, state0)

    @hot_path
    def draft_step(self, last, live):
        """One decode step under the DRAFT policy's program — the same
        carry contract as ``step`` (state + positions donated, zero host
        work), differing only in the exp backend the kernels route to."""
        nxt, self.data, self.pos_dev = self._draft_decode(
            self.params_decode, last, self.data, self.pos_dev, live)
        return nxt

    @hot_path
    def verify_step(self, toks, snap, rem, live):
        """ONE batched exact-policy pass scoring all W = k + 1 burst
        candidates at their per-slot offsets. Returns ``(block, last,
        rem)``: the (B, W) accepted-token block (SPEC_PAD past each
        row's accepted length), the new last token, and the advanced
        budget. Acceptance length is computed device-side and folded
        into the carry — positions advance by m inside the program, so
        a burst adds zero host syncs over a plain decode tick."""
        pos0, state0 = snap
        carry = self.data if state0 is None else state0
        block, nlast, self.data, self.pos_dev, rem = self._verify(
            self.params_decode, toks, carry, pos0, rem, live)
        return block, nlast, rem

    def check_integrity(self, live_slots=()):
        """Post-fault invariant sweep (deliberately NOT hot-path: it
        syncs). Freed slots must be parked at position 0 — a nonzero
        parked position means an abort path skipped ``reset_slots``."""
        live = {int(j) for j in live_slots}
        pos = np.asarray(self.pos_dev)
        for j in range(self.pool_width):
            if j not in live and int(pos[j]) != 0:
                raise AssertionError(
                    f"freed slot {j} parked at pos {int(pos[j])}")


class KVDecodeState(DecodeState):
    """Transformer families (dense / moe / vlm): today's KV cache +
    per-slot positions, including the sequence-sharded SPMD path."""

    kind = "kv"

    @classmethod
    def supports_seq_sharding(cls, cfg) -> bool:
        # windowed archs keep the GSPMD path: the ring-buffer wrap write
        # straddles shard boundaries.
        return cfg.sliding_window is None

    def _state_axes(self, cfg):
        from .transformer import cache_axes
        return cache_axes(cfg)

    def max_len(self):
        # a linear cache is exhausted when the next write would fall past
        # the last slot; ring-buffer windows wrap instead.
        return self._linear_cap()

    def supports_speculative(self) -> bool:
        # linear caches only: the cheap position-only rollback relies
        # on rejected rows staying cache_len-masked until overwritten —
        # a ring-buffer wrap instead DESTROYS the pre-burst row it
        # lands on, which only a (costly) pool snapshot could restore.
        # Single-partition (the verify program is unsharded) and
        # token-only families (vlm extras don't fit a decode scan).
        return (self.kv_axis is None and self.max_len() is not None
                and self.cfg.family not in ("vlm", "audio"))

    def _spec_mode(self) -> str:
        return "kv"

    def _setup_placement(self):
        if self.kv_axis is None:
            return
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import serve_cache_sharding
        # decode runs over the mesh; prefill stays on the default device
        # (its outputs are re-placed at admission).
        self._repl = NamedSharding(self.mesh, P())
        self._state_shard = serve_cache_sharding(self.cfg, self.mesh,
                                                 self.kv_axis)

    def _reset_leaf(self, ax) -> bool:
        return False      # pure KV: every leaf is cache_len-masked

    def _autotune_warmup(self):
        """Eagerly tune the decode-attention block size for this group's
        decode shape. Timing is meaningless inside the jitted decode
        program (tracers, not device work), so the tuner only ever
        *reads* its cache there — this one eager call at the real
        (pool_width, cache_s) shape times the candidates, memoizes the
        winner for the jit path to pick up, and persists it to disk so
        the next server start skips even this.

        On a sequence-sharded group it additionally times the two
        collective merge strategies (packed single-collective vs
        pmax+2×psum) at the group's exact decode shape and returns the
        policy with the winner baked in (the shard_map decode program
        takes the policy statically, so it must resolve before the
        program is built). Returns the — possibly tuned — policy.
        """
        cfg, policy = self.cfg, self.policy
        if not policy.autotune or policy.kernel_backend != "pallas":
            return policy
        from repro.kernels.dispatch import dispatch, autotune_policy
        lay = cfg.kv_cache_layout
        kv_shape = ((self.pool_width, cfg.n_kv_heads, self.cache_s, cfg.hd)
                    if lay == "bhsd" else
                    (self.pool_width, self.cache_s, cfg.n_kv_heads, cfg.hd))
        q = jnp.zeros((self.pool_width, 1, cfg.n_heads, cfg.hd),
                      jnp.dtype(cfg.compute_dtype))
        kv = jnp.zeros(kv_shape, jnp.bfloat16)      # init_cache's dtype
        clen = jnp.full((self.pool_width,), self.cache_s, jnp.int32)
        dispatch("decode_attention", policy)(q, kv, kv, clen, layout=lay,
                                             policy=policy)
        if self.kv_axis is None:
            return policy
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.kernels.decode_attention.ops import _sharded_program
        from .transformer import cache_seq_axis as _csa
        spec = [None] * 4
        spec[_csa(lay, stacked=False)] = self.kv_axis
        kvs = jax.device_put(kv, NamedSharding(self.mesh, P(*spec)))
        return autotune_policy(
            "decode_attention_sharded", policy,
            lambda p: _sharded_program(self.mesh, self.kv_axis, None, None,
                                       lay, p)(q, kvs, kvs, clen),
            q, kvs)


class RecurrentDecodeState(DecodeState):
    """ssm (mamba2/SSD): batched per-layer (h, conv) snapshots. No
    sequence axis anywhere — a slot's state is O(1) in its length, so
    there is no capacity cap and admission scatters whole slot rows."""

    kind = "recurrent"

    def _state_axes(self, cfg):
        from .ssm import state_axes
        return state_axes(cfg)

    def chunk_width(self, c: int) -> int:
        # Chunk boundaries pinned to the SSD block size: a boundary on a
        # ``cfg.ssm_chunk`` multiple keeps the per-block decomposition —
        # and so the fp summation order — identical to a one-shot pass,
        # making chunked prefill bitwise admission-invariant.
        q = self.cfg.ssm_chunk
        return -(-max(1, int(c)) // q) * q

    def supports_speculative(self) -> bool:
        return True                      # O(1) state: no cap, no shards

    def _spec_mode(self) -> str:
        return "recurrent"

    def _spec_copy_state(self) -> bool:
        return True


class HybridDecodeState(DecodeState):
    """hybrid (recurrentgemma/griffin): mixed per-period state — RG-LRU
    ``(h, conv)`` snapshots next to ring-buffer local-attention KV."""

    kind = "hybrid"

    def _state_axes(self, cfg):
        from .hybrid import cache_axes
        return cache_axes(cfg)

    def max_len(self):
        return self._linear_cap()

    def _reset_leaf(self, ax) -> bool:
        # zero only the recurrent snapshots; the ring-buffer KV leaves
        # are cache_len-masked and fully overwritten by the fixed-width
        # admission prefill, so zeroing them per finish is wasted work.
        return ax.seq is None

    def prefill_width(self, n: int) -> int:
        # Fixed admission width: the RG-LRU associative scan's combine
        # tree — and therefore its fp summation order — depends on the
        # scan *length*, so pow2 buckets would make a row's state drift
        # with the admission wave it rode in (vs. solo serving). A fixed
        # width keeps batched tokens bit-identical to solo tokens; it is
        # bounded by the sliding window, so the cost stays modest.
        return self.cache_s

    def supports_speculative(self) -> bool:
        # both regimes: the verify scans run plain decode steps, which
        # wrap the ring natively, and the snapshot copies the WHOLE
        # mixed state (RG-LRU rows AND ring KV) — a rejected burst's
        # ring overwrites are rebuilt from c0 by the replay scan, so
        # wrap-destroyed rows are never lost.
        return self.kv_axis is None

    def _spec_mode(self) -> str:
        return "recurrent"

    def _spec_copy_state(self) -> bool:
        return True


# --------------------------------------------------------------- paged pool

# (repr(cfg), policy, decode_policy, page, kv_axis[, mesh]) ->
# (prefill_hist_fn, decode_fn). Same lifetime rationale as _PROGRAM_CACHE.
_PAGED_PROGRAM_CACHE: dict = {}


def _paged_programs(cfg, policy, page, mesh=None, kv_axis=None,
                    decode_policy=None):
    dpol = policy if decode_policy is None else decode_policy
    key = (repr(cfg), policy, dpol, page, kv_axis,
           mesh if kv_axis is not None else None)
    if key not in _PAGED_PROGRAM_CACHE:
        pol = policy

        def prefill_hist_fn(p, toks, plens, hist):
            # suffix prefill against the shared-prefix KV gathered from
            # the pool (prefix-cache hot admission)
            logits, state = api.prefill(
                p, cfg, {"tokens": toks, "prompt_len": plens,
                         "hist": hist}, policy=pol)
            return _guard_tokens(logits), state

        # The pool donates everywhere except the CPU backend: XLA-CPU
        # lowers the page scatter to a full-pool materialization whether
        # or not the input buffer is donated, so donation there buys no
        # in-place update — it only adds an alias-restoring copy of the
        # whole pool per step (~25% of a reduced decode step). Positions
        # always donate; they are what keeps the hot loop host-sync-free.
        pool_d = () if jax.default_backend() == "cpu" else (2,)

        if kv_axis is None:
            def decode_fn(p, t, c, tab, pos, live):
                logits, c = api.decode_step_paged(p, cfg, t, c, tab, pos,
                                                  policy=dpol, live=live)
                return _guard_tokens(logits, t), c, pos + live

            decode = jax.jit(decode_fn, donate_argnums=pool_d + (4,))

            # chunk_fn(params, toks, pool, tables, off, clens): resumable
            # prefill scattered straight into the slots' reserved pages.
            # Sharded paged pools hold partition-local page ids the host
            # allocator owns — they admit monolithically (no chunk
            # program is built for them).
            def chunk_fn(p, toks, c, tab, off, clens):
                logits, c = api.prefill_chunk_paged(
                    p, cfg, toks, c, tab, off, clens, policy=pol)
                return _guard_tokens(logits), c

            chunk = jax.jit(chunk_fn, donate_argnums=pool_d)
        else:
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import shard_map
            from .transformer import decode_step_paged_sharded
            cspec = {"k": P(None, kv_axis), "v": P(None, kv_axis)}
            tspec = P(None, kv_axis)

            def decode_local(p, t, c, tab, pos, live):
                logits, c = decode_step_paged_sharded(
                    p, cfg, t, c, tab, pos, policy=dpol, seq_axis=kv_axis,
                    live=live)
                return _guard_tokens(logits, t), c, pos + live

            decode = jax.jit(
                shard_map(decode_local, mesh=mesh,
                          in_specs=(P(), P(), cspec, tspec, P(), P()),
                          out_specs=(P(), cspec, P())),
                donate_argnums=pool_d + (4,))
            chunk = None

        _PAGED_PROGRAM_CACHE[key] = (jax.jit(prefill_hist_fn), decode,
                                     chunk)
    return _PAGED_PROGRAM_CACHE[key]


def tune_block_page(cfg, policy, pool_width, cache_s):
    """Resolve the pool's page size BEFORE the pool exists: the page size
    is a pool-construction parameter (it shapes every KV leaf), so unlike
    ``block_s`` it can never be re-tuned per call — this one eager
    autotune over ``CANDIDATES["decode_attention_paged"]`` times each
    candidate on a synthetic pool of the group's real decode shape and
    the winner is baked into the pool. Non-autotuning / non-pallas
    policies use ``policy.block_page`` as-is."""
    if not policy.autotune or policy.kernel_backend != "pallas":
        return policy.block_page
    from repro.kernels.dispatch import autotune_policy, dispatch
    lay = cfg.kv_cache_layout
    q = jnp.zeros((pool_width, 1, cfg.n_heads, cfg.hd),
                  jnp.dtype(cfg.compute_dtype))
    clen = jnp.full((pool_width,), cache_s, jnp.int32)

    def run(p):
        pg = p.block_page
        ns = -(-cache_s // pg)
        n = 1 + pool_width * ns
        shape = ((n, cfg.n_kv_heads, pg, cfg.hd) if lay == "bhsd"
                 else (n, pg, cfg.n_kv_heads, cfg.hd))
        pool = jnp.zeros(shape, jnp.bfloat16)
        tab = jnp.arange(1, 1 + pool_width * ns,
                         dtype=jnp.int32).reshape(pool_width, ns)
        return dispatch("decode_attention_paged", p)(
            q, pool, pool, tab, clen, layout=lay, policy=p)

    tuned = autotune_policy("decode_attention_paged", policy, run, q)
    return tuned.block_page


def _paged_scatter_impl(pool, rows, g, sl, page, lay, batch_ax):
    if sl is not None:
        rows = jnp.take(rows, sl, axis=batch_ax)
    L = rows.shape[0]
    nc = g.shape[0] // rows.shape[1]
    if lay == "bhsd":
        n, hkv, sp, hd = rows.shape[1:]
        r = jnp.pad(rows, [(0, 0)] * 3 + [(0, nc * page - sp), (0, 0)])
        r = r.reshape(L, n, hkv, nc, page, hd).transpose(0, 1, 3, 2, 4, 5)
        r = r.reshape(L, n * nc, hkv, page, hd)
    else:
        n, sp, hkv, hd = rows.shape[1:]
        r = jnp.pad(rows, [(0, 0)] * 2 + [(0, nc * page - sp),
                                          (0, 0), (0, 0)])
        r = r.reshape(L, n * nc, page, hkv, hd)
    return pool.at[:, g].set(r.astype(pool.dtype))


_paged_scatter_jit = jax.jit(_paged_scatter_impl,
                             static_argnums=(4, 5, 6))


def _paged_scatter(pool, rows, gids, page, lay, *, rows_sel=None):
    """Scatter per-slot prefill KV into pool pages. ``pool`` is a stacked
    (L, N, page, Hkv, hd) ("bshd") / (L, N, Hkv, page, hd) ("bhsd") pool;
    ``rows`` the admitted rows of the prefill cache, (L, n, sp, Hkv, hd) /
    (L, n, Hkv, sp, hd); ``gids`` (n, ceil(sp/page)) GLOBAL page positions
    (the sharded pool's global axis order is partition-major, matching
    the allocator's gid layout). A partial last page is zero-padded —
    those positions sit beyond every reader's ``cache_len`` until decode
    overwrites them. Jitted (shape-keyed) so an admission pays one
    dispatch, not one per pad/reshape/scatter op. ``rows_sel=(sl, axis)``
    folds the admitted-row gather of the full prefill cache into the
    same program instead of an eager advanced-index on the host path."""
    g = jnp.asarray(np.asarray(gids).reshape(-1), jnp.int32)
    if rows_sel is None:
        return _paged_scatter_jit(pool, rows, g, None, page, lay, 0)
    sl, batch_ax = rows_sel
    return _paged_scatter_jit(pool, rows, g, jnp.asarray(sl), page, lay,
                              int(batch_ax))


def _paged_gather_hist_impl(pool, g, page, lay):
    b, hp = g.shape
    got = pool[:, g.reshape(-1)]
    L = got.shape[0]
    if lay == "bhsd":                       # (L, B*hP, Hkv, page, hd)
        hkv, hd = got.shape[2], got.shape[4]
        got = got.reshape(L, b, hp, hkv, page, hd)
        got = got.transpose(0, 1, 2, 4, 3, 5).reshape(L, b, hp * page,
                                                      hkv, hd)
    else:                                   # (L, B*hP, page, Hkv, hd)
        got = got.reshape(L, b, hp * page, *got.shape[3:])
    return got


_paged_gather_jit = jax.jit(_paged_gather_hist_impl,
                            static_argnums=(2, 3))


# One dispatch for an admission's table-row + position writes.
_admit_rows_jit = jax.jit(
    lambda tab, pos, sl, rows, pl: (tab.at[sl].set(rows),
                                    pos.at[sl].set(pl)))


def _paged_integrity(state, live):
    """Shared paged-pool invariant sweep: allocator self-check (free-list
    conservation), freed slots hold no pages and have all-zero table
    rows, and every page's refcount exactly equals its holders (slot
    tables + prefix-cache entries) — conservation with no orphans. Host
    work over host mirrors plus one table readback; runs only at
    fault-recovery events and in tests."""
    state.alloc.check()
    holders: dict = {}
    for j, pages in enumerate(state.slot_pages):
        if j not in live and pages:
            raise AssertionError(
                f"freed slot {j} still holds {len(pages)} pages")
        for gid in pages:
            holders[int(gid)] = holders.get(int(gid), 0) + 1
    pcache = getattr(state, "pcache", None)
    if pcache is not None:
        for gid, _, _ in pcache._entries.values():
            holders[int(gid)] = holders.get(int(gid), 0) + 1
    for gid in range(state.n_pages):
        if gid % state.alloc.per_part == 0:
            continue                      # scratch pages are never held
        refs = state.alloc.refcount(gid)
        held = holders.get(gid, 0)
        if refs != held:
            raise AssertionError(
                f"page {gid}: refcount {refs} != {held} holders")
    if state.tables is not None:
        tab = np.asarray(state.tables)
        for j in range(state.pool_width):
            if j not in live and tab[j].any():
                raise AssertionError(
                    f"freed slot {j} has a nonzero table row")


def _paged_gather_hist(pool, gids, page, lay):
    """Gather prefix pages into a contiguous (L, B, h, Hkv, hd) history
    (always "bshd" — the ``hist`` contract of ``transformer.prefill``).
    Rows without a history point at the scratch page; their gathered
    content is arbitrary and their outputs are ignored. Jitted for the
    same hot-admission dispatch reason as ``_paged_scatter``."""
    g = jnp.asarray(np.asarray(gids), jnp.int32)
    return _paged_gather_jit(pool, g, page, lay)


class PagedKVDecodeState(KVDecodeState):
    """Transformer families over a paged pool: fixed-size KV pages behind
    per-slot block tables, a host-side refcounted allocator, and a
    shared-prefix page cache.

    The tentpole invariants:

      * full reservation — a slot's whole table (ceil(cache_s/page)
        columns, minus its prefix-cache hits) is allocated at admission,
        so the decode hot loop NEVER touches the allocator or the tables:
        zero host work, zero host syncs, no preemption.
      * oversubscription comes from sharing, not from overcommit — N
        slots on a shared prefix of P pages store P + N*suffix physical
        pages against N*(P+suffix) logical tokens.
      * no shared page is ever written — decode writes only at positions
        >= the slot's prompt length, which lie strictly past every full
        (hashable, shareable) prompt page; ``BlockAllocator.cow`` remains
        the defensive discipline for any future in-page writer.
    """

    kind = "paged-kv"
    is_paged = True

    def __init__(self, cfg, params, policy, pool_width, cache_s, *,
                 mesh=None, kv_axis=None, n_pages=None, page=None,
                 prefix_cache=True):
        from .block_pool import BlockAllocator, PrefixCache
        self.page = int(page or tune_block_page(cfg, policy, pool_width,
                                                cache_s))
        self.ns = -(-cache_s // self.page)          # table columns per slot
        nsh = 1 if kv_axis is None else mesh.shape[kv_axis]
        if kv_axis is not None and self.ns % nsh:
            raise ValueError(
                f"table width {self.ns} not divisible by {nsh} shards")
        if n_pages is None:
            n_pages = nsh + pool_width * self.ns    # scratch + full pool
        if n_pages % nsh:
            raise ValueError(f"page budget {n_pages} not divisible by "
                             f"{nsh} shards")
        self.n_pages = int(n_pages)
        self.alloc = BlockAllocator(
            self.n_pages, n_partitions=nsh,
            cols_per_part=None if nsh == 1 else self.ns // nsh)
        self.use_prefix = bool(prefix_cache) and cfg.sliding_window is None
        self.pcache = PrefixCache(self.alloc, self.page) \
            if self.use_prefix else None
        self.slot_pages = [[] for _ in range(pool_width)]
        self.tables = None                          # device (B, nS) int32
        self._chunk_hit = {}       # slot -> prefix-hit depth (pages)
        super().__init__(cfg, params, policy, pool_width, cache_s,
                         mesh=mesh, kv_axis=kv_axis)
        (self._hist_prefill, self._decode_paged,
         self._chunk_paged) = _paged_programs(
            cfg, policy, self.page, mesh, kv_axis, self._decode_policy)

    # ------------------------------------------------------------ plumbing

    def _autotune_warmup(self):
        # the contiguous decode-attention tune is meaningless here and
        # the page size was already resolved before pool construction
        self._decode_policy = self.policy
        return self.policy

    def _placed_tables(self, arr):
        if self.kv_axis is None:
            return jnp.asarray(arr, jnp.int32)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(jnp.asarray(arr, jnp.int32),
                              NamedSharding(self.mesh, P(None,
                                                         self.kv_axis)))

    def _setup_placement(self):
        if self.kv_axis is None:
            return
        from jax.sharding import NamedSharding, PartitionSpec as P
        self._repl = NamedSharding(self.mesh, P())
        self._state_shard = {
            "k": NamedSharding(self.mesh, P(None, self.kv_axis)),
            "v": NamedSharding(self.mesh, P(None, self.kv_axis))}

    def _ensure_pool(self):
        if self.data is None:
            self.data = self._place_state(api.init_paged_cache(
                self.cfg, self.pool_width, self.n_pages, self.page))
            self.tables = self._placed_tables(
                np.zeros((self.pool_width, self.ns), np.int32))

    def _local_ids(self, gids):
        """Device-table values for global page ids (partition-local on a
        sharded pool: each shard indexes its own pool slice)."""
        g = np.asarray(gids, np.int64)
        return (g % self.alloc.per_part).astype(np.int32)

    # ------------------------------------------------------------- budget

    def pages_per_slot(self) -> int:
        return self.ns

    def free_with_evictable(self):
        """Per-partition page budget: free pages plus prefix-cache pages
        held only by the cache (refcount 1) — live state is never
        evicted, so those are genuinely reclaimable under pressure."""
        free = self.alloc.free_counts()
        if self.pcache is not None:
            ev = np.zeros_like(free)
            for gid, _, _ in self.pcache._entries.values():
                if self.alloc.refcount(gid) == 1:
                    ev[self.alloc.part_of(gid)] += 1
            free = free + ev
        return free

    def admission_need(self, prompt, *, cap_h=None):
        """(per-partition fresh-page counts, hit depth) for admitting one
        request. The hit depth is this prompt's own prefix-cache depth
        (capped at ``cap_h``, the wave's shared depth); fresh pages are
        the reserved columns ``[h, ns)`` mapped to their partitions."""
        h = 0
        if self.pcache is not None:
            p = np.asarray(prompt).reshape(-1)
            h = min(self.pcache.probe(p), (len(p) - 1) // self.page)
        if cap_h is not None:
            h = min(h, cap_h)
        need = np.zeros(self.alloc.n_partitions, np.int64)
        for c in range(h, self.ns):
            need[self.alloc.part_of_col(c)] += 1
        return need, h

    def can_admit(self, n_slots: int) -> bool:
        """Whether ``n_slots`` full (cold) reservations fit."""
        per_part = (self.ns if self.alloc.n_partitions == 1
                    else self.ns // self.alloc.n_partitions)
        return bool((self.free_with_evictable() >= n_slots * per_part).all())

    def admission_pin(self, prompt, h, reserved):
        """Evictable supply this request's admission will consume beyond
        its fresh-page need: per-partition counts (and gids) of its first
        ``h`` hit pages that are cache-only (refcount 1) and not already
        in ``reserved`` (pages pinned earlier in the same wave).
        ``free_with_evictable`` counts those pages as reclaimable while
        ``admission_need`` counts them as hits needing no fresh page —
        but attach raises their refcount, so the admission gate must
        debit them or it double-counts the supply and a later row's
        allocation can run out of pages mid-prefill."""
        pin = np.zeros(self.alloc.n_partitions, np.int64)
        gids = []
        if self.pcache is None or not h:
            return pin, gids
        p = np.asarray(prompt).reshape(-1)
        for gid in self.pcache.hit_gids(p, max_pages=h):
            if gid not in reserved and self.alloc.refcount(gid) == 1:
                pin[self.alloc.part_of(gid)] += 1
                gids.append(gid)
        return pin, gids

    def pool_stats(self) -> dict:
        s = {"page": self.page, "pages_total": self.n_pages,
             "pages_allocatable": self.n_pages - self.alloc.n_partitions,
             "pages_used": self.alloc.n_used(),
             "pages_free": self.alloc.n_free()}
        s["utilization"] = s["pages_used"] / max(s["pages_allocatable"], 1)
        if self.pcache is not None:
            s["prefix"] = self.pcache.stats()
        return s

    # ------------------------------------------------------- engine ops

    def prefill_into(self, slots, toks, plens, *, full, uniform=False):
        self._ensure_pool()
        self._maybe_inject_admission_fault()
        slots = list(np.asarray(slots).reshape(-1))
        toks_np = np.asarray(toks)
        plens_np = np.asarray(plens).reshape(-1)
        page, ns = self.page, self.ns

        # ---- prefix probe: the wave's shared history depth is the MIN
        # over its rows (one uniform hist shape per prefill program);
        # a cold row in the wave degrades it to a cold admission.
        h_pages = 0
        if self.pcache is not None and slots:
            h_pages = ns
            for j in slots:
                n_hit = self.pcache.probe(toks_np[j, :plens_np[j]])
                # a hit must leave >= 1 suffix token (the prefill needs a
                # real position to emit the first logits from)
                n_hit = min(n_hit, (int(plens_np[j]) - 1) // page)
                h_pages = min(h_pages, n_hit)

        # ---- attach the shared prefix FIRST, for every row, before any
        # fresh-page allocation: attach pins the hit pages (refcount++),
        # so an eviction triggered by a later row's alloc_cols can no
        # longer free a chain another row probed. If a probed page
        # vanished anyway (evicted in the probe->attach window), degrade
        # the wave to the depth every row actually holds — never crash.
        held_pref = {j: [] for j in slots}
        if h_pages:
            try:
                for j in slots:
                    held_pref[j] = self.pcache.attach(
                        toks_np[j, :plens_np[j]], max_pages=h_pages)
            except BaseException:
                # release every row already attached: a wave must hold
                # all of its references or none of them
                for gids in held_pref.values():
                    for gid in gids:
                        self.alloc.decref(int(gid))
                raise
            got = min(len(held_pref[j]) for j in slots)
            if got < h_pages:
                for j in slots:
                    for gid in held_pref[j][got:]:
                        self.alloc.decref(int(gid))
                    held_pref[j] = held_pref[j][:got]
                h_pages = got
        h = h_pages * page

        # ---- reserve the rest of each slot's table up front (full
        # reservation). All-or-nothing for the whole wave: on OutOfBlocks
        # every page the wave holds (attached and fresh) is released, so
        # the engine can re-queue the wave with no pages leaked.
        from .block_pool import OutOfBlocks
        new_tab = {}
        try:
            for j in slots:
                new_tab[j] = held_pref[j] + self.alloc.alloc_cols(
                    range(h_pages, ns))
        except OutOfBlocks:
            for j in slots:
                for gid in new_tab.get(j, held_pref[j]):
                    self.alloc.decref(int(gid))
            raise
        for j in slots:
            self.slot_pages[j] = new_tab[j]

        # ---- prefill (cold: full prompts; hot: suffix against the
        # gathered history) + page scatter of the computed KV
        lay = self.cfg.kv_cache_layout
        sl = jnp.asarray(np.asarray(slots))
        if h_pages == 0:
            if uniform:
                first, pref = self._prefill_plain(self.params,
                                                  jnp.asarray(toks))
            else:
                first, pref = self._prefill(self.params, jnp.asarray(toks),
                                            jnp.asarray(plens))
            sp = toks.shape[1]
            col0 = 0
        else:
            hist_tab = np.zeros((self.pool_width, h_pages), np.int64)
            for j in slots:
                hist_tab[j] = new_tab[j][:h_pages]
            hist = {kname: _paged_gather_hist(self.data[kname], hist_tab,
                                              page, lay)
                    for kname in ("k", "v")}
            sp = _len_bucket(int((plens_np - h).max()), self.cache_s - h)
            toks_suf = np.ones((self.pool_width, sp), toks_np.dtype)
            plens_suf = np.ones((self.pool_width,), plens_np.dtype)
            for j in slots:
                n_suf = int(plens_np[j]) - h
                toks_suf[j, :n_suf] = toks_np[j, h:h + n_suf]
                plens_suf[j] = n_suf
            first, pref = self._hist_prefill(
                self.params, jnp.asarray(toks_suf), jnp.asarray(plens_suf),
                hist)
            col0 = h_pages
        first = self.place_tokens(first)

        nc = -(-sp // page)
        gids = np.zeros((len(slots), nc), np.int64)
        for i, j in enumerate(slots):
            gids[i] = new_tab[j][col0:col0 + nc]
        for kname in ("k", "v"):
            ax = self.axes[kname]
            self.data[kname] = _paged_scatter(
                self.data[kname], pref[kname], gids, page, lay,
                rows_sel=(sl, ax.batch))

        # ---- publish full prompt pages to the prefix cache (the cache
        # takes its own refs, so shared prefixes outlive their slot)
        if self.pcache is not None:
            for j in slots:
                prompt = toks_np[j, :plens_np[j]]
                for c in range(h_pages, int(plens_np[j]) // page):
                    self.pcache.insert(prompt, c, self.slot_pages[j][c])

        # ---- table rows + positions (one fused device update)
        tab_rows = np.zeros((len(slots), ns), np.int32)
        for i, j in enumerate(slots):
            tab_rows[i] = self._local_ids(new_tab[j])
        self.tables, self.pos_dev = _admit_rows_jit(
            self.tables, self.pos_dev, sl, jnp.asarray(tab_rows),
            jnp.asarray(plens_np[np.asarray(slots)], jnp.int32))
        return first

    @hot_path
    def step(self, last, live):
        nxt, self.data, self.pos_dev = self._decode_paged(
            self.params_decode, last, self.data, self.tables, self.pos_dev,
            live)
        return nxt

    # ------------------------------------------------- speculative decoding

    def supports_speculative(self) -> bool:
        # same preconditions as per-slot chunk admission: the verify
        # chunk writes through the device tables (unsharded, linear)
        return self.supports_chunked()

    def _spec_mode(self) -> str:
        return "kv_paged"

    def _wire_spec(self):
        self._draft_decode_paged = _paged_programs(
            self.cfg, self.policy, self.page, self.mesh, self.kv_axis,
            self._draft_policy())[1]
        self._verify = _spec_programs(self.cfg, self.policy,
                                      self._spec_k + 1, self._spec_mode(),
                                      self.max_len(), page=self.page,
                                      impl=self._spec_impl())

    @hot_path
    def draft_step(self, last, live):
        nxt, self.data, self.pos_dev = self._draft_decode_paged(
            self.params_decode, last, self.data, self.tables, self.pos_dev,
            live)
        return nxt

    @hot_path
    def verify_step(self, toks, snap, rem, live):
        # tables are read-only and rollback never frees a page (full
        # reservation holds every column, accepted prefix included)
        pos0, _ = snap
        block, nlast, self.data, self.pos_dev, rem = self._verify(
            self.params_decode, toks, self.data, self.tables, pos0, rem,
            live)
        return block, nlast, rem

    # ------------------------------------------------- chunked prefill

    def supports_chunked(self) -> bool:
        # per-slot chunk admission writes through the device tables, so
        # it needs global == partition-local page ids (unsharded pools)
        # and a linear, non-wrapping table (no sliding window). Sharded
        # and windowed paged pools admit monolithically.
        return self.kv_axis is None and self.cfg.sliding_window is None

    def begin_chunk(self, slot, prompt, plen) -> int:
        """Reserve the slot's whole table up front (the same full-
        reservation invariant as monolithic admission) and attach this
        prompt's own prefix-cache hits — per-request, not the wave-min
        depth of batched admission, so a chunked request's hit depth is
        independent of who it was admitted with. The cursor starts past
        the attached pages; shared pages are never written by chunks
        (only full pages are shared, and writes begin at the cursor)."""
        self._ensure_pool()
        self._maybe_inject_admission_fault()
        from .block_pool import OutOfBlocks
        j, plen = int(slot), int(plen)
        prompt = np.asarray(prompt).reshape(-1)[:plen]
        page, ns = self.page, self.ns
        h_pages, held = 0, []
        if self.pcache is not None:
            # a hit must leave >= 1 suffix token to emit logits from
            h_pages = min(self.pcache.probe(prompt), (plen - 1) // page)
            if h_pages:
                held = self.pcache.attach(prompt, max_pages=h_pages)
                h_pages = len(held)
        try:
            tab = held + self.alloc.alloc_cols(range(h_pages, ns))
        except OutOfBlocks:
            for gid in held:
                self.alloc.decref(int(gid))
            raise
        self.slot_pages[j] = tab
        self._chunk_hit[j] = h_pages
        self.tables = self.tables.at[j].set(
            jnp.asarray(self._local_ids(tab), jnp.int32))
        self.pos_dev = self.pos_dev.at[j].set(plen)
        return h_pages * page

    def finish_chunk(self, slot, prompt, plen):
        # publish the prompt's full pages (past the attached hits) so
        # later requests share them — the cache takes its own refs
        j, plen = int(slot), int(plen)
        h0 = self._chunk_hit.pop(j, 0)
        if self.pcache is None:
            return
        prompt = np.asarray(prompt).reshape(-1)[:plen]
        for c in range(h0, plen // self.page):
            self.pcache.insert(prompt, c, self.slot_pages[j][c])

    @hot_path
    def prefill_chunk_into(self, toks, offs, clens):
        self._ensure_pool()
        first, self.data = self._chunk_paged(
            self.params, jnp.asarray(toks), self.data, self.tables,
            jnp.asarray(offs, jnp.int32), jnp.asarray(clens, jnp.int32))
        return first

    def reset_slots(self, slots):
        sl = jnp.asarray(np.asarray(slots))
        self.pos_dev = self.pos_dev.at[sl].set(0)
        for j in np.asarray(slots).reshape(-1):
            for gid in self.slot_pages[int(j)]:
                self.alloc.decref(int(gid))
            self.slot_pages[int(j)] = []
            self._chunk_hit.pop(int(j), None)
        if self.tables is not None:
            self.tables = self.tables.at[sl].set(0)

    # ----------------------------------------- fault tolerance / lifecycle

    def set_injector(self, inj):
        super().set_injector(inj)
        self.alloc.injector = inj        # alloc.out_of_blocks fires there

    def poison_slot(self, slot) -> bool:
        # NaN only the slot's PRIVATE pages (refcount 1): shared /
        # published prefix pages back other requests' histories, and the
        # fault model is "this slot's state went bad", not "the cache
        # lied to everyone". A fully-shared slot (aligned prompt, all
        # pages published) has no private page yet — report False so the
        # chaos driver picks another victim.
        if self.data is None:
            return False
        gids = [int(g) for g in self.slot_pages[int(slot)]
                if self.alloc.refcount(int(g)) == 1]
        if not gids:
            return False
        ids = jnp.asarray(self._local_ids(gids), jnp.int32)
        for kname in ("k", "v"):
            self.data[kname] = self.data[kname].at[:, ids].set(jnp.nan)
        return True

    def corrupt_prefix(self, injector) -> int:
        if self.pcache is None or not self.pcache._entries:
            return 0
        n = max(1, len(self.pcache._entries) // 2)
        return self.pcache.invalidate(n=n, rng=injector.rng)

    def scrub_slot(self, slot):
        # zero the slot's PRIVATE pages in the pool BEFORE the reset
        # returns them to the free list: a NaN page reallocated to a
        # later request sits past its cache_len but still flows through
        # additively-masked attention scores. Shared/published pages are
        # never poisoned (poison_slot skips them) and never written.
        j = int(slot)
        gids = [int(g) for g in self.slot_pages[j]
                if self.alloc.refcount(int(g)) == 1]
        if gids and self.data is not None:
            ids = jnp.asarray(self._local_ids(gids), jnp.int32)
            for kname in ("k", "v"):
                self.data[kname] = self.data[kname].at[:, ids].set(0)
        self.reset_slots([j])

    def recover(self):
        # the donated carry (pool + tables' target) is gone; every page
        # the slots hold AND every cached prefix page points into it —
        # release them all, then drop the pool itself
        for j in range(self.pool_width):
            for gid in self.slot_pages[j]:
                self.alloc.decref(int(gid))
            self.slot_pages[j] = []
        self._chunk_hit.clear()
        if self.pcache is not None:
            self.pcache.drop_all()
        self.tables = None
        super().recover()

    def set_policy(self, policy):
        dpol = super().set_policy(policy)
        self._decode_policy = dpol
        (self._hist_prefill, self._decode_paged,
         self._chunk_paged) = _paged_programs(
            self.cfg, policy, self.page, self.mesh, self.kv_axis, dpol)
        return dpol

    def check_integrity(self, live_slots=()):
        super().check_integrity(live_slots)
        _paged_integrity(self, {int(j) for j in live_slots})


class PagedHybridDecodeState(HybridDecodeState):
    """Hybrid family over a paged pool: the O(1) recurrent leaves keep
    their slot rows (generic scatter/zero), the ring-buffer KV leaves
    live in slotless page pools behind a fixed per-slot ring table of
    ceil(window/page) pages — allocated whole at admission, freed whole
    at finish. No prefix cache: a ring's page content depends on the
    slot's wrap phase, so pages are never content-addressable."""

    kind = "paged-hybrid"
    is_paged = True

    def __init__(self, cfg, params, policy, pool_width, cache_s, *,
                 mesh=None, kv_axis=None, n_pages=None, page=None,
                 prefix_cache=True):
        from .block_pool import BlockAllocator
        if kv_axis is not None:
            raise ValueError("paged hybrid state is single-partition")
        self.page = int(page or policy.block_page)
        self.ns = -(-cache_s // self.page)
        if n_pages is None:
            n_pages = 1 + pool_width * self.ns
        self.n_pages = int(n_pages)
        self.alloc = BlockAllocator(self.n_pages)
        self.pcache = None
        self.use_prefix = False
        self.slot_pages = [[] for _ in range(pool_width)]
        self.tables = None
        super().__init__(cfg, params, policy, pool_width, cache_s,
                         mesh=mesh, kv_axis=kv_axis)
        (_, self._decode_paged,
         self._chunk_paged) = _paged_programs(cfg, policy, self.page,
                                              None, None, policy)

    def can_admit(self, n_slots: int) -> bool:
        return self.alloc.n_free() >= n_slots * self.ns

    def free_with_evictable(self):
        return self.alloc.free_counts()

    def admission_need(self, prompt, *, cap_h=None):
        return np.array([self.ns], np.int64), 0

    def admission_pin(self, prompt, h, reserved):
        return np.zeros(1, np.int64), []    # no prefix cache: nothing pins

    def pages_per_slot(self) -> int:
        return self.ns

    def pool_stats(self) -> dict:
        s = {"page": self.page, "pages_total": self.n_pages,
             "pages_allocatable": self.n_pages - 1,
             "pages_used": self.alloc.n_used(),
             "pages_free": self.alloc.n_free()}
        s["utilization"] = s["pages_used"] / max(s["pages_allocatable"], 1)
        return s

    def _ensure_pool(self):
        if self.data is None:
            self.data = api.init_paged_cache(self.cfg, self.pool_width,
                                             self.n_pages, self.page)
            self.tables = jnp.zeros((self.pool_width, self.ns), jnp.int32)

    def prefill_into(self, slots, toks, plens, *, full, uniform=False):
        self._ensure_pool()
        self._maybe_inject_admission_fault()
        slots = list(np.asarray(slots).reshape(-1))
        plens_np = np.asarray(plens).reshape(-1)
        if uniform:
            first, pref = self._prefill_plain(self.params,
                                              jnp.asarray(toks))
        else:
            first, pref = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(plens))
        sp = toks.shape[1]
        sl = jnp.asarray(np.asarray(slots))
        gids = np.zeros((len(slots), -(-sp // self.page)), np.int64)
        tab_rows = np.zeros((len(slots), self.ns), np.int32)
        # all-or-nothing for the wave: a mid-wave OutOfBlocks releases the
        # earlier rows' rings so the engine can re-queue without a leak
        from .block_pool import OutOfBlocks
        try:
            for i, j in enumerate(slots):
                held = self.alloc.alloc_cols(range(self.ns))
                self.slot_pages[j] = held
                tab_rows[i] = held
                gids[i] = held[:gids.shape[1]]
        except OutOfBlocks:
            for j in slots:
                for gid in self.slot_pages[j]:
                    self.alloc.decref(int(gid))
                self.slot_pages[j] = []
            raise
        self.tables = self.tables.at[sl].set(jnp.asarray(tab_rows))

        def place(pool, leaf, ax):
            if ax.seq is None:           # recurrent leaf: slot-row scatter
                rows_idx = [slice(None)] * leaf.ndim
                rows_idx[ax.batch] = sl
                idx = [slice(None)] * pool.ndim
                idx[ax.batch] = sl
                return pool.at[tuple(idx)].set(leaf[tuple(rows_idx)])
            return _paged_scatter(pool, leaf, gids, self.page, "bshd",
                                  rows_sel=(sl, ax.batch))

        self.data = jax.tree.map(place, self.data, pref, self.axes)
        self.pos_dev = self.pos_dev.at[sl].set(
            jnp.asarray(plens_np[np.asarray(slots)], jnp.int32))
        return first

    @hot_path
    def step(self, last, live):
        nxt, self.data, self.pos_dev = self._decode_paged(
            self.params_decode, last, self.data, self.tables, self.pos_dev,
            live)
        return nxt

    # ------------------------------------------------- speculative decoding

    def supports_speculative(self) -> bool:
        # both ring regimes (see HybridDecodeState): the verify scans
        # wrap natively and the snapshot copies the ring pools too.
        # Single-partition by construction.
        return True

    def _spec_mode(self) -> str:
        return "recurrent_paged"

    def _wire_spec(self):
        self._draft_decode_paged = _paged_programs(
            self.cfg, self.policy, self.page, None, None,
            self._draft_policy())[1]
        self._verify = _spec_programs(self.cfg, self.policy,
                                      self._spec_k + 1, self._spec_mode(),
                                      self.max_len(), page=self.page)

    @hot_path
    def draft_step(self, last, live):
        nxt, self.data, self.pos_dev = self._draft_decode_paged(
            self.params_decode, last, self.data, self.tables, self.pos_dev,
            live)
        return nxt

    @hot_path
    def verify_step(self, toks, snap, rem, live):
        # the snapshot copy carries BOTH the RG-LRU rows and the ring
        # page pools; the two-pass verify rebuilds the exact post-accept
        # state from it. Tables read-only, zero allocator work.
        pos0, state0 = snap
        block, nlast, self.data, self.pos_dev, rem = self._verify(
            self.params_decode, toks, state0, self.tables, pos0, rem,
            live)
        return block, nlast, rem

    # ------------------------------------------------- chunked prefill

    def begin_chunk(self, slot, prompt, plen) -> int:
        # allocate the slot's whole ring up front, exactly like
        # monolithic admission; prompts fit the window so prefill
        # positions never wrap the ring table
        self._ensure_pool()
        self._maybe_inject_admission_fault()
        j = int(slot)
        held = self.alloc.alloc_cols(range(self.ns))
        self.slot_pages[j] = held
        self.tables = self.tables.at[j].set(
            jnp.asarray(np.asarray(held), jnp.int32))
        self.pos_dev = self.pos_dev.at[j].set(int(plen))
        return 0

    @hot_path
    def prefill_chunk_into(self, toks, offs, clens):
        self._ensure_pool()
        first, self.data = self._chunk_paged(
            self.params, jnp.asarray(toks), self.data, self.tables,
            jnp.asarray(offs, jnp.int32), jnp.asarray(clens, jnp.int32))
        return first

    def reset_slots(self, slots):
        super().reset_slots(slots)       # positions + recurrent leaf rows
        sl = jnp.asarray(np.asarray(slots))
        for j in np.asarray(slots).reshape(-1):
            for gid in self.slot_pages[int(j)]:
                self.alloc.decref(int(gid))
            self.slot_pages[int(j)] = []
        if self.tables is not None:
            self.tables = self.tables.at[sl].set(0)

    # ----------------------------------------- fault tolerance / lifecycle

    def set_injector(self, inj):
        super().set_injector(inj)
        self.alloc.injector = inj

    def poison_slot(self, slot) -> bool:
        # NaN only the recurrent snapshots: the paged KV leaves are
        # slotless pools whose batch axis the contiguous nanify would
        # mis-index. The RG-LRU state is read unconditionally every step,
        # so recurrent NaNs alone are guaranteed to reach the logits.
        if self.data is None:
            return False
        j = int(slot)

        def nanify(leaf, ax):
            if ax.seq is not None or \
                    not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            idx = [slice(None)] * leaf.ndim
            idx[ax.batch] = j
            return leaf.at[tuple(idx)].set(jnp.nan)

        self.data = jax.tree.map(nanify, self.data, self.axes)
        return True

    def recover(self):
        for j in range(self.pool_width):
            for gid in self.slot_pages[j]:
                self.alloc.decref(int(gid))
            self.slot_pages[j] = []
        self.tables = None
        super().recover()

    def scrub_slot(self, slot):
        # recurrent rows zero through the generic scrub; the slot's ring
        # pages are zeroed in the slotless pools before they return to
        # the free list (same NaN-reallocation hazard as paged KV)
        j = int(slot)
        gids = [int(g) for g in self.slot_pages[j]]
        if gids and self.data is not None:
            ids = jnp.asarray(np.asarray(gids), jnp.int32)

            def zero(leaf, ax):
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    return leaf
                if ax.seq is None:
                    idx = [slice(None)] * leaf.ndim
                    idx[ax.batch] = j
                    return leaf.at[tuple(idx)].set(0)
                return leaf.at[:, ids].set(0)

            self.data = jax.tree.map(zero, self.data, self.axes)
        self.reset_slots([j])

    def set_policy(self, policy):
        dpol = super().set_policy(policy)
        (_, self._decode_paged,
         self._chunk_paged) = _paged_programs(self.cfg, policy, self.page,
                                              None, None, dpol)
        return dpol

    def check_integrity(self, live_slots=()):
        super().check_integrity(live_slots)
        _paged_integrity(self, {int(j) for j in live_slots})


def decode_state_for(cfg, paged=False):
    """The DecodeState implementation serving ``cfg`` (the one family
    dispatch of the serving stack). ``paged`` selects the block-pool
    states; recurrent state is O(1) per slot — nothing to page — so ssm
    serves through the contiguous state either way."""
    if cfg.family == "ssm":
        return RecurrentDecodeState
    if cfg.family == "hybrid":
        return PagedHybridDecodeState if paged else HybridDecodeState
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode state to serve")
    return PagedKVDecodeState if paged else KVDecodeState
