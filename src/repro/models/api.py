"""Family-dispatching model API: init / loss / prefill / decode / specs.

This is the single entry point the trainer, server, dry-run and tests use.

Every compute entry accepts an optional ``policy`` (runtime.ExecPolicy).
Two mechanisms make one policy govern every family:

  * the transformer stack threads ``policy`` explicitly down to the
    attention/softmax kernels (kernel routing + static jit caching), and
  * ``cfg.with_policy(policy)`` projects the policy onto the config's
    execution fields, so families that read ``cfg.exp_impl`` directly
    (ssm, hybrid, moe router) follow the same switch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer, ssm, hybrid
from .transformer import cache_seq_axis  # noqa: F401  (re-export: serving)


def _mod(cfg):
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        return hybrid
    return transformer       # dense | moe | vlm | audio


def _apply_policy(cfg, policy):
    """Project a policy onto cfg (no-op when policy is None)."""
    return cfg if policy is None else cfg.with_policy(policy)


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def loss_fn(params, cfg, batch, *, policy=None):
    cfg = _apply_policy(cfg, policy)
    return _mod(cfg).loss_fn(params, cfg, batch, policy=policy)


def forward(params, cfg, batch, *, policy=None):
    cfg = _apply_policy(cfg, policy)
    m = _mod(cfg)
    if cfg.family in ("vlm", "audio"):
        out = m.forward(params, cfg, batch.get("tokens"),
                        batch.get("extra"), policy=policy)
    else:
        out = m.forward(params, cfg, batch["tokens"], policy=policy)
    return out[0] if isinstance(out, tuple) else out


def prefill(params, cfg, batch, *, policy=None):
    """Prompt forward -> (last_logits, decode_state).

    ``batch["prompt_len"]`` (optional, (B,) int32) marks ragged
    right-padded prompts — every decoding family honors it: attention
    masks the padding (recurrences dt/gather-mask it), pad K/V rows are
    zeroed, and logits (and recurrent states) are taken at each row's
    last real token.
    """
    cfg = _apply_policy(cfg, policy)
    m = _mod(cfg)
    prompt_len = batch.get("prompt_len")
    if cfg.family == "audio":
        # encoder-only: "prefill" is a full encode; no cache/decode exists.
        if prompt_len is not None:
            raise ValueError("encoder-only arch has no ragged prefill")
        from .layers import mask_padded_logits
        x, _ = transformer.forward(params, cfg, None, batch["extra"],
                                   policy=policy)
        logits = (x.astype(jnp.float32)
                  @ params["unembed"].astype(jnp.float32))
        return mask_padded_logits(logits, cfg.vocab), None
    if cfg.family == "vlm":
        return transformer.prefill(params, cfg, batch["tokens"],
                                   batch.get("extra"),
                                   prompt_len=prompt_len, policy=policy)
    hist = batch.get("hist")
    if hist is not None:
        # suffix prefill against a shared-prefix KV history (paged
        # prefix-cache hot path) — transformer families only.
        return transformer.prefill(params, cfg, batch["tokens"],
                                   prompt_len=prompt_len, policy=policy,
                                   hist=hist)
    return m.prefill(params, cfg, batch["tokens"],
                     prompt_len=prompt_len, policy=policy)


def init_cache(cfg, batch_size, seq_len):
    """Family-uniform decode-state constructor (the DecodeState pool
    allocator): every decoding family exposes
    ``init_cache(cfg, batch, seq_len)`` — KV families size their cache by
    ``seq_len``, recurrent families document it as a no-op (state is O(1)
    in sequence length). ``ssm.init_state`` remains as a deprecation
    shim."""
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode cache")
    return _mod(cfg).init_cache(cfg, batch_size, seq_len)


def prefill_chunk(params, cfg, tokens, cache, off, clens, *, policy=None,
                  all_lanes=False):
    """Resumable chunked prefill: advance every prefilling slot by one
    fixed-width (B, C) chunk against the contiguous slot-pool ``cache``.
    ``off`` (B,) per-slot progress cursors (tokens already cached);
    ``clens`` (B,) valid tokens per row this chunk — 0 marks rows not
    prefilling this tick, whose state passes through bit-untouched.
    Returns (last-valid-lane logits, new_cache). KV families write chunk
    KV at the cursor offset; recurrent families carry (h, conv) across
    chunks and ignore ``off``. ``all_lanes=True`` (speculative chunk
    verify) returns per-lane (B, C, V) logits — transformer caches
    only."""
    cfg = _apply_policy(cfg, policy)
    if cfg.family in ("audio", "vlm"):
        raise ValueError(f"{cfg.family} family has no chunked prefill")
    if all_lanes:
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"{cfg.family} family has no all-lanes chunk scoring")
        return _mod(cfg).prefill_chunk(params, cfg, tokens, cache, off,
                                       clens, policy=policy, all_lanes=True)
    return _mod(cfg).prefill_chunk(params, cfg, tokens, cache, off, clens,
                                   policy=policy)


def prefill_chunk_paged(params, cfg, tokens, cache, tables, off, clens, *,
                        policy=None, all_lanes=False):
    """``prefill_chunk`` over a paged cache: chunk KV scatters into each
    slot's reserved pages via ``tables`` (B, nS) at its cursor. Linear
    transformer caches and hybrid ring tables (prompts fit the window)
    only; the recurrent family has nothing to page. ``all_lanes`` as in
    ``prefill_chunk`` (linear transformer caches only)."""
    cfg = _apply_policy(cfg, policy)
    if cfg.family in ("audio", "vlm", "ssm"):
        raise ValueError(f"{cfg.family} family has no paged chunked prefill")
    if all_lanes:
        if cfg.family == "hybrid":
            raise ValueError(
                "hybrid family has no all-lanes chunk scoring")
        return _mod(cfg).prefill_chunk_paged(params, cfg, tokens, cache,
                                             tables, off, clens,
                                             policy=policy, all_lanes=True)
    return _mod(cfg).prefill_chunk_paged(params, cfg, tokens, cache, tables,
                                         off, clens, policy=policy)


def decode_step(params, cfg, token, cache, pos, *, policy=None, live=None):
    """One decode step. ``pos`` may be a scalar (whole batch at one
    position) or a per-slot (B,) vector (continuous batching) for every
    decoding family — recurrences ignore it, KV caches scatter by it.
    ``live`` (B,) int32 (serving only): rows with ``live == 0`` — free
    slots and slots mid-chunked-prefill — leave their state untouched
    (KV writes park at a droppable position, recurrent updates are
    where-masked)."""
    cfg = _apply_policy(cfg, policy)
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode step")
    return _mod(cfg).decode_step(params, cfg, token, cache, pos,
                                 policy=policy, live=live)


def init_paged_cache(cfg, batch_size, n_pages, page):
    """Paged decode-state constructor: KV families get slotless page
    pools driven by per-slot block tables (transformer: no slot axis at
    all; hybrid: pools for KV, per-slot leaves for the O(1) recurrent
    state). Recurrent (ssm) families have nothing to page — the caller
    uses ``init_cache`` there."""
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode cache")
    if cfg.family == "ssm":
        raise ValueError("recurrent state is O(1) per slot; nothing to page")
    if cfg.family == "hybrid":
        return hybrid.init_paged_cache(cfg, batch_size, n_pages, page)
    return transformer.init_paged_cache(cfg, n_pages, page)


def decode_step_paged(params, cfg, token, cache, tables, pos, *, policy=None,
                      live=None):
    """One decode step over a paged cache (see ``init_paged_cache``).
    ``tables`` (B, nS) int32 maps each slot's logical pages to physical
    pool pages; read-only inside the step. ``live`` as in
    ``decode_step`` (dead rows' writes park at gid == N)."""
    cfg = _apply_policy(cfg, policy)
    if cfg.family in ("audio", "ssm"):
        raise ValueError(f"{cfg.family} family has no paged decode step")
    return _mod(cfg).decode_step_paged(params, cfg, token, cache, tables,
                                       pos, policy=policy, live=live)


# ----------------------------------------------------------- input specs

def input_specs(cfg, shape, *, for_dryrun=True):
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    Returns a dict: for train -> {"batch": {...}}; for prefill -> prompt
    inputs; for decode -> {"token", "cache", "pos"}. Used by the dry-run
    (no allocation) and mirrored by data.synthetic for real arrays.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    extra = None
    s_txt = S
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        s_txt = S - nv
        extra = jax.ShapeDtypeStruct((B, nv, cfg.vision_embed_dim),
                                     jnp.float32)
    if cfg.family == "audio":
        extra = jax.ShapeDtypeStruct((B, S, cfg.frame_input_dim),
                                     jnp.float32)

    if shape.kind == "train":
        batch = {"tokens": tok(B, s_txt), "labels": tok(B, s_txt)}
        if extra is not None:
            batch["extra"] = extra
        if cfg.family == "audio":
            batch["tokens"] = tok(B, S)   # unused; labels drive the loss
            batch["labels"] = tok(B, S)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": tok(B, s_txt)}
        if extra is not None:
            batch["extra"] = extra
        if cfg.family == "audio":
            batch.pop("tokens")
        return {"batch": batch}

    # decode: token + cache at full context length
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"token": tok(B, 1), "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}
