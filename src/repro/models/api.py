"""Family-dispatching model API: init / loss / prefill / decode / specs.

This is the single entry point the trainer, server, dry-run and tests use.

Every compute entry accepts an optional ``policy`` (runtime.ExecPolicy).
Two mechanisms make one policy govern every family:

  * the transformer stack threads ``policy`` explicitly down to the
    attention/softmax kernels (kernel routing + static jit caching), and
  * ``cfg.with_policy(policy)`` projects the policy onto the config's
    execution fields, so families that read ``cfg.exp_impl`` directly
    (ssm, hybrid, moe router) follow the same switch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer, ssm, hybrid
from .transformer import cache_seq_axis  # noqa: F401  (re-export: serving)


def _mod(cfg):
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        return hybrid
    return transformer       # dense | moe | vlm | audio


def _apply_policy(cfg, policy):
    """Project a policy onto cfg (no-op when policy is None)."""
    return cfg if policy is None else cfg.with_policy(policy)


def init_params(cfg, key):
    return _mod(cfg).init_params(cfg, key)


def loss_fn(params, cfg, batch, *, policy=None):
    cfg = _apply_policy(cfg, policy)
    if cfg.family in ("ssm", "hybrid"):
        return _mod(cfg).loss_fn(params, cfg, batch)
    return transformer.loss_fn(params, cfg, batch, policy=policy)


def forward(params, cfg, batch, *, policy=None):
    cfg = _apply_policy(cfg, policy)
    m = _mod(cfg)
    if cfg.family in ("vlm", "audio"):
        out = m.forward(params, cfg, batch.get("tokens"),
                        batch.get("extra"), policy=policy)
    elif cfg.family in ("ssm", "hybrid"):
        out = m.forward(params, cfg, batch["tokens"])
    else:
        out = m.forward(params, cfg, batch["tokens"], policy=policy)
    return out[0] if isinstance(out, tuple) else out


def prefill(params, cfg, batch, *, policy=None):
    """Prompt forward -> (last_logits, cache).

    ``batch["prompt_len"]`` (optional, (B,) int32) marks ragged
    right-padded prompts: attention masks the padding, pad K/V rows are
    zeroed, and logits are taken at each row's last real token
    (transformer families only).
    """
    cfg = _apply_policy(cfg, policy)
    m = _mod(cfg)
    prompt_len = batch.get("prompt_len")
    if prompt_len is not None and (cfg.family in ("ssm", "hybrid", "audio")):
        raise NotImplementedError(
            f"per-request prompt_len is not supported for the "
            f"{cfg.family!r} family")
    if cfg.family == "audio":
        # encoder-only: "prefill" is a full encode; no cache/decode exists.
        from .layers import mask_padded_logits
        x, _ = transformer.forward(params, cfg, None, batch["extra"],
                                   policy=policy)
        logits = (x.astype(jnp.float32)
                  @ params["unembed"].astype(jnp.float32))
        return mask_padded_logits(logits, cfg.vocab), None
    if cfg.family == "vlm":
        return transformer.prefill(params, cfg, batch["tokens"],
                                   batch.get("extra"),
                                   prompt_len=prompt_len, policy=policy)
    if cfg.family in ("ssm", "hybrid"):
        return m.prefill(params, cfg, batch["tokens"])
    return transformer.prefill(params, cfg, batch["tokens"],
                               prompt_len=prompt_len, policy=policy)


def init_cache(cfg, batch_size, seq_len):
    if cfg.family == "ssm":
        return ssm.init_state(cfg, batch_size)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch_size, seq_len)
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode cache")
    return transformer.init_cache(cfg, batch_size, seq_len)


def decode_step(params, cfg, token, cache, pos, *, policy=None):
    """One decode step. ``pos`` may be a scalar (whole batch at one
    position) or a per-slot (B,) vector (continuous batching; transformer
    families only)."""
    cfg = _apply_policy(cfg, policy)
    m = _mod(cfg)
    if cfg.family == "audio":
        raise ValueError("encoder-only arch has no decode step")
    if cfg.family in ("ssm", "hybrid"):
        if getattr(pos, "ndim", 0):
            raise NotImplementedError(
                f"per-slot decode positions are not supported for the "
                f"{cfg.family!r} family")
        return m.decode_step(params, cfg, token, cache, pos)
    return transformer.decode_step(params, cfg, token, cache, pos,
                                   policy=policy)


# ----------------------------------------------------------- input specs

def input_specs(cfg, shape, *, for_dryrun=True):
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    Returns a dict: for train -> {"batch": {...}}; for prefill -> prompt
    inputs; for decode -> {"token", "cache", "pos"}. Used by the dry-run
    (no allocation) and mirrored by data.synthetic for real arrays.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    extra = None
    s_txt = S
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        s_txt = S - nv
        extra = jax.ShapeDtypeStruct((B, nv, cfg.vision_embed_dim),
                                     jnp.float32)
    if cfg.family == "audio":
        extra = jax.ShapeDtypeStruct((B, S, cfg.frame_input_dim),
                                     jnp.float32)

    if shape.kind == "train":
        batch = {"tokens": tok(B, s_txt), "labels": tok(B, s_txt)}
        if extra is not None:
            batch["extra"] = extra
        if cfg.family == "audio":
            batch["tokens"] = tok(B, S)   # unused; labels drive the loss
            batch["labels"] = tok(B, S)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": tok(B, s_txt)}
        if extra is not None:
            batch["extra"] = extra
        if cfg.family == "audio":
            batch.pop("tokens")
        return {"batch": batch}

    # decode: token + cache at full context length
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"token": tok(B, 1), "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}
