from .checkpoint import (save, restore, latest_step, unflatten_like,
                         reshard, AsyncCheckpointer)
