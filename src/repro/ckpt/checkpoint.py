"""Checkpointing: atomic, async-capable, mesh-elastic (no orbax offline).

Layout:  <dir>/step_<k>/
            manifest.json     step, config hash, leaf paths/dtypes/shapes
            arrays.npz        one entry per flattened pytree path
         <dir>/LATEST         text file with the newest complete step dir

Guarantees used by the fault-tolerance story:
  * atomicity — writes go to ``.tmp-...`` then ``os.replace`` (POSIX rename
    is atomic), LATEST updated last, so a crash mid-save never corrupts the
    restore point;
  * async — ``AsyncCheckpointer`` snapshots to host memory synchronously
    (jax.device_get) and does the file I/O on a worker thread, overlapping
    with the next training steps;
  * elasticity — ``restore`` is mesh-agnostic (returns host numpy), and
    ``reshard`` places the tree onto any new mesh/sharding, so a job can
    restart on a different topology (checkpoint saved on mesh A, resumed
    on mesh B).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np
import jax


SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(tree, directory: str, step: int, *, extra: dict | None = None):
    """Synchronous atomic save. Returns the final step directory."""
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    return _write(host, directory, step, extra or {})


def _write(host: dict, directory: str, step: int, extra: dict) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp-step_{step:08d}-{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    # bfloat16 has no numpy dtype: store raw uint16 + dtype tag.
    conv = {}
    manifest = {"step": step, "extra": extra, "leaves": {}}
    for k, v in host.items():
        tag = str(v.dtype)
        if tag == "bfloat16":
            v = v.view(np.uint16)
        manifest["leaves"][k] = {"dtype": tag, "shape": list(v.shape)}
        conv[k] = v
    np.savez(os.path.join(tmp, "arrays.npz"), **conv)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(directory, ".LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, ".LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[-1])


def restore(directory: str, step: int | None = None):
    """Returns (flat_dict_of_numpy, manifest). Mesh-agnostic."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    import ml_dtypes
    out = {}
    for k, meta in manifest["leaves"].items():
        v = data[k]
        if meta["dtype"] == "bfloat16":
            v = v.view(ml_dtypes.bfloat16)
        out[k] = v
    return out, manifest


def unflatten_like(flat: dict, template):
    """Rebuild a pytree with `template`'s structure from flat path->array."""
    tflat, treedef = _flatten(template)
    missing = set(tflat) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    leaves = [flat[k] for k in tflat]
    ref_leaves, _ = jax.tree_util.tree_flatten(template)
    order = jax.tree_util.tree_structure(template)
    # tree_flatten_with_path and tree_flatten agree on leaf order
    return jax.tree_util.tree_unflatten(order, leaves)


def reshard(tree, shardings):
    """Place a host tree onto device shardings (elastic restart on a new
    mesh): jax.device_put handles arbitrary host->sharded placement."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training. snapshot() blocks only for
    device_get; the write happens on a daemon thread. wait() joins."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save_async(self, tree, step: int, *, extra: dict | None = None):
        self.wait()
        flat, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def work():
            try:
                _write(host, self.directory, step, extra or {})
                self._gc()
            except Exception as e:          # surfaced via last_error/wait
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[-1]) for n in os.listdir(self.directory)
            if n.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
